#!/usr/bin/env python3
"""Bench regression guard: compare a fresh event-queue bench run against
the checked-in baseline and fail if any shared workload regressed by more
than the allowed factor (default 2x on mean ns/iter).

Usage: bench_guard.py <baseline.json> <current.json> [max_ratio]

The baseline ships as BENCH_event_queue.json at the repo root; the bench
rewrites that file in place, so CI copies the baseline aside before the
run. A baseline with no results (fresh seed) passes with a notice —
committing the first real run arms the guard.

Record the baseline in the SAME environment that checks it: copy the
rewritten BENCH_event_queue.json out of a CI run (ARENA_BENCH_FAST=1 on
a shared runner) rather than a fast dev box, or the 2x gate measures
hardware difference instead of regression.
"""

import json
import sys


def load(path):
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: float(r["mean_ns"]) for r in data.get("results", [])}


def main():
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    baseline = load(sys.argv[1])
    current = load(sys.argv[2])
    max_ratio = float(sys.argv[3]) if len(sys.argv) > 3 else 2.0
    if not baseline:
        print(
            "bench guard: baseline has no results yet (pending first "
            "recorded run) — passing; commit the rewritten "
            "BENCH_event_queue.json to arm the guard"
        )
        return 0
    if not current:
        print("bench guard: FAIL — current run produced no results")
        return 1
    failed = []
    for name, base_ns in sorted(baseline.items()):
        cur_ns = current.get(name)
        if cur_ns is None:
            # A baseline entry with no matching current workload is a
            # renamed/retired bench, not a regression: warn so the noise
            # is visible, and let the record-baselines merge step drop
            # the stale entry on the next main push.
            print(
                f"bench guard: WARN — baseline workload '{name}' missing "
                "from current run (renamed or retired?); not failing"
            )
            continue
        ratio = cur_ns / base_ns if base_ns > 0 else float("inf")
        marker = "FAIL" if ratio > max_ratio else "ok"
        print(
            f"bench guard: {name}: {base_ns:.0f} -> {cur_ns:.0f} ns/iter "
            f"({ratio:.2f}x) {marker}"
        )
        if ratio > max_ratio:
            failed.append(name)
    for name in sorted(set(current) - set(baseline)):
        print(f"bench guard: new workload '{name}' (no baseline, ignored)")
    if failed:
        print(
            f"bench guard: FAIL — {len(failed)} workload(s) regressed "
            f">{max_ratio}x: {', '.join(failed)}"
        )
        return 1
    print("bench guard: all workloads within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
