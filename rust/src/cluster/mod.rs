//! Profiling module (paper §3.1): characterize devices, then cluster them
//! so each edge serves devices of similar capability (straggler removal).
//!
//! * `afkmc2` — AFK-MC² seeding (Bachem et al., NeurIPS'16), the paper's
//!   choice for fast, provably good k-means++ style seeds.
//! * `kmeans` — size-balanced Lloyd iterations ("minimizes the mean square
//!   error and balances the cluster size").
//! * `profiling` — runs the profiling task, builds the V_i feature vectors
//!   [T_pro, E_pro, Fl_pro, Fr_pro, Ut_pro], z-scores them, and assigns
//!   devices to edges (region-constrained, as in §3.1 "divide edges and
//!   devices into multiple groups by region").

pub mod afkmc2;
pub mod kmeans;
pub mod profiling;

pub use afkmc2::afkmc2_seeds;
pub use kmeans::{balanced_kmeans, Clustering};
pub use profiling::{profile_devices, ProfilingOutcome};
