//! Size-balanced k-means (the paper's "K-cluster algorithm ... minimizes
//! the mean square error and balances the cluster size").
//!
//! Assignment step: all (point, centroid) pairs sorted by distance, points
//! greedily assigned while respecting a per-cluster capacity of ⌈n/k⌉ —
//! this keeps clusters equal-sized (each edge must serve the same number
//! of devices so the HFL topology stays valid) while staying close to the
//! unconstrained optimum.

use crate::linalg::dist2;
use crate::util::rng::Rng;

use super::afkmc2::afkmc2_seeds;

#[derive(Clone, Debug)]
pub struct Clustering {
    /// Cluster id per point.
    pub assignment: Vec<usize>,
    pub centroids: Vec<Vec<f64>>,
    /// Final within-cluster mean squared error.
    pub mse: f64,
    pub iterations: usize,
}

impl Clustering {
    pub fn members(&self, cluster: usize) -> Vec<usize> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|(_, &c)| c == cluster)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn sizes(&self, k: usize) -> Vec<usize> {
        let mut s = vec![0usize; k];
        for &c in &self.assignment {
            s[c] += 1;
        }
        s
    }
}

/// Balanced Lloyd iterations from AFK-MC² seeds.
pub fn balanced_kmeans(
    points: &[Vec<f64>],
    k: usize,
    max_iters: usize,
    rng: &mut Rng,
) -> Clustering {
    let n = points.len();
    assert!(k >= 1 && n >= k);
    let cap = n.div_ceil(k);
    let seeds = afkmc2_seeds(points, k, (2 * n).max(30), rng);
    let mut centroids: Vec<Vec<f64>> =
        seeds.iter().map(|&s| points[s].clone()).collect();
    let mut assignment = vec![usize::MAX; n];
    let mut iterations = 0;

    for it in 0..max_iters {
        iterations = it + 1;
        // --- balanced assignment ---
        let mut pairs: Vec<(f64, usize, usize)> = Vec::with_capacity(n * k);
        for (i, p) in points.iter().enumerate() {
            for (c, cent) in centroids.iter().enumerate() {
                pairs.push((dist2(p, cent), i, c));
            }
        }
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut new_assignment = vec![usize::MAX; n];
        let mut counts = vec![0usize; k];
        let mut assigned = 0;
        for &(_, i, c) in &pairs {
            if new_assignment[i] == usize::MAX && counts[c] < cap {
                new_assignment[i] = c;
                counts[c] += 1;
                assigned += 1;
                if assigned == n {
                    break;
                }
            }
        }
        let converged = new_assignment == assignment;
        assignment = new_assignment;
        // --- centroid update ---
        let dim = points[0].len();
        let mut sums = vec![vec![0.0; dim]; k];
        let mut cnts = vec![0usize; k];
        for (i, &c) in assignment.iter().enumerate() {
            for (d, v) in points[i].iter().enumerate() {
                sums[c][d] += v;
            }
            cnts[c] += 1;
        }
        for c in 0..k {
            if cnts[c] > 0 {
                for d in 0..dim {
                    sums[c][d] /= cnts[c] as f64;
                }
                centroids[c] = sums[c].clone();
            }
        }
        if converged {
            break;
        }
    }

    let mse = points
        .iter()
        .zip(&assignment)
        .map(|(p, &c)| dist2(p, &centroids[c]))
        .sum::<f64>()
        / n as f64;
    Clustering {
        assignment,
        centroids,
        mse,
        iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;

    fn blobs(
        centers: &[(f64, f64)],
        per: usize,
        spread: f64,
        rng: &mut Rng,
    ) -> Vec<Vec<f64>> {
        let mut pts = Vec::new();
        for &(cx, cy) in centers {
            for _ in 0..per {
                pts.push(vec![
                    cx + spread * rng.normal(),
                    cy + spread * rng.normal(),
                ]);
            }
        }
        pts
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(1);
        let pts =
            blobs(&[(0.0, 0.0), (20.0, 0.0), (0.0, 20.0)], 20, 0.5, &mut rng);
        let c = balanced_kmeans(&pts, 3, 50, &mut rng);
        // Points from the same blob share a cluster.
        for b in 0..3 {
            let first = c.assignment[b * 20];
            for i in 0..20 {
                assert_eq!(c.assignment[b * 20 + i], first, "blob {b}");
            }
        }
        assert!(c.mse < 1.0, "mse {}", c.mse);
    }

    #[test]
    fn prop_clusters_are_balanced() {
        check(
            "kmeans-balance",
            25,
            |g| {
                let k = g.usize_in(1, 6);
                let n = k * g.usize_in(2, 12);
                let seed = g.rng.next_u64();
                (n, k, seed)
            },
            |&(n, k, seed)| {
                let mut rng = Rng::new(seed);
                let pts: Vec<Vec<f64>> = (0..n)
                    .map(|_| vec![rng.range(-5.0, 5.0), rng.range(-5.0, 5.0)])
                    .collect();
                let c = balanced_kmeans(&pts, k, 30, &mut rng);
                let cap = n.div_ceil(k);
                let sizes = c.sizes(k);
                if sizes.iter().sum::<usize>() != n {
                    return Err("not all points assigned".into());
                }
                if sizes.iter().any(|&s| s > cap) {
                    return Err(format!("cap {cap} violated: {sizes:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn exact_balance_when_divisible() {
        let mut rng = Rng::new(5);
        let pts: Vec<Vec<f64>> = (0..50)
            .map(|_| vec![rng.range(0.0, 1.0), rng.range(0.0, 1.0)])
            .collect();
        let c = balanced_kmeans(&pts, 5, 50, &mut rng);
        assert_eq!(c.sizes(5), vec![10; 5]);
    }

    #[test]
    fn k_equals_one() {
        let mut rng = Rng::new(6);
        let pts: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let c = balanced_kmeans(&pts, 1, 10, &mut rng);
        assert!(c.assignment.iter().all(|&a| a == 0));
        assert!((c.centroids[0][0] - 4.5).abs() < 1e-9);
    }
}
