//! AFK-MC² seeding (Assumption-Free K-MC², Bachem et al. 2016).
//!
//! k-means++ needs a full pass over the data per seed; AFK-MC² replaces
//! that with a Markov chain over a proposal distribution built from the
//! first (uniform) seed:  q(x) = 0.5 · d(x,c1)² / Σd² + 0.5 / n,
//! then runs an m-step Metropolis–Hastings chain per additional seed.

use crate::linalg::dist2;
use crate::util::rng::Rng;

/// Pick k seed indices from `points` with an m-step chain.
pub fn afkmc2_seeds(
    points: &[Vec<f64>],
    k: usize,
    chain_len: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let n = points.len();
    assert!(k >= 1 && n >= k, "need at least k points");
    let mut seeds = Vec::with_capacity(k);
    // First seed: uniform.
    let c1 = rng.below(n);
    seeds.push(c1);
    if k == 1 {
        return seeds;
    }
    // Proposal distribution q.
    let d1: Vec<f64> = points
        .iter()
        .map(|p| dist2(p, &points[c1]))
        .collect();
    let sum_d1: f64 = d1.iter().sum();
    let q: Vec<f64> = if sum_d1 > 0.0 {
        d1.iter()
            .map(|&d| 0.5 * d / sum_d1 + 0.5 / n as f64)
            .collect()
    } else {
        vec![1.0 / n as f64; n]
    };

    // Distance to the nearest chosen seed, updated incrementally.
    let mut dmin = d1;

    for _ in 1..k {
        // Metropolis–Hastings chain targeting p(x) ∝ dmin(x).
        let mut x = rng.weighted(&q);
        let mut dx = dmin[x];
        for _ in 1..chain_len {
            let y = rng.weighted(&q);
            let dy = dmin[y];
            let accept = if dx * q[y] <= 0.0 {
                true
            } else {
                (dy * q[x]) / (dx * q[y]) > rng.uniform()
            };
            if accept {
                x = y;
                dx = dy;
            }
        }
        seeds.push(x);
        for (i, p) in points.iter().enumerate() {
            let d = dist2(p, &points[x]);
            if d < dmin[i] {
                dmin[i] = d;
            }
        }
    }
    seeds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(cx: f64, cy: f64, n: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
        (0..n)
            .map(|_| vec![cx + 0.1 * rng.normal(), cy + 0.1 * rng.normal()])
            .collect()
    }

    #[test]
    fn seeds_are_distinct_and_in_range() {
        let mut rng = Rng::new(1);
        let mut pts = blob(0.0, 0.0, 30, &mut rng);
        pts.extend(blob(10.0, 0.0, 30, &mut rng));
        pts.extend(blob(0.0, 10.0, 30, &mut rng));
        let seeds = afkmc2_seeds(&pts, 3, 50, &mut rng);
        assert_eq!(seeds.len(), 3);
        assert!(seeds.iter().all(|&s| s < pts.len()));
    }

    #[test]
    fn seeds_cover_separated_blobs() {
        // With well-separated blobs, the 3 seeds should land in 3
        // different blobs nearly always.
        let mut hits = 0;
        for trial in 0..20 {
            let mut rng = Rng::new(100 + trial);
            let mut pts = blob(0.0, 0.0, 40, &mut rng);
            pts.extend(blob(50.0, 0.0, 40, &mut rng));
            pts.extend(blob(0.0, 50.0, 40, &mut rng));
            let seeds = afkmc2_seeds(&pts, 3, 100, &mut rng);
            let mut blobs: Vec<usize> =
                seeds.iter().map(|&s| s / 40).collect();
            blobs.sort_unstable();
            blobs.dedup();
            if blobs.len() == 3 {
                hits += 1;
            }
        }
        assert!(hits >= 17, "only {hits}/20 trials covered all blobs");
    }

    #[test]
    fn single_seed_works() {
        let mut rng = Rng::new(2);
        let pts = blob(0.0, 0.0, 5, &mut rng);
        assert_eq!(afkmc2_seeds(&pts, 1, 10, &mut rng).len(), 1);
    }

    #[test]
    fn identical_points_dont_panic() {
        let mut rng = Rng::new(3);
        let pts = vec![vec![1.0, 1.0]; 10];
        let seeds = afkmc2_seeds(&pts, 3, 20, &mut rng);
        assert_eq!(seeds.len(), 3);
    }
}
