//! The profiling module (paper §3.1 / Fig. 5 left).
//!
//! Every device runs the same short profiling task; the cloud records the
//! characteristic V_i = [T_pro, E_pro, Fl_pro, Fr_pro, Ut_pro]
//! (configuration time, energy, attainable FLOPS, governor frequency,
//! CPU utilization), z-scores the features, and clusters devices with
//! AFK-MC²-seeded balanced k-means — region-constrained, so devices only
//! join edges in their own region ("divide edges and devices into multiple
//! groups by region, then cluster devices under each group").

use crate::sim::{CpuModel, EnergyModel, Region};
use crate::util::rng::Rng;
use crate::util::stats;

use super::kmeans::balanced_kmeans;

/// One device's profiling characteristic V_i.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub t_pro: f64,
    pub e_pro: f64,
    pub fl_pro: f64,
    pub fr_pro: f64,
    pub ut_pro: f64,
}

impl DeviceProfile {
    pub fn as_vec(&self) -> Vec<f64> {
        vec![self.t_pro, self.e_pro, self.fl_pro, self.fr_pro, self.ut_pro]
    }
}

/// Run the profiling task (a fixed number of SGD batches) on one device.
pub fn profile_device(
    cpu: &mut CpuModel,
    energy: &EnergyModel,
    epochs: usize,
) -> DeviceProfile {
    let mut t_total = 0.0;
    let mut e_total = 0.0;
    for _ in 0..epochs {
        cpu.step_usage();
        let t = cpu.sgd_time();
        t_total += t;
        e_total += energy.sgd_energy(cpu, t);
    }
    DeviceProfile {
        t_pro: t_total,
        e_pro: e_total,
        fl_pro: cpu.available_gflops(),
        fr_pro: cpu.frequency_ghz(),
        ut_pro: cpu.usage,
    }
}

/// Output: device -> edge assignment plus diagnostics.
#[derive(Clone, Debug)]
pub struct ProfilingOutcome {
    /// edge id per device.
    pub assignment: Vec<usize>,
    pub profiles: Vec<DeviceProfile>,
    /// Within-cluster MSE of the (normalized) features per region.
    pub mse: f64,
}

/// Cluster `profiles` into edges, respecting regions: devices with region
/// r may only be assigned to edges with region r. `edge_regions[j]` gives
/// edge j's region; `device_regions[i]` gives device i's.
pub fn profile_devices(
    profiles: Vec<DeviceProfile>,
    device_regions: &[Region],
    edge_regions: &[Region],
    rng: &mut Rng,
) -> ProfilingOutcome {
    let n = profiles.len();
    assert_eq!(device_regions.len(), n);
    let features: Vec<Vec<f64>> =
        profiles.iter().map(|p| p.as_vec()).collect();
    let norm = zscore(&features);
    let (assignment, total_mse) =
        cluster_by_region(&norm, device_regions, edge_regions, rng);
    let mse = total_mse / n as f64;
    ProfilingOutcome {
        assignment,
        profiles,
        mse,
    }
}

/// The region-constrained balanced clustering core, shared between the
/// startup clustering above and the membership subsystem's live
/// re-clustering (`hfl::membership::plan_recluster`): per region, cluster
/// that region's points into that region's edges with AFK-MC²-seeded
/// balanced k-means. `norm` holds already-normalized feature rows and
/// `point_regions[i]` the region of row i. Returns (edge per point,
/// point-weighted mse sum).
pub(crate) fn cluster_by_region(
    norm: &[Vec<f64>],
    point_regions: &[Region],
    edge_regions: &[Region],
    rng: &mut Rng,
) -> (Vec<usize>, f64) {
    let n = norm.len();
    let mut assignment = vec![usize::MAX; n];
    let mut total_mse = 0.0;
    for &region in &[Region::Cn, Region::Us] {
        let edges: Vec<usize> = (0..edge_regions.len())
            .filter(|&j| edge_regions[j] == region)
            .collect();
        let points: Vec<usize> = (0..n)
            .filter(|&i| point_regions[i] == region)
            .collect();
        if edges.is_empty() {
            assert!(
                points.is_empty(),
                "devices in region {region:?} but no edges there"
            );
            continue;
        }
        if points.is_empty() {
            continue;
        }
        let pts: Vec<Vec<f64>> =
            points.iter().map(|&i| norm[i].clone()).collect();
        let clustering = balanced_kmeans(&pts, edges.len(), 50, rng);
        for (local, &i) in points.iter().enumerate() {
            assignment[i] = edges[clustering.assignment[local]];
        }
        total_mse += clustering.mse * points.len() as f64;
    }
    (assignment, total_mse)
}

/// Column-wise z-scoring of feature vectors (shared with the membership
/// subsystem's live re-clustering).
pub(crate) fn zscore(features: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let dims = features[0].len();
    let mut out = vec![vec![0.0; dims]; features.len()];
    for d in 0..dims {
        let col: Vec<f64> = features.iter().map(|f| f[d]).collect();
        let m = stats::mean(&col);
        let s = stats::std(&col).max(1e-9);
        for (i, f) in features.iter().enumerate() {
            out[i][d] = (f[d] - m) / s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_cpu(u: f64, seed: u64) -> CpuModel {
        CpuModel::new(u, 2.0, 1.2, 0.18, Rng::new(seed))
    }

    #[test]
    fn profile_reflects_interference() {
        let e = EnergyModel::new(2.2, 6.2);
        let mut fast = make_cpu(0.1, 1);
        let mut slow = make_cpu(0.8, 2);
        let pf = profile_device(&mut fast, &e, 20);
        let ps = profile_device(&mut slow, &e, 20);
        assert!(ps.t_pro > pf.t_pro);
        assert!(ps.e_pro > pf.e_pro);
        assert!(ps.fl_pro < pf.fl_pro);
    }

    #[test]
    fn clustering_groups_similar_devices() {
        // 2 regions x (fast + slow) devices; check that within each region
        // fast devices dominate one edge and slow the other.
        let e = EnergyModel::new(2.2, 6.2);
        let mut profiles = Vec::new();
        let mut device_regions = Vec::new();
        for i in 0..20 {
            let u = if i % 2 == 0 { 0.12 } else { 0.75 };
            let mut cpu = make_cpu(u, 100 + i as u64);
            profiles.push(profile_device(&mut cpu, &e, 30));
            device_regions
                .push(if i < 10 { Region::Cn } else { Region::Us });
        }
        let edge_regions =
            vec![Region::Cn, Region::Cn, Region::Us, Region::Us];
        let mut rng = Rng::new(7);
        let out = profile_devices(
            profiles,
            &device_regions,
            &edge_regions,
            &mut rng,
        );
        // Region constraint respected.
        for (i, &edge) in out.assignment.iter().enumerate() {
            assert_eq!(edge_regions[edge], device_regions[i], "device {i}");
        }
        // Within region cn (devices 0..10): fast devices (even idx) should
        // mostly share an edge.
        let fast_edges: Vec<usize> =
            (0..10).step_by(2).map(|i| out.assignment[i]).collect();
        let same = fast_edges
            .iter()
            .filter(|&&e| e == fast_edges[0])
            .count();
        assert!(same >= 4, "fast cn devices split: {fast_edges:?}");
    }

    #[test]
    fn balanced_sizes_per_region() {
        let e = EnergyModel::new(2.2, 6.2);
        let mut profiles = Vec::new();
        let mut device_regions = Vec::new();
        for i in 0..30 {
            let mut cpu = make_cpu(0.1 + 0.1 * (i % 5) as f64, i as u64);
            profiles.push(profile_device(&mut cpu, &e, 10));
            device_regions
                .push(if i < 18 { Region::Cn } else { Region::Us });
        }
        let edge_regions =
            vec![Region::Cn, Region::Cn, Region::Cn, Region::Us, Region::Us];
        let mut rng = Rng::new(8);
        let out = profile_devices(
            profiles,
            &device_regions,
            &edge_regions,
            &mut rng,
        );
        let mut sizes = vec![0usize; 5];
        for &e in &out.assignment {
            sizes[e] += 1;
        }
        assert_eq!(sizes[..3].iter().sum::<usize>(), 18);
        assert_eq!(sizes[3..].iter().sum::<usize>(), 12);
        assert!(sizes[..3].iter().all(|&s| s == 6), "{sizes:?}");
        assert!(sizes[3..].iter().all(|&s| s == 6), "{sizes:?}");
    }
}
