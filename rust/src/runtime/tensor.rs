//! Host-side tensors: the plain-`Send` interchange between the coordinator
//! logic, the worker pool, and PJRT literals.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        HostTensor {
            shape,
            data: TensorData::F32(data),
        }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        HostTensor {
            shape,
            data: TensorData::I32(data),
        }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::f32(vec![], vec![x])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self.data {
            TensorData::F32(_) => "float32",
            TensorData::I32(_) => "int32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// First element as f64 (for scalar outputs like loss / correct count).
    pub fn scalar(&self) -> Result<f64> {
        match &self.data {
            TensorData::F32(v) => Ok(v[0] as f64),
            TensorData::I32(v) => Ok(v[0] as f64),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        lit.reshape(&dims)
            .map_err(|e| anyhow::anyhow!("reshape to {dims:?}: {e:?}"))
    }

    pub fn from_literal(
        lit: xla::Literal,
        shape: &[usize],
        dtype: &str,
    ) -> Result<Self> {
        let data = match dtype {
            "float32" => TensorData::F32(
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("literal to f32: {e:?}"))?,
            ),
            "int32" => TensorData::I32(
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("literal to i32: {e:?}"))?,
            ),
            other => bail!("unsupported dtype {other}"),
        };
        let t = HostTensor {
            shape: shape.to_vec(),
            data,
        };
        if t.len()
            != match &t.data {
                TensorData::F32(v) => v.len(),
                TensorData::I32(v) => v.len(),
            }
        {
            bail!("literal size does not match manifest shape {shape:?}");
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t =
            HostTensor::f32(vec![2, 3], (0..6).map(|x| x as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(lit, &[2, 3], "float32").unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(vec![4], vec![1, -2, 3, -4]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(lit, &[4], "int32").unwrap();
        assert_eq!(t, back);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn scalar_access() {
        assert_eq!(HostTensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
    }
}
