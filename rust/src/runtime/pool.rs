//! Parallel device-training pool.
//!
//! The HFL engine trains 10-50 simulated devices per synchronization
//! barrier; each device's local epochs are independent, so they fan out
//! over worker threads. Every worker owns its own PJRT client and
//! `<dataset>_train_epoch` executable (compile-once at pool startup), plus
//! a shared `Arc` of the immutable device shards — jobs carry only the
//! model vector and a shuffle seed, not the training data.

use std::sync::Arc;

use anyhow::Result;

use super::tensor::HostTensor;
use super::Runtime;
use crate::data::synthetic::DeviceShard;
use crate::util::rng::Rng;
use crate::util::threadpool::Pool;

/// One device's local-training job: `epochs` sequential local epochs
/// starting from `w`, data drawn from the worker-shared shard table.
pub struct TrainJob {
    pub device: usize,
    pub w: Vec<f32>,
    pub epochs: usize,
    /// Seed for the per-epoch shard shuffles (deterministic per job).
    pub seed: u64,
}

pub struct TrainResult {
    pub device: usize,
    pub w: Vec<f32>,
    /// Mean loss per epoch.
    pub losses: Vec<f64>,
}

struct WorkerState {
    rt: Runtime,
    shards: Arc<Vec<DeviceShard>>,
    art: String,
    nb: usize,
    batch: usize,
    p: usize,
    x_shape: Vec<usize>,
    y_shape: Vec<usize>,
}

pub struct DevicePool {
    inner: Pool<TrainJob, Result<TrainResult>>,
    workers: usize,
}

impl DevicePool {
    /// `dataset` is "mnist" or "cifar"; shapes come from the manifest.
    pub fn new(
        workers: usize,
        artifacts_dir: &str,
        dataset: &str,
        shards: Arc<Vec<DeviceShard>>,
    ) -> Result<Self> {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(1, 8)
        } else {
            workers
        };
        let dir = artifacts_dir.to_string();
        let art = format!("{dataset}_train_epoch");
        // Fail fast on the main thread if the artifact can't load at all.
        Runtime::load(&dir, &[art.as_str()])?;
        let art_init = art.clone();
        let inner = Pool::new(
            workers,
            move |_idx| {
                let rt = Runtime::load(&dir, &[art_init.as_str()])
                    .expect("worker failed to load artifacts");
                let spec = rt
                    .manifest
                    .artifact(&art_init)
                    .expect("artifact vanished from manifest");
                WorkerState {
                    nb: rt.manifest.config.nb,
                    batch: rt.manifest.config.batch,
                    p: spec.inputs[0].shape[0],
                    x_shape: spec.inputs[1].shape.clone(),
                    y_shape: spec.inputs[2].shape.clone(),
                    art: art_init.clone(),
                    shards: shards.clone(),
                    rt,
                }
            },
            move |st: &mut WorkerState, job: TrainJob| run_job(st, job),
        );
        Ok(DevicePool { inner, workers })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Train all jobs in parallel; results in job order.
    pub fn train(&mut self, jobs: Vec<TrainJob>) -> Result<Vec<TrainResult>> {
        self.inner.map(jobs).into_iter().collect()
    }
}

fn run_job(st: &mut WorkerState, job: TrainJob) -> Result<TrainResult> {
    let shard = &st.shards[job.device];
    let mut rng = Rng::new(job.seed);
    let mut w = job.w;
    anyhow::ensure!(
        w.len() == st.p,
        "param size {} != artifact {}",
        w.len(),
        st.p
    );
    let mut losses = Vec::with_capacity(job.epochs);
    for _ in 0..job.epochs {
        let (x, y) = shard.epoch_tensors(st.nb, st.batch, &mut rng);
        let inputs = vec![
            HostTensor::f32(vec![st.p], w),
            HostTensor::f32(st.x_shape.clone(), x),
            HostTensor::i32(st.y_shape.clone(), y),
        ];
        let mut out = st.rt.execute(&st.art, &inputs)?;
        let loss = out[1].scalar()?;
        w = std::mem::take(&mut out[0]).into_f32()?;
        losses.push(loss);
    }
    Ok(TrainResult {
        device: job.device,
        w,
        losses,
    })
}

impl Default for HostTensor {
    fn default() -> Self {
        HostTensor::f32(vec![0], vec![])
    }
}
