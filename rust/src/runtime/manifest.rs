//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//! The rust side validates every experiment config against this at startup,
//! so a stale artifact set fails fast instead of mis-executing.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Flat-parameter layout if this artifact carries one (train_epoch).
    pub layout: Vec<(String, Vec<usize>, usize)>,
}

#[derive(Clone, Debug)]
pub struct ManifestConfig {
    pub nb: usize,
    pub batch: usize,
    pub test_size: usize,
    pub m_edges: usize,
    pub npca: usize,
    pub nmax: usize,
    pub traj_batch: usize,
    pub kernels: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ManifestConfig,
    pub param_counts: BTreeMap<String, usize>,
    pub init: BTreeMap<String, String>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .context("expected array of tensor specs")?
        .iter()
        .map(|s| {
            Ok(TensorSpec {
                shape: s
                    .get("shape")
                    .and_then(|x| x.as_arr())
                    .context("spec.shape")?
                    .iter()
                    .map(|d| d.as_usize().context("shape dim"))
                    .collect::<Result<_>>()?,
                dtype: s
                    .get("dtype")
                    .and_then(|x| x.as_str())
                    .context("spec.dtype")?
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
            format!(
                "reading {} (run `make artifacts` first)",
                path.as_ref().display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let c = j.get("config").context("manifest.config")?;
        let get = |k: &str| -> Result<usize> {
            c.get(k)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("config.{k}"))
        };
        let config = ManifestConfig {
            nb: get("nb")?,
            batch: get("batch")?,
            test_size: get("test_size")?,
            m_edges: get("m_edges")?,
            npca: get("npca")?,
            nmax: get("nmax")?,
            traj_batch: get("traj_batch")?,
            kernels: c
                .get("kernels")
                .and_then(|v| v.as_str())
                .unwrap_or("pallas")
                .to_string(),
        };
        let mut param_counts = BTreeMap::new();
        for (k, v) in j
            .get("param_counts")
            .and_then(|v| v.as_obj())
            .context("manifest.param_counts")?
        {
            param_counts.insert(k.clone(), v.as_usize().context("count")?);
        }
        let mut init = BTreeMap::new();
        if let Some(obj) = j.get("init").and_then(|v| v.as_obj()) {
            for (k, v) in obj {
                init.insert(
                    k.clone(),
                    v.as_str().context("init path")?.to_string(),
                );
            }
        }
        let mut artifacts = BTreeMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(|v| v.as_obj())
            .context("manifest.artifacts")?
        {
            let mut layout = Vec::new();
            if let Some(entries) = a.get("layout").and_then(|l| l.as_arr()) {
                for e in entries {
                    layout.push((
                        e.get("name")
                            .and_then(|x| x.as_str())
                            .context("layout.name")?
                            .to_string(),
                        e.get("shape")
                            .and_then(|x| x.as_arr())
                            .context("layout.shape")?
                            .iter()
                            .map(|d| d.as_usize().context("dim"))
                            .collect::<Result<_>>()?,
                        e.get("offset")
                            .and_then(|x| x.as_usize())
                            .context("layout.offset")?,
                    ));
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a
                        .get("file")
                        .and_then(|x| x.as_str())
                        .context("artifact.file")?
                        .to_string(),
                    inputs: tensor_specs(a.get("inputs").context("inputs")?)?,
                    outputs: tensor_specs(
                        a.get("outputs").context("outputs")?,
                    )?,
                    layout,
                },
            );
        }
        Ok(Manifest {
            config,
            param_counts,
            init,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn param_count(&self, model: &str) -> Result<usize> {
        self.param_counts
            .get(model)
            .copied()
            .with_context(|| format!("no param count for '{model}'"))
    }

    /// Validate that an experiment config is compatible with these
    /// artifacts (shapes were baked at AOT time).
    pub fn validate_config(
        &self,
        cfg: &crate::config::ExperimentConfig,
    ) -> Result<()> {
        let c = &self.config;
        anyhow::ensure!(
            cfg.topology.edges == c.m_edges,
            "config has {} edges but artifacts were built for {}",
            cfg.topology.edges,
            c.m_edges
        );
        anyhow::ensure!(
            cfg.topology.nmax == c.nmax,
            "config nmax {} != artifact nmax {}",
            cfg.topology.nmax,
            c.nmax
        );
        if cfg.agent.npca != c.npca {
            let variant = format!("ppo_actor_fwd_npca{}", cfg.agent.npca);
            anyhow::ensure!(
                self.artifacts.contains_key(&variant),
                "config npca {} != artifact default {} and no '{variant}' \
                 variant was built (see aot.py --npca-variants)",
                cfg.agent.npca,
                c.npca
            );
        }
        anyhow::ensure!(
            cfg.agent.traj_max == c.traj_batch,
            "config traj_max {} != artifact traj_batch {}",
            cfg.agent.traj_max,
            c.traj_batch
        );
        anyhow::ensure!(
            cfg.hfl.samples_per_device >= c.nb * c.batch,
            "samples_per_device {} < one epoch's nb*batch = {}",
            cfg.hfl.samples_per_device,
            c.nb * c.batch
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"nb": 4, "batch": 32, "test_size": 512, "eval_chunk": 128,
                 "m_edges": 5, "npca": 6, "nmax": 16, "traj_batch": 32,
                 "ppo_lr": 0.0003, "clip_eps": 0.2,
                 "lr": {"mnist": 0.003}, "seed": 42, "kernels": "pallas"},
      "param_counts": {"mnist": 21840, "ppo": 121589},
      "init": {"mnist": "init/mnist_params.bin"},
      "artifacts": {
        "mnist_eval": {
          "file": "mnist_eval.hlo.txt",
          "inputs": [{"shape": [21840], "dtype": "float32"},
                      {"shape": [512, 28, 28, 1], "dtype": "float32"},
                      {"shape": [512], "dtype": "int32"}],
          "outputs": [{"shape": [], "dtype": "float32"},
                       {"shape": [], "dtype": "float32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config.m_edges, 5);
        assert_eq!(m.param_count("mnist").unwrap(), 21840);
        let a = m.artifact("mnist_eval").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].shape, vec![512, 28, 28, 1]);
        assert_eq!(a.outputs[0].dtype, "float32");
    }

    #[test]
    fn validates_config_compat() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let mut cfg = crate::config::ExperimentConfig::mnist();
        cfg.hfl.samples_per_device = 128;
        m.validate_config(&cfg).unwrap();
        cfg.topology.edges = 4;
        assert!(m.validate_config(&cfg).is_err());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse(r#"{"config": {}}"#).is_err());
    }
}
