//! PJRT runtime: load the AOT HLO-text artifacts and execute them.
//!
//! `Runtime` wraps one `PjRtClient::cpu()` plus a compile-once executable
//! cache; `DevicePool` fans device training across worker threads, each
//! owning its *own* client + executables (the xla crate's handles are not
//! `Send`). Tensors cross threads as plain `HostTensor` buffers.

pub mod manifest;
pub mod pool;
pub mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use manifest::Manifest;
pub use pool::DevicePool;
pub use tensor::HostTensor;

pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Create a runtime over `dir` (must contain manifest.json) and
    /// pre-compile the named artifacts.
    pub fn load(dir: impl AsRef<Path>, names: &[&str]) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        let mut rt = Runtime {
            client,
            exes: HashMap::new(),
            manifest,
            dir,
        };
        for name in names {
            rt.compile(name)?;
        }
        Ok(rt)
    }

    /// Compile (and cache) one artifact by manifest name.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let art = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with host tensors; validates shapes against the
    /// manifest and returns the flattened output tuple.
    pub fn execute(
        &self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let art = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        if inputs.len() != art.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                art.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&art.inputs).enumerate() {
            if t.shape != spec.shape {
                bail!(
                    "{name} input {i}: shape {:?} != manifest {:?}",
                    t.shape,
                    spec.shape
                );
            }
            if t.dtype_name() != spec.dtype {
                bail!(
                    "{name} input {i}: dtype {} != manifest {}",
                    t.dtype_name(),
                    spec.dtype
                );
            }
        }
        let exe = self
            .exes
            .get(name)
            .with_context(|| format!("artifact '{name}' not compiled"))?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} output: {e:?}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name}: {e:?}"))?;
        let mut tensors = Vec::with_capacity(parts.len());
        for (i, lit) in parts.into_iter().enumerate() {
            let spec = art.outputs.get(i).with_context(|| {
                format!("{name}: more outputs than manifest lists")
            })?;
            tensors.push(HostTensor::from_literal(
                lit,
                &spec.shape,
                &spec.dtype,
            )?);
        }
        Ok(tensors)
    }

    /// Read an initial-parameter binary (little-endian f32) from init/.
    pub fn load_init_params(&self, model: &str) -> Result<Vec<f32>> {
        let rel = self
            .manifest
            .init
            .get(model)
            .with_context(|| format!("no init params for '{model}'"))?;
        let bytes = std::fs::read(self.dir.join(rel))?;
        if bytes.len() % 4 != 0 {
            bail!("init params for {model}: size not a multiple of 4");
        }
        let expect = self.manifest.param_count(model)?;
        let n = bytes.len() / 4;
        if n != expect {
            bail!(
                "init params for {model}: {n} floats, manifest says {expect}"
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
