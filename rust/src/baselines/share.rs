//! Share (Deng et al., ICDCS'21): shape the data distribution *at the
//! edge* by re-assigning devices to edges so each cluster's aggregate
//! label distribution approaches the global one (low inter-edge drift),
//! subject to the region constraint and balanced cluster sizes; then run
//! fixed-frequency HFL on the reshaped topology.
//!
//! Implemented as greedy same-region swap descent on the summed KL
//! divergence between per-edge label distributions and the global one.

use anyhow::Result;

use crate::hfl::{HflEngine, RunHistory};

/// KL(p || q) with add-one smoothing over counts.
fn kl(p_counts: &[usize], q_counts: &[usize]) -> f64 {
    let ps: f64 = p_counts.iter().map(|&c| c as f64 + 1.0).sum();
    let qs: f64 = q_counts.iter().map(|&c| c as f64 + 1.0).sum();
    p_counts
        .iter()
        .zip(q_counts)
        .map(|(&pc, &qc)| {
            let p = (pc as f64 + 1.0) / ps;
            let q = (qc as f64 + 1.0) / qs;
            p * (p / q).ln()
        })
        .sum()
}

/// Total divergence of an assignment.
fn objective(
    device_hists: &[Vec<usize>],
    global: &[usize],
    assignment: &[usize],
    m: usize,
    classes: usize,
) -> f64 {
    let mut edge_hists = vec![vec![0usize; classes]; m];
    for (dev, &e) in assignment.iter().enumerate() {
        for c in 0..classes {
            edge_hists[e][c] += device_hists[dev][c];
        }
    }
    edge_hists.iter().map(|h| kl(h, global)).sum()
}

/// Compute the Share re-assignment (returns device -> edge).
pub fn share_assignment(engine: &HflEngine) -> Vec<usize> {
    let classes = engine.topo.dataset.classes;
    let n = engine.cfg.topology.devices;
    let m = engine.edges();
    let device_hists: Vec<Vec<usize>> = (0..n)
        .map(|d| engine.topo.shards[d].class_histogram(classes))
        .collect();
    let mut global = vec![0usize; classes];
    for h in &device_hists {
        for c in 0..classes {
            global[c] += h[c];
        }
    }
    let mut assignment: Vec<usize> =
        (0..n).map(|d| engine.topo.edge_of(d)).collect();
    let regions: Vec<_> = (0..m)
        .map(|j| engine.topo.edges[j].region)
        .collect();
    let dev_region = |d: usize, a: &[usize]| regions[a[d]];
    let mut best = objective(&device_hists, &global, &assignment, m, classes);
    // Greedy swap descent (same-region pairs keep sizes balanced and the
    // communication structure intact).
    let mut improved = true;
    let mut iters = 0;
    while improved && iters < 20 {
        improved = false;
        iters += 1;
        for a in 0..n {
            for b in (a + 1)..n {
                if assignment[a] == assignment[b] {
                    continue;
                }
                if dev_region(a, &assignment) != dev_region(b, &assignment) {
                    continue;
                }
                assignment.swap(a, b);
                let obj =
                    objective(&device_hists, &global, &assignment, m, classes);
                if obj + 1e-12 < best {
                    best = obj;
                    improved = true;
                } else {
                    assignment.swap(a, b);
                }
            }
        }
    }
    assignment
}

/// Run Share: reshape the topology, then fixed-frequency HFL.
pub fn share(engine: &mut HflEngine) -> Result<RunHistory> {
    let assignment = share_assignment(engine);
    engine.topo.set_assignment(&assignment);
    super::vanilla_hfl(engine)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kl_zero_for_identical() {
        let p = vec![10, 20, 30];
        assert!(kl(&p, &p).abs() < 1e-12);
    }

    #[test]
    fn kl_positive_for_different() {
        assert!(kl(&[100, 0, 0], &[33, 33, 34]) > 0.1);
    }

    #[test]
    fn objective_prefers_mixed_edges() {
        // 4 devices, 2 classes, 2 edges: pairing unlike devices beats
        // pairing like devices.
        let hists = vec![
            vec![10, 0],
            vec![0, 10],
            vec![10, 0],
            vec![0, 10],
        ];
        let global = vec![20, 20];
        let mixed = vec![0, 0, 1, 1]; // edge0 = {A,B}, edge1 = {A,B}
        let skewed = vec![0, 1, 0, 1]; // edge0 = {A,A}, edge1 = {B,B}
        let om = objective(&hists, &global, &mixed, 2, 2);
        let os = objective(&hists, &global, &skewed, 2, 2);
        assert!(om < os, "mixed {om} skewed {os}");
    }
}
