//! Favor (Wang et al., INFOCOM'20): FedAvg + DQN device selection.
//!
//! The original observes PCA-compressed *device models* to pick which
//! devices join each round. Our cloud does not retain per-device models
//! after aggregation (privacy-preserving state, §3.2), so the Q-network
//! observes the per-device telemetry the cloud does hold — last training
//! loss, profiled speed/energy, selection recency — plus global accuracy.
//! This keeps Favor's structure (per-device Q values, ε-greedy top-K,
//! accuracy-gain reward, target-network DQN) on available signals; see
//! DESIGN.md §3 for the substitution note.

use anyhow::Result;

use crate::hfl::{HflEngine, RunHistory};
use crate::nn::Mlp;
use crate::util::rng::Rng;

const FEATURES: usize = 6;

pub struct FavorOptions {
    /// Fraction of devices selected per round.
    pub frac: f64,
    pub eps_start: f64,
    pub eps_end: f64,
    pub lr: f32,
    /// Target-network sync period (rounds).
    pub target_sync: usize,
}

impl Default for FavorOptions {
    fn default() -> Self {
        FavorOptions {
            frac: 0.6,
            eps_start: 0.5,
            eps_end: 0.05,
            lr: 0.01,
            target_sync: 5,
        }
    }
}

struct DeviceFeat {
    last_loss: f64,
    speed: f64,
    energy_rate: f64,
    rounds_since_selected: f64,
}

fn features(f: &DeviceFeat, acc: f64) -> Vec<f32> {
    vec![
        f.last_loss as f32,
        f.speed as f32,
        f.energy_rate as f32,
        (f.rounds_since_selected / 10.0) as f32,
        acc as f32,
        1.0,
    ]
}

pub fn favor(
    engine: &mut HflEngine,
    opts: &FavorOptions,
) -> Result<RunHistory> {
    let n = engine.cfg.topology.devices;
    let m = engine.edges();
    let gamma1 = engine.cfg.hfl.gamma1 * engine.cfg.hfl.gamma2;
    let g1 = vec![gamma1; m];
    let g2 = vec![1usize; m]; // FL mode: cloud sync every edge aggregation
    let mut rng = Rng::new(engine.cfg.seed ^ 0xfa40);
    let mut qnet = Mlp::new(&[FEATURES, 32, 16, 1], &mut rng);
    let mut target = qnet.clone();
    let k_sel = ((n as f64 * opts.frac).round() as usize).clamp(1, n);

    let mut feats: Vec<DeviceFeat> = (0..n)
        .map(|i| {
            let c = &engine.topo.cpus[i];
            DeviceFeat {
                last_loss: 2.3,
                speed: c.base_time * c.slowdown(),
                energy_rate: c.slowdown(),
                rounds_since_selected: 0.0,
            }
        })
        .collect();

    engine.reset();
    let mut hist = RunHistory::default();
    let mut prev_acc = 0.1;
    let mut round = 0usize;
    while engine.remaining_time() > 0.0 {
        let eps = opts.eps_start
            + (opts.eps_end - opts.eps_start)
                * (round as f64 / 20.0).min(1.0);
        // Q-scores per device; ε-greedy top-K selection.
        let mut scored: Vec<(f64, usize)> = (0..n)
            .map(|i| {
                let q = qnet.forward(&features(&feats[i], prev_acc))[0] as f64;
                let noise = if rng.uniform() < eps {
                    rng.normal() * 2.0
                } else {
                    0.0
                };
                (q + noise, i)
            })
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut mask = vec![false; n];
        for &(_, i) in scored.iter().take(k_sel) {
            mask[i] = true;
        }
        let stats = engine.run_round(&g1, &g2, Some(&mask))?;
        // DQN update: reward = accuracy gain shared by selected devices.
        let r = stats.accuracy - prev_acc;
        let max_next = scored
            .iter()
            .take(k_sel)
            .map(|&(_, i)| {
                target.forward(&features(&feats[i], stats.accuracy))[0] as f64
            })
            .fold(f64::NEG_INFINITY, f64::max);
        let target_q = (r * 10.0 + 0.9 * max_next) as f32;
        for &(_, i) in scored.iter().take(k_sel) {
            let x = features(&feats[i], prev_acc);
            qnet.train_step(&x, &[target_q], &[1.0], opts.lr);
        }
        // Telemetry updates.
        for (dev, loss) in &stats.device_losses {
            feats[*dev].last_loss = *loss;
        }
        for (i, f) in feats.iter_mut().enumerate() {
            if mask[i] {
                f.rounds_since_selected = 0.0;
            } else {
                f.rounds_since_selected += 1.0;
            }
        }
        prev_acc = stats.accuracy;
        hist.push(stats);
        round += 1;
        if round % opts.target_sync == 0 {
            target.copy_from(&qnet);
        }
    }
    Ok(hist)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_vector_shape() {
        let f = DeviceFeat {
            last_loss: 1.0,
            speed: 2.0,
            energy_rate: 1.5,
            rounds_since_selected: 3.0,
        };
        assert_eq!(features(&f, 0.5).len(), FEATURES);
    }

    #[test]
    fn default_options_sane() {
        let o = FavorOptions::default();
        assert!(o.frac > 0.0 && o.frac <= 1.0);
        assert!(o.eps_start >= o.eps_end);
    }
}
