//! Async-Greedy: a baseline exercising the fully asynchronous engine.
//!
//! Device-side policy in the spirit of the async-FL scheduling literature
//! (arXiv:2107.11415): since nobody waits for stragglers, fast clusters
//! should simply do more local work per report. Per-edge local epochs are
//! scaled greedily by the inverse of the edge's expected unit time (same
//! time model as Var-Freq A, §2.2), then the run executes under
//! `SyncMode::Async` — per-report staleness-discounted edge aggregation
//! with a cloud timer — instead of barriered rounds.

use anyhow::Result;

use crate::hfl::{AsyncHflEngine, HflEngine, RunHistory};

/// Greedy per-edge local-epoch counts: slower clusters train less per
/// report (their updates would arrive stale anyway), faster ones more.
pub fn async_greedy_frequencies(engine: &HflEngine) -> Vec<usize> {
    let cfg = &engine.cfg.hfl;
    let units: Vec<f64> = (0..engine.edges())
        .map(|j| engine.predict_edge_time(j, 1, 1))
        .collect();
    let slowest = units.iter().copied().fold(0.0, f64::max);
    units
        .iter()
        .map(|&u| {
            let scale = (slowest / u).clamp(1.0, 4.0);
            ((cfg.gamma1 as f64 * scale).round() as usize)
                .clamp(1, cfg.gamma1_max)
        })
        .collect()
}

/// Run the greedy frequencies under the engine's configured (async) mode
/// to the time threshold.
pub fn async_greedy(engine: &mut AsyncHflEngine) -> Result<RunHistory> {
    let g1 = async_greedy_frequencies(&engine.eng);
    engine.run_with(&g1)
}
