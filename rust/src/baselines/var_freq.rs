//! Var-Freq A/B (paper §2.2): the hand-tuned per-edge frequency schemes
//! that motivate Arena.
//!
//! A: equalize per-round times — edges that finish early (fast clusters)
//!    get proportionally more local work until every cluster's expected
//!    round time is close to the straggler's (the paper's "until all
//!    clusters have similar training times in each cloud round").
//! B: start from A, then pull back the frequencies of the most
//!    energy-hungry clusters ("appropriately reduce the aggregation
//!    frequency of fast devices with high energy consumption").

use anyhow::Result;

use crate::hfl::{HflEngine, RunHistory};

/// Per-edge expected seconds of one (γ1=1, γ2=1) unit of work.
fn unit_times(engine: &HflEngine) -> Vec<f64> {
    (0..engine.edges())
        .map(|j| engine.predict_edge_time(j, 1, 1))
        .collect()
}

/// Compute Var-Freq A's per-edge frequencies.
pub fn var_freq_a_frequencies(
    engine: &HflEngine,
) -> (Vec<usize>, Vec<usize>) {
    let cfg = &engine.cfg.hfl;
    let units = unit_times(engine);
    let slowest = units.iter().copied().fold(0.0, f64::max);
    let mut g1 = Vec::new();
    let mut g2 = Vec::new();
    for &u in &units {
        // Scale default work by slowest/u so expected times equalize.
        let scale = (slowest / u).clamp(1.0, 3.0);
        let work = (cfg.gamma1 as f64 * scale).round() as usize;
        g1.push(work.clamp(1, cfg.gamma1_max));
        g2.push(cfg.gamma2.clamp(1, cfg.gamma2_max));
    }
    (g1, g2)
}

/// Var-Freq B: A's frequencies with the highest-energy edges damped.
pub fn var_freq_b_frequencies(
    engine: &HflEngine,
) -> (Vec<usize>, Vec<usize>) {
    let (mut g1, g2) = var_freq_a_frequencies(engine);
    // Energy proxy: slowest-member slowdown x frequency.
    let units = unit_times(engine);
    let mean_u = crate::util::stats::mean(&units);
    for (j, &u) in units.iter().enumerate() {
        if u > mean_u {
            // Slow (expensive) cluster: halve the extra work A gave it.
            let base = engine.cfg.hfl.gamma1;
            g1[j] = ((g1[j] + base) / 2).max(1);
        }
    }
    (g1, g2)
}

pub fn var_freq_a(engine: &mut HflEngine) -> Result<RunHistory> {
    let (g1, g2) = var_freq_a_frequencies(engine);
    run_with(engine, &g1, &g2)
}

pub fn var_freq_b(engine: &mut HflEngine) -> Result<RunHistory> {
    let (g1, g2) = var_freq_b_frequencies(engine);
    run_with(engine, &g1, &g2)
}

fn run_with(
    engine: &mut HflEngine,
    g1: &[usize],
    g2: &[usize],
) -> Result<RunHistory> {
    engine.reset();
    let mut hist = RunHistory::default();
    while engine.remaining_time() > 0.0 {
        hist.push(engine.run_round(g1, g2, None)?);
    }
    Ok(hist)
}

#[cfg(test)]
mod tests {
    // Frequency-shape tests that don't need a live engine are covered via
    // the integration tests (rust/tests/) since unit_times needs artifacts.
    #[test]
    fn clamp_logic_is_sane() {
        // scale clamps to [1, 3]: a 10x-slow edge cannot explode gamma1.
        let scale: f64 = (10.0f64).clamp(1.0, 3.0);
        assert_eq!(scale, 3.0);
    }
}
