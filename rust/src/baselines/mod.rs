//! The paper's comparison schemes (§4.1):
//!  * Vanilla-FL  [1]  — FedAvg: devices↔cloud directly (γ2 ≡ 1), random
//!    device participation per round.
//!  * Vanilla-HFL [8]  — fixed γ1/γ2 everywhere.
//!  * Var-Freq A/B     — the §2.2 motivation schemes: per-edge frequencies
//!    equalizing round times (A), then energy-tuned (B).
//!  * Favor       [5]  — DQN-based device selection (FedAvg + RL).
//!  * Share       [9]  — data-distribution-aware device→edge re-assignment.
//!  * Hwamei      [15] — Arena minus the §3.6 enhancements (see agent/).
//!
//! Beyond the paper, two event-driven schemes exercise the asynchronous
//! engine (`hfl::async_engine`):
//!  * Semi-Sync        — K-quorum edge aggregation, cloud on a timer.
//!  * Async-Greedy     — staleness-discounted async mode with greedy
//!    per-edge local-epoch scaling (see async_greedy.rs).

pub mod async_greedy;
pub mod favor;
pub mod share;
pub mod var_freq;

use anyhow::Result;

use crate::hfl::{HflEngine, RunHistory};

/// Run a fixed-frequency scheme to the time threshold.
pub fn run_fixed(
    engine: &mut HflEngine,
    gamma1: usize,
    gamma2: usize,
    participation_frac: f64,
) -> Result<RunHistory> {
    let m = engine.edges();
    let g1 = vec![gamma1; m];
    let g2 = vec![gamma2; m];
    engine.reset();
    let mut hist = RunHistory::default();
    let mut rng = crate::util::rng::Rng::new(engine.cfg.seed ^ 0xf1de);
    let n = engine.cfg.topology.devices;
    while engine.remaining_time() > 0.0 {
        let mask = participation_mask(n, participation_frac, &mut rng);
        let stats = engine.run_round(&g1, &g2, mask.as_deref())?;
        hist.push(stats);
    }
    Ok(hist)
}

/// Vanilla-FL: flat FedAvg (γ2 = 1 turns every edge into a relay; with the
/// paper's setting γ1·γ2 matched to Vanilla-HFL) with fractional random
/// device selection.
pub fn vanilla_fl(engine: &mut HflEngine, frac: f64) -> Result<RunHistory> {
    let g = engine.cfg.hfl.gamma1 * engine.cfg.hfl.gamma2;
    run_fixed(engine, g, 1, frac)
}

/// Vanilla-HFL: the configured fixed frequencies, full participation.
pub fn vanilla_hfl(engine: &mut HflEngine) -> Result<RunHistory> {
    let (g1, g2) = (engine.cfg.hfl.gamma1, engine.cfg.hfl.gamma2);
    run_fixed(engine, g1, g2, 1.0)
}

pub(crate) fn participation_mask(
    n: usize,
    frac: f64,
    rng: &mut crate::util::rng::Rng,
) -> Option<Vec<bool>> {
    if frac >= 1.0 {
        return None;
    }
    let k = ((n as f64 * frac).round() as usize).clamp(1, n);
    let chosen = rng.sample_indices(n, k);
    let mut mask = vec![false; n];
    for c in chosen {
        mask[c] = true;
    }
    Some(mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn participation_mask_counts() {
        let mut rng = Rng::new(1);
        let mask = participation_mask(50, 0.6, &mut rng).unwrap();
        assert_eq!(mask.iter().filter(|&&b| b).count(), 30);
        assert!(participation_mask(50, 1.0, &mut rng).is_none());
        let one = participation_mask(50, 0.001, &mut rng).unwrap();
        assert_eq!(one.iter().filter(|&&b| b).count(), 1);
    }
}
