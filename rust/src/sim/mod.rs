//! Testbed simulation substrates (paper §2.3 / §4.1 hardware, modeled).
//!
//! The paper measured Raspberry-Pi training under stress-ng interference
//! (Fig. 3) and Beijing/US edge-to-cloud links to an Alibaba cloud in
//! Silicon Valley (Fig. 4). These modules reproduce those measured shapes
//! as calibrated stochastic models driving a simulated clock; the *learning*
//! itself stays real (actual SGD through the AOT artifacts).

pub mod availability;
pub mod clock;
pub mod cpu;
pub mod energy;
pub mod event;
pub mod link;
pub mod mobility;
pub mod network;
pub mod shard;

pub use availability::AvailabilityModel;
pub use clock::SimClock;
pub use cpu::CpuModel;
pub use energy::EnergyModel;
pub use event::{CALENDAR_THRESHOLD, Event, EventQueue, QueueBackend};
pub use link::{Direction, LinkManager, Transfer};
pub use mobility::{FlipStats, MobilityModel};
pub use network::{NetworkModel, Region};
pub use shard::{MergedStats, ShardSpec, ShardedDeviceSim, WindowRow};
