//! Per-edge uplink/downlink transfer scheduling — the first-class
//! communication layer behind `Event::TransferDone`.
//!
//! Every edge owns two directed links to the cloud (`Direction::Up`,
//! `Direction::Down`). A transfer is admitted with a *work* budget measured
//! in exclusive-link seconds (latency + bytes/bandwidth, jitter already
//! applied by the caller so all RNG stays on the engine's streams), and the
//! manager tracks how that work drains over simulated time.
//!
//! # Contention model
//!
//! Links are processor-sharing queues: with contention enabled, `k`
//! concurrent transfers on one link each drain at rate `1/k` (fair share),
//! so a transfer's completion time depends on everything that overlaps it.
//! The latency floor is folded into the work budget, i.e. it is shared
//! too — a deliberate simplification that keeps the model a single number
//! per transfer. With contention disabled every transfer drains at rate 1
//! regardless of load (infinite-capacity link, the pre-transfer-layer
//! lump behavior spread over time).
//!
//! # Event protocol
//!
//! The manager never touches the event queue; it only *predicts* finish
//! times. Whenever link membership changes (a transfer starts or
//! completes), [`LinkManager::start`]/[`LinkManager::poll`] return the
//! recomputed `(transfer id, finish time)` pairs for every transfer still
//! on that link, and the caller schedules a `TransferDone` for each. A
//! popped `TransferDone` is *live* only if its timestamp is bit-identical
//! to the transfer's currently predicted finish (`poll` returns `None`
//! otherwise): earlier predictions that were invalidated by later
//! arrivals/departures pop as stale events and are dropped. Because every
//! recomputation schedules a fresh event at the new prediction, exactly
//! one event per transfer eventually matches.
//!
//! Everything is a pure function of the call sequence — no RNG, no global
//! state — so two runs issuing the same calls observe bit-identical
//! transfer timelines. That is what makes the asynchronous engines'
//! overlapped-communication runs reproducible from the experiment seed.
//!
//! # Sharding
//!
//! Links are strictly per-edge (an edge's uplink contends only with
//! itself), so the manager partitions cleanly: the sharded engine loop
//! (`hfl::engine_shard`) gives every shard its own `LinkManager` over
//! just that shard's edges, with shard-local transfer ids. Because the
//! timeline is a pure function of the per-link call sequence and no
//! call ever crosses an edge boundary, the per-shard managers replay
//! the serial manager's predictions bit-for-bit at any worker count.

use std::collections::HashMap;

/// Transfer direction relative to the edge: `Up` = edge→cloud upload,
/// `Down` = cloud→edge broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Direction {
    Up,
    Down,
}

impl Direction {
    pub fn name(self) -> &'static str {
        match self {
            Direction::Up => "up",
            Direction::Down => "down",
        }
    }
}

/// Handle for a completed transfer, returned by [`LinkManager::poll`].
#[derive(Clone, Debug, PartialEq)]
pub struct Transfer {
    /// Monotonically increasing id (never reused within a run).
    pub id: usize,
    pub edge: usize,
    pub dir: Direction,
    /// Payload size on the wire.
    pub bytes: usize,
    /// Simulated time the transfer was admitted.
    pub start: f64,
    /// Simulated time it landed (`finish - start` ≥ the uncontended work).
    pub finish: f64,
}

#[derive(Clone, Debug)]
struct InFlight {
    edge: usize,
    dir: Direction,
    bytes: usize,
    start: f64,
    /// Exclusive-link seconds of work left; drains at the fair-share rate.
    remaining: f64,
    /// Currently predicted completion time. The `TransferDone` event whose
    /// timestamp equals this value bit-for-bit is the live one.
    finish: f64,
}

#[derive(Clone, Debug, Default)]
struct LinkState {
    /// In-flight transfer ids in admission order (deterministic).
    active: Vec<usize>,
    /// Simulated time the link's work accounting was last advanced to.
    last_t: f64,
}

/// All per-edge links of one topology plus their in-flight transfers.
#[derive(Clone, Debug)]
pub struct LinkManager {
    edges: usize,
    contention: bool,
    /// `2 * edges` directed links, indexed `edge * 2 + dir`.
    links: Vec<LinkState>,
    flights: HashMap<usize, InFlight>,
    next_id: usize,
}

impl LinkManager {
    pub fn new(edges: usize, contention: bool) -> Self {
        LinkManager {
            edges,
            contention,
            links: vec![LinkState::default(); 2 * edges],
            flights: HashMap::new(),
            next_id: 0,
        }
    }

    fn link_idx(&self, edge: usize, dir: Direction) -> usize {
        debug_assert!(edge < self.edges, "edge {edge} out of range");
        edge * 2
            + match dir {
                Direction::Up => 0,
                Direction::Down => 1,
            }
    }

    /// Transfers currently in flight on `edge`'s `dir` link.
    pub fn active_count(&self, edge: usize, dir: Direction) -> usize {
        self.links[self.link_idx(edge, dir)].active.len()
    }

    /// All in-flight transfers, every link.
    pub fn in_flight_total(&self) -> usize {
        self.flights.len()
    }

    pub fn contention(&self) -> bool {
        self.contention
    }

    /// Drop all in-flight transfers and rewind every link clock (fresh
    /// run). Ids restart from 0 so two reset managers replay identically.
    pub fn reset(&mut self) {
        self.flights.clear();
        for l in &mut self.links {
            l.active.clear();
            l.last_t = 0.0;
        }
        self.next_id = 0;
    }

    /// Rewind the link clocks for a barrier round that accounts in
    /// round-relative time. Requires the previous round to have drained
    /// every transfer it started (the barrier guarantees it).
    pub fn begin_round(&mut self) {
        debug_assert!(
            self.flights.is_empty(),
            "begin_round with {} transfers in flight",
            self.flights.len()
        );
        for l in &mut self.links {
            l.last_t = 0.0;
        }
    }

    /// Drain in-flight work on link `li` up to time `t`.
    fn advance(&mut self, li: usize, t: f64) {
        let dt = t - self.links[li].last_t;
        if dt <= 0.0 {
            debug_assert!(
                dt >= -1e-9,
                "link time moved backwards: {t} < {}",
                self.links[li].last_t
            );
            return;
        }
        let k = self.links[li].active.len();
        if k > 0 {
            let rate = if self.contention { 1.0 / k as f64 } else { 1.0 };
            for i in 0..k {
                let id = self.links[li].active[i];
                let f = self.flights.get_mut(&id).expect("active transfer");
                // Clamp: simultaneous completions can leave a hair of
                // negative residue; finishes must never precede `t`.
                f.remaining = (f.remaining - dt * rate).max(0.0);
            }
        }
        self.links[li].last_t = t;
    }

    /// Recompute predicted finishes for everything on link `li` as of `t`;
    /// returns `(id, finish)` for the caller to (re)schedule.
    fn refinish(&mut self, li: usize, t: f64) -> Vec<(usize, f64)> {
        let k = self.links[li].active.len();
        let stretch = if self.contention && k > 0 { k as f64 } else { 1.0 };
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let id = self.links[li].active[i];
            let f = self.flights.get_mut(&id).expect("active transfer");
            f.finish = t + f.remaining * stretch;
            out.push((id, f.finish));
        }
        out
    }

    /// Admit a transfer of `bytes` needing `work` exclusive-link seconds
    /// on `edge`'s `dir` link at time `now`. Returns the new transfer's id
    /// plus the recomputed `(id, finish)` predictions for every transfer
    /// on the link (the new one included) — schedule a `TransferDone` for
    /// each.
    pub fn start(
        &mut self,
        edge: usize,
        dir: Direction,
        bytes: usize,
        work: f64,
        now: f64,
    ) -> (usize, Vec<(usize, f64)>) {
        assert!(
            work.is_finite() && work >= 0.0,
            "transfer work must be finite and non-negative ({work})"
        );
        let li = self.link_idx(edge, dir);
        self.advance(li, now);
        let id = self.next_id;
        self.next_id += 1;
        self.flights.insert(
            id,
            InFlight {
                edge,
                dir,
                bytes,
                start: now,
                remaining: work,
                finish: now + work,
            },
        );
        self.links[li].active.push(id);
        let resched = self.refinish(li, now);
        (id, resched)
    }

    /// Handle a popped `TransferDone { transfer: id }` at time `t`.
    /// Returns the completed [`Transfer`] plus finish predictions for the
    /// transfers that remain on the link (they speed up when a sharer
    /// leaves) — or `None` when the event is stale (the prediction it was
    /// scheduled against has since been superseded, or the transfer
    /// already completed via an equal-time duplicate).
    pub fn poll(
        &mut self,
        id: usize,
        t: f64,
    ) -> Option<(Transfer, Vec<(usize, f64)>)> {
        let f = self.flights.get(&id)?;
        // Bit-exact match: predictions are scheduled verbatim, so the live
        // event reproduces the stored f64 exactly; any difference means a
        // newer prediction owns this transfer.
        #[allow(clippy::float_cmp)]
        if f.finish != t {
            return None;
        }
        let li = self.link_idx(f.edge, f.dir);
        self.advance(li, t);
        let f = self.flights.remove(&id).expect("present above");
        self.links[li].active.retain(|&x| x != id);
        let resched = self.refinish(li, t);
        Some((
            Transfer {
                id,
                edge: f.edge,
                dir: f.dir,
                bytes: f.bytes,
                start: f.start,
                finish: t,
            },
            resched,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Event, EventQueue};

    /// Drive a schedule of (start_time, edge, dir, work) through a manager
    /// and an event queue exactly the way the engines do; returns the
    /// completed transfers in landing order.
    fn drive(
        contention: bool,
        seed: u64,
        plan: &[(f64, usize, Direction, f64)],
    ) -> Vec<Transfer> {
        let mut links = LinkManager::new(4, contention);
        let mut q = EventQueue::new(seed);
        let mut plan: Vec<_> = plan.to_vec();
        plan.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut done = Vec::new();
        let mut next = 0usize;
        loop {
            // Admit every transfer that starts before the next event
            // (re-peek after each admission: a new transfer can finish
            // before the next planned start).
            while next < plan.len() {
                let t_ev = q.peek_time();
                if !t_ev.map(|t| plan[next].0 <= t).unwrap_or(true) {
                    break;
                }
                let (t0, edge, dir, work) = plan[next];
                next += 1;
                let (_, resched) = links.start(edge, dir, 1000, work, t0);
                for (id, finish) in resched {
                    q.schedule(finish, Event::TransferDone { transfer: id });
                }
            }
            match q.pop() {
                None => break,
                Some((t, Event::TransferDone { transfer })) => {
                    if let Some((tr, resched)) = links.poll(transfer, t) {
                        done.push(tr);
                        for (id, finish) in resched {
                            q.schedule(
                                finish,
                                Event::TransferDone { transfer: id },
                            );
                        }
                    }
                }
                Some(_) => unreachable!("only transfer events scheduled"),
            }
        }
        assert_eq!(links.in_flight_total(), 0, "transfers left in flight");
        done
    }

    #[test]
    fn uncontended_transfer_lands_after_its_work() {
        let done = drive(true, 1, &[(2.0, 0, Direction::Up, 10.0)]);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].start, 2.0);
        assert_eq!(done[0].finish, 12.0);
    }

    #[test]
    fn fair_share_stretches_overlapping_transfers() {
        // A: work 10 from t=0. B: work 10 from t=5. Processor sharing:
        // A has 5 left at t=5, drains at 1/2 -> lands at 15; B then has
        // 5 left alone -> lands at 20.
        let done = drive(
            true,
            1,
            &[(0.0, 0, Direction::Up, 10.0), (5.0, 0, Direction::Up, 10.0)],
        );
        assert_eq!(done.len(), 2);
        assert!((done[0].finish - 15.0).abs() < 1e-9, "{:?}", done);
        assert!((done[1].finish - 20.0).abs() < 1e-9, "{:?}", done);
    }

    #[test]
    fn contention_off_restores_independent_timing() {
        let done = drive(
            false,
            1,
            &[(0.0, 0, Direction::Up, 10.0), (5.0, 0, Direction::Up, 10.0)],
        );
        assert!((done[0].finish - 10.0).abs() < 1e-9);
        assert!((done[1].finish - 15.0).abs() < 1e-9);
    }

    #[test]
    fn distinct_links_never_contend() {
        // Same timings as the fair-share test, but split across the up
        // and down links / different edges: no stretching.
        let done = drive(
            true,
            1,
            &[
                (0.0, 0, Direction::Up, 10.0),
                (5.0, 0, Direction::Down, 10.0),
                (5.0, 1, Direction::Up, 10.0),
            ],
        );
        for tr in &done {
            assert!(
                (tr.finish - tr.start - 10.0).abs() < 1e-9,
                "stretched across links: {tr:?}"
            );
        }
    }

    #[test]
    fn stale_predictions_are_dropped_not_double_completed() {
        // Three staggered transfers on one link produce a pile of
        // superseded predictions; each transfer must land exactly once.
        let done = drive(
            true,
            3,
            &[
                (0.0, 2, Direction::Up, 4.0),
                (1.0, 2, Direction::Up, 4.0),
                (2.0, 2, Direction::Up, 4.0),
            ],
        );
        assert_eq!(done.len(), 3);
        let mut ids: Vec<usize> = done.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 3, "a transfer completed twice");
        // Landing order is time-sorted.
        for w in done.windows(2) {
            assert!(w[0].finish <= w[1].finish);
        }
    }

    #[test]
    fn transfer_timeline_is_deterministic() {
        let plan: Vec<(f64, usize, Direction, f64)> = (0..40)
            .map(|i| {
                (
                    (i % 7) as f64 * 1.5,
                    i % 3,
                    if i % 2 == 0 { Direction::Up } else { Direction::Down },
                    2.0 + (i % 5) as f64,
                )
            })
            .collect();
        let a = drive(true, 9, &plan);
        let b = drive(true, 9, &plan);
        assert_eq!(a, b, "same calls, same seed -> identical timeline");
        assert_eq!(a.len(), 40);
    }

    #[test]
    fn conservation_under_contention() {
        // Fair share serializes: total landing span on one link can never
        // beat the serial sum of work, and every transfer takes at least
        // its own work.
        let plan: Vec<(f64, usize, Direction, f64)> =
            (0..10).map(|i| (i as f64 * 0.5, 0, Direction::Up, 3.0)).collect();
        let done = drive(true, 4, &plan);
        let total_work: f64 = 10.0 * 3.0;
        let makespan = done.last().unwrap().finish;
        assert!(
            makespan >= total_work - 1e-6,
            "one link finished {total_work}s of work in {makespan}s"
        );
        for tr in &done {
            assert!(tr.finish - tr.start >= 3.0 - 1e-9, "{tr:?}");
        }
    }

    #[test]
    fn reset_restores_a_fresh_manager() {
        let mut links = LinkManager::new(2, true);
        let (id0, _) = links.start(0, Direction::Up, 10, 5.0, 0.0);
        assert_eq!(id0, 0);
        links.reset();
        assert_eq!(links.in_flight_total(), 0);
        let (id1, resched) = links.start(0, Direction::Up, 10, 5.0, 0.0);
        assert_eq!(id1, 0, "ids restart after reset");
        assert_eq!(resched, vec![(0, 5.0)]);
    }
}
