//! Simulated wall clock. All durations produced by the cpu/network models
//! are accumulated here; the HFL engine advances it by the *straggler*
//! (max) path per synchronization barrier, matching how the paper's
//! testbed experiences time.

#[derive(Clone, Debug, Default)]
pub struct SimClock {
    now: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0, "time cannot go backwards (dt={dt})");
        self.now += dt;
    }

    pub fn reset(&mut self) {
        self.now = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = SimClock::new();
        c.advance(1.5);
        c.advance(0.0);
        c.advance(2.5);
        assert!((c.now() - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "time cannot go backwards")]
    fn rejects_negative() {
        let mut c = SimClock::new();
        c.advance(-1.0);
    }
}
