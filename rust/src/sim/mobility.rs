//! Device mobility (paper §1: "some devices may join or leave HFL at any
//! time"). A two-state Markov process per device: active devices leave
//! with `leave_prob` per cloud round, departed ones return with
//! `join_prob`. The profiling module re-clusters when the active set
//! drifts enough (`hfl::membership`); the DRL state dimensions are
//! unaffected (M fixed).
//!
//! Every [`MobilityModel::step`] reports its join/leave counts as a
//! [`FlipStats`] (re-readable via [`MobilityModel::flip_stats`]) and
//! remembers *which* devices flipped ([`MobilityModel::flipped`]), so
//! drift tracking and the event engines never have to re-scan the whole
//! active vector per event.

use crate::util::rng::Rng;

/// Join/leave counts of one or more mobility steps.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FlipStats {
    /// Departed devices that became active.
    pub joins: usize,
    /// Active devices that departed.
    pub leaves: usize,
}

impl FlipStats {
    pub fn total(self) -> usize {
        self.joins + self.leaves
    }

    pub fn merge(&mut self, other: FlipStats) {
        self.joins += other.joins;
        self.leaves += other.leaves;
    }
}

#[derive(Clone, Debug)]
pub struct MobilityModel {
    pub leave_prob: f64,
    pub join_prob: f64,
    active: Vec<bool>,
    rng: Rng,
    /// Devices that changed state in the most recent `step` (net flips:
    /// a leave revived by the keep-alive in the same step cancels out).
    last_flipped: Vec<usize>,
    /// Join/leave counts of the most recent `step`.
    last_stats: FlipStats,
}

impl MobilityModel {
    pub fn new(n: usize, leave_prob: f64, join_prob: f64, rng: Rng) -> Self {
        MobilityModel {
            leave_prob,
            join_prob,
            active: vec![true; n],
            rng,
            last_flipped: Vec::new(),
            last_stats: FlipStats::default(),
        }
    }

    /// Immobile population (the default experiment setting). Steps report
    /// zero joins/leaves, so drift tracking sees a quiescent population.
    pub fn disabled(n: usize) -> Self {
        MobilityModel::new(n, 0.0, 1.0, Rng::new(0))
    }

    /// Population churning at the config's `sim.leave_prob`/`sim.join_prob`
    /// rates, seeded independently of the engine's main stream so enabling
    /// mobility does not perturb training/communication draws.
    pub fn from_config(
        n: usize,
        sim: &crate::config::SimConfig,
        seed: u64,
    ) -> Self {
        MobilityModel::new(
            n,
            sim.leave_prob,
            sim.join_prob,
            Rng::new(seed ^ 0x0b111e),
        )
    }

    pub fn is_active(&self, device: usize) -> bool {
        self.active[device]
    }

    /// Force `device`'s state — the injected-fault hook
    /// (`hfl::lifecycle` crash storms). RNG-safe by construction:
    /// [`MobilityModel::step`] draws exactly one uniform per device
    /// regardless of state, so external toggles never desync the churn
    /// stream (a toggled run and an untoggled one consume identical
    /// draws). Not reported through `flipped()`/`flip_stats()` — fault
    /// churn is accounted by the fault counters, not the mobility ones.
    pub fn set_active(&mut self, device: usize, active: bool) {
        self.active[device] = active;
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    pub fn active_set(&self) -> Vec<usize> {
        (0..self.active.len()).filter(|&i| self.active[i]).collect()
    }

    /// Devices that changed state in the most recent [`step`](Self::step)
    /// — the event engines use this instead of diffing the active vector.
    pub fn flipped(&self) -> &[usize] {
        &self.last_flipped
    }

    /// Join/leave counts of the most recent [`step`](Self::step) — the
    /// per-interval churn surface the membership subsystem's drift
    /// tracking accumulates (`hfl::membership::MembershipTracker`).
    pub fn flip_stats(&self) -> FlipStats {
        self.last_stats
    }

    /// Advance one cloud round; returns this step's join/leave counts.
    pub fn step(&mut self) -> FlipStats {
        let mut fs = FlipStats::default();
        self.last_flipped.clear();
        for (i, a) in self.active.iter_mut().enumerate() {
            let p = if *a { self.leave_prob } else { self.join_prob };
            if self.rng.uniform() < p {
                *a = !*a;
                if *a {
                    fs.joins += 1;
                } else {
                    fs.leaves += 1;
                }
                self.last_flipped.push(i);
            }
        }
        // Never let the system empty out entirely. If device 0 departed in
        // this very step the revival cancels its flip (net no change).
        if self.active.iter().all(|&a| !a) {
            self.active[0] = true;
            if let Some(pos) =
                self.last_flipped.iter().position(|&d| d == 0)
            {
                self.last_flipped.remove(pos);
                fs.leaves -= 1;
            } else {
                self.last_flipped.push(0);
                fs.joins += 1;
            }
        }
        self.last_stats = fs;
        fs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_never_changes() {
        let mut m = MobilityModel::disabled(10);
        for _ in 0..100 {
            assert_eq!(m.step(), FlipStats::default());
            assert!(m.flipped().is_empty());
            assert_eq!(m.flip_stats().total(), 0);
            assert_eq!(m.active_count(), 10);
        }
    }

    #[test]
    fn stationary_fraction_matches_rates() {
        // leave 0.1 / join 0.3 → stationary active ≈ 0.75.
        let mut m = MobilityModel::new(200, 0.1, 0.3, Rng::new(5));
        let mut counts = 0usize;
        let rounds = 2000;
        for _ in 0..rounds {
            m.step();
            counts += m.active_count();
        }
        let frac = counts as f64 / (rounds * 200) as f64;
        assert!((frac - 0.75).abs() < 0.05, "stationary frac {frac}");
    }

    #[test]
    fn same_seed_step_sequences_are_reproducible() {
        let mut a = MobilityModel::new(64, 0.2, 0.4, Rng::new(77));
        let mut b = MobilityModel::new(64, 0.2, 0.4, Rng::new(77));
        for _ in 0..500 {
            assert_eq!(a.step(), b.step());
            assert_eq!(a.flipped(), b.flipped());
            assert_eq!(a.active_set(), b.active_set());
        }
    }

    #[test]
    fn from_config_rates_and_determinism() {
        let mut sim = crate::config::ExperimentConfig::mnist().sim;
        sim.leave_prob = 0.3;
        sim.join_prob = 0.7;
        let mut a = MobilityModel::from_config(30, &sim, 42);
        let mut b = MobilityModel::from_config(30, &sim, 42);
        assert_eq!(a.leave_prob, 0.3);
        assert_eq!(a.join_prob, 0.7);
        for _ in 0..200 {
            a.step();
            b.step();
            assert_eq!(a.active_set(), b.active_set());
        }
        // Defaults (leave 0 / join 1) must behave like `disabled`.
        let mut d = MobilityModel::from_config(
            30,
            &crate::config::ExperimentConfig::mnist().sim,
            42,
        );
        for _ in 0..50 {
            assert_eq!(d.step().total(), 0);
            assert_eq!(d.active_count(), 30);
        }
    }

    #[test]
    fn never_fully_empty() {
        let mut m = MobilityModel::new(5, 1.0, 0.0, Rng::new(6));
        for _ in 0..50 {
            m.step();
            assert!(m.active_count() >= 1);
        }
    }

    #[test]
    fn flip_stats_match_the_state_diff() {
        // The reported joins/leaves and flipped() must equal the actual
        // active-set diff of each step — keep-alive revivals included
        // (which can report a join even at join_prob 0).
        let mut m = MobilityModel::new(8, 0.5, 0.1, Rng::new(9));
        for _ in 0..50 {
            let before = m.active_set();
            let fs = m.step();
            let after = m.active_set();
            let joins = after.iter().filter(|d| !before.contains(d)).count();
            let leaves = before.iter().filter(|d| !after.contains(d)).count();
            assert_eq!(fs, FlipStats { joins, leaves });
            assert_eq!(fs, m.flip_stats(), "flip_stats mirrors the step");
            assert_eq!(fs.total(), m.flipped().len());
            // flipped() is exactly the symmetric difference.
            for &d in m.flipped() {
                assert_ne!(before.contains(&d), after.contains(&d));
            }
        }
    }

    #[test]
    fn keep_alive_revival_is_a_net_noop_for_flips() {
        // With leave_prob 1 everyone tries to leave each step; the
        // keep-alive revives device 0, which must not be reported as
        // flipped (its state did not change net of the step).
        let mut m = MobilityModel::new(3, 1.0, 0.0, Rng::new(1));
        m.step(); // collapses to {0}
        let fs = m.step(); // 0 leaves + revived, others stay departed
        assert_eq!(fs.joins, 0);
        assert_eq!(fs.leaves, 0);
        assert!(m.flipped().is_empty());
        assert!(m.is_active(0));
    }
}
