//! Device mobility (paper §1: "some devices may join or leave HFL at any
//! time"). A two-state Markov process per device: active devices leave
//! with `leave_prob` per cloud round, departed ones return with
//! `join_prob`. The profiling module re-clusters when the active set
//! changes enough; the DRL state dimensions are unaffected (M fixed).

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct MobilityModel {
    pub leave_prob: f64,
    pub join_prob: f64,
    active: Vec<bool>,
    rng: Rng,
}

impl MobilityModel {
    pub fn new(n: usize, leave_prob: f64, join_prob: f64, rng: Rng) -> Self {
        MobilityModel {
            leave_prob,
            join_prob,
            active: vec![true; n],
            rng,
        }
    }

    /// Immobile population (the default experiment setting).
    pub fn disabled(n: usize) -> Self {
        MobilityModel::new(n, 0.0, 1.0, Rng::new(0))
    }

    /// Population churning at the config's `sim.leave_prob`/`sim.join_prob`
    /// rates, seeded independently of the engine's main stream so enabling
    /// mobility does not perturb training/communication draws.
    pub fn from_config(
        n: usize,
        sim: &crate::config::SimConfig,
        seed: u64,
    ) -> Self {
        MobilityModel::new(
            n,
            sim.leave_prob,
            sim.join_prob,
            Rng::new(seed ^ 0x0b111e),
        )
    }

    pub fn is_active(&self, device: usize) -> bool {
        self.active[device]
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    pub fn active_set(&self) -> Vec<usize> {
        (0..self.active.len()).filter(|&i| self.active[i]).collect()
    }

    /// Advance one cloud round; returns the number of state flips.
    pub fn step(&mut self) -> usize {
        let mut flips = 0;
        for a in self.active.iter_mut() {
            let p = if *a { self.leave_prob } else { self.join_prob };
            if self.rng.uniform() < p {
                *a = !*a;
                flips += 1;
            }
        }
        // Never let the system empty out entirely.
        if self.active.iter().all(|&a| !a) {
            self.active[0] = true;
            flips += 1;
        }
        flips
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_never_changes() {
        let mut m = MobilityModel::disabled(10);
        for _ in 0..100 {
            assert_eq!(m.step(), 0);
            assert_eq!(m.active_count(), 10);
        }
    }

    #[test]
    fn stationary_fraction_matches_rates() {
        // leave 0.1 / join 0.3 → stationary active ≈ 0.75.
        let mut m = MobilityModel::new(200, 0.1, 0.3, Rng::new(5));
        let mut counts = 0usize;
        let rounds = 2000;
        for _ in 0..rounds {
            m.step();
            counts += m.active_count();
        }
        let frac = counts as f64 / (rounds * 200) as f64;
        assert!((frac - 0.75).abs() < 0.05, "stationary frac {frac}");
    }

    #[test]
    fn same_seed_step_sequences_are_reproducible() {
        let mut a = MobilityModel::new(64, 0.2, 0.4, Rng::new(77));
        let mut b = MobilityModel::new(64, 0.2, 0.4, Rng::new(77));
        for _ in 0..500 {
            assert_eq!(a.step(), b.step());
            assert_eq!(a.active_set(), b.active_set());
        }
    }

    #[test]
    fn from_config_rates_and_determinism() {
        let mut sim = crate::config::ExperimentConfig::mnist().sim;
        sim.leave_prob = 0.3;
        sim.join_prob = 0.7;
        let mut a = MobilityModel::from_config(30, &sim, 42);
        let mut b = MobilityModel::from_config(30, &sim, 42);
        assert_eq!(a.leave_prob, 0.3);
        assert_eq!(a.join_prob, 0.7);
        for _ in 0..200 {
            a.step();
            b.step();
            assert_eq!(a.active_set(), b.active_set());
        }
        // Defaults (leave 0 / join 1) must behave like `disabled`.
        let mut d = MobilityModel::from_config(
            30,
            &crate::config::ExperimentConfig::mnist().sim,
            42,
        );
        for _ in 0..50 {
            assert_eq!(d.step(), 0);
            assert_eq!(d.active_count(), 30);
        }
    }

    #[test]
    fn never_fully_empty() {
        let mut m = MobilityModel::new(5, 1.0, 0.0, Rng::new(6));
        for _ in 0..50 {
            m.step();
            assert!(m.active_count() >= 1);
        }
    }
}
