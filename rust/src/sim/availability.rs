//! Deterministic diurnal device availability — the pace-steering
//! substrate ("Towards Federated Learning at Scale", arXiv:1902.01046:
//! devices check in on diurnal waves; the selector shapes the arrival
//! rate instead of dispatching into the trough).
//!
//! Each device owns one availability window inside a configurable day:
//! a start phase and a length drawn once at construction from a
//! dedicated stream (`seed ^ 0xd1a1`), the same isolation discipline as
//! [`crate::sim::mobility::MobilityModel`] — enabling pace steering
//! never perturbs training, communication or churn draws. After
//! construction the model consumes no RNG at all: availability is a
//! pure function of `(device, sim_time)`, so both engines (barrier and
//! event loop) and every worker count read identical answers.
//!
//! The engines never *skip* an unavailable device (that could stall an
//! edge forever); they defer its dispatch by
//! [`AvailabilityModel::delay_until`] — arrival-rate shaping, not
//! participation filtering — and prefer currently-available devices
//! when over-selection picks a subset.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct AvailabilityModel {
    /// Diurnal period in simulated seconds.
    day: f64,
    /// Per-device window start phase in `[0, day)`.
    start: Vec<f64>,
    /// Per-device window length in `(0, day]`.
    len: Vec<f64>,
}

impl AvailabilityModel {
    /// Seeded diurnal model: every device is available for roughly
    /// `avail_frac` of each `day` (per-device length jittered ±25% and
    /// clamped to `(0, day]`), with a uniform start phase.
    pub fn new(n: usize, day: f64, avail_frac: f64, seed: u64) -> Self {
        assert!(day > 0.0, "diurnal day must be positive ({day})");
        let frac = avail_frac.clamp(0.01, 1.0);
        let mut rng = Rng::new(seed ^ 0xd1a1);
        let mut start = Vec::with_capacity(n);
        let mut len = Vec::with_capacity(n);
        for _ in 0..n {
            start.push(rng.uniform() * day);
            let jitter = 0.75 + 0.5 * rng.uniform();
            len.push((day * frac * jitter).clamp(day * 1e-3, day));
        }
        AvailabilityModel { day, start, len }
    }

    pub fn day(&self) -> f64 {
        self.day
    }

    /// Is `device` inside its window at simulated time `t`?
    pub fn is_available(&self, device: usize, t: f64) -> bool {
        let phase = t.rem_euclid(self.day);
        let s = self.start[device];
        let e = s + self.len[device];
        if e <= self.day {
            phase >= s && phase < e
        } else {
            // Window wraps midnight.
            phase >= s || phase < e - self.day
        }
    }

    /// Seconds until `device` next enters its window (0 if available
    /// now). Pure arithmetic — no draws — so deferring a dispatch by
    /// this delay is deterministic at any worker count.
    pub fn delay_until(&self, device: usize, t: f64) -> f64 {
        if self.is_available(device, t) {
            return 0.0;
        }
        let phase = t.rem_euclid(self.day);
        let s = self.start[device];
        if phase < s {
            s - phase
        } else {
            self.day - phase + s
        }
    }

    /// Mean availability of `devices` at time `t` — the DRL observable
    /// (`agent/state.rs` availability column).
    pub fn fraction_available(&self, devices: &[usize], t: f64) -> f64 {
        if devices.is_empty() {
            return 1.0;
        }
        let n = devices
            .iter()
            .filter(|&&d| self.is_available(d, t))
            .count();
        n as f64 / devices.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_is_reproducible() {
        let a = AvailabilityModel::new(64, 3600.0, 0.5, 7);
        let b = AvailabilityModel::new(64, 3600.0, 0.5, 7);
        for d in 0..64 {
            for k in 0..20 {
                let t = k as f64 * 137.5;
                assert_eq!(a.is_available(d, t), b.is_available(d, t));
                assert_eq!(
                    a.delay_until(d, t).to_bits(),
                    b.delay_until(d, t).to_bits()
                );
            }
        }
    }

    #[test]
    fn windows_cover_roughly_the_requested_fraction() {
        let m = AvailabilityModel::new(200, 1000.0, 0.5, 3);
        let mut avail = 0usize;
        let mut total = 0usize;
        for d in 0..200 {
            for k in 0..100 {
                total += 1;
                if m.is_available(d, k as f64 * 10.0) {
                    avail += 1;
                }
            }
        }
        let frac = avail as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.1, "availability frac {frac}");
    }

    #[test]
    fn delay_lands_inside_the_window() {
        let m = AvailabilityModel::new(32, 500.0, 0.3, 11);
        for d in 0..32 {
            for k in 0..40 {
                let t = k as f64 * 61.7;
                let delay = m.delay_until(d, t);
                assert!(delay >= 0.0 && delay < 500.0);
                assert!(
                    m.is_available(d, t + delay + 1e-9),
                    "device {d} still unavailable after its delay"
                );
            }
        }
    }

    #[test]
    fn availability_is_periodic() {
        let m = AvailabilityModel::new(16, 250.0, 0.4, 5);
        for d in 0..16 {
            for k in 0..25 {
                let t = k as f64 * 13.0;
                assert_eq!(
                    m.is_available(d, t),
                    m.is_available(d, t + 250.0 * 3.0)
                );
            }
        }
    }

    #[test]
    fn fraction_available_bounds() {
        let m = AvailabilityModel::new(50, 800.0, 0.5, 9);
        let devs: Vec<usize> = (0..50).collect();
        for k in 0..30 {
            let f = m.fraction_available(&devs, k as f64 * 97.0);
            assert!((0.0..=1.0).contains(&f));
        }
        assert_eq!(m.fraction_available(&[], 0.0), 1.0);
    }
}
