//! Device energy model (paper Fig. 3b; measured with a Monsoon power
//! monitor on the testbed, reported in mAh).
//!
//! Power is affine in the governor frequency/usage (DVFS-style):
//!     P(u) = P_idle + (P_max - P_idle) · u_eff,
//! where u_eff blends the training task's own load with interference.
//! Energy for an activity = P · t, converted to mAh at the Pi's 5 V rail.

use super::cpu::CpuModel;

#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub power_idle: f64,
    pub power_max: f64,
    /// Rail voltage for W·s → mAh conversion (Raspberry Pi: 5 V).
    pub volts: f64,
}

impl EnergyModel {
    pub fn new(power_idle: f64, power_max: f64) -> Self {
        EnergyModel {
            power_idle,
            power_max,
            volts: 5.0,
        }
    }

    /// Instantaneous power while training under the given CPU state.
    pub fn training_power(&self, cpu: &CpuModel) -> f64 {
        // Training saturates the free share; interference keeps the rest
        // busy too, so effective load ≈ 0.6 + 0.4·usage of full tilt.
        let u_eff = 0.6 + 0.4 * cpu.usage;
        self.power_idle + (self.power_max - self.power_idle) * u_eff
    }

    /// Radio/communication power (roughly constant).
    pub fn comm_power(&self) -> f64 {
        self.power_idle + 0.35 * (self.power_max - self.power_idle)
    }

    /// W over s → mAh at the rail voltage.
    pub fn to_mah(&self, watts: f64, seconds: f64) -> f64 {
        watts * seconds / self.volts / 3600.0 * 1000.0
    }

    /// Energy (mAh) for one SGD batch that took `t` seconds.
    pub fn sgd_energy(&self, cpu: &CpuModel, t: f64) -> f64 {
        self.to_mah(self.training_power(cpu), t)
    }

    /// Energy (mAh) for a communication activity of `t` seconds.
    pub fn comm_energy(&self, t: f64) -> f64 {
        self.to_mah(self.comm_power(), t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats;

    fn cpu(u: f64) -> CpuModel {
        CpuModel::new(u, 2.0, 1.2, 0.18, Rng::new(3))
    }

    #[test]
    fn energy_grows_with_usage() {
        // Fig. 3b: higher interference → more J per SGD (longer AND hotter).
        let e = EnergyModel::new(2.2, 6.2);
        let mut means = Vec::new();
        for &u in &[0.1, 0.5, 0.9] {
            let mut c = cpu(u);
            let xs: Vec<f64> = (0..2000)
                .map(|_| {
                    let t = c.sgd_time();
                    e.sgd_energy(&c, t)
                })
                .collect();
            means.push(stats::mean(&xs));
        }
        assert!(means[0] < means[1] && means[1] < means[2], "{means:?}");
    }

    #[test]
    fn mah_conversion() {
        let e = EnergyModel::new(2.0, 6.0);
        // 5 W for 3600 s at 5 V = 1000 mAh.
        assert!((e.to_mah(5.0, 3600.0) - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn comm_power_between_idle_and_max() {
        let e = EnergyModel::new(2.0, 6.0);
        assert!(e.comm_power() > 2.0 && e.comm_power() < 6.0);
    }
}
