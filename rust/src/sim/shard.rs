//! Sharded parallel execution layer — simulate 1M+ devices at full
//! hardware speed without giving up a single bit of determinism.
//!
//! Devices are partitioned **by edge** into a fixed set of shards. Each
//! shard owns, privately and for the whole run:
//!
//!  * its own [`EventQueue`] (seeded from the master seed + shard index),
//!  * its own forked RNG streams (one per device, forked in canonical
//!    edge-major order at construction),
//!  * its own region of the device-sharded model store (a per-shard
//!    [`ModelStore`] slab — no cross-shard buffer ever exists).
//!
//! Long-lived worker threads ([`ShardPool`]) advance shards independently
//! up to a **conservative time-window barrier** (the cloud decision
//! point): within a window, nothing a shard computes can depend on
//! another shard, because cross-shard information (the cloud broadcast)
//! only flows at barriers. At each barrier the per-shard reports are
//! merged **in fixed shard order**, the cloud state advances, and the
//! next window's broadcast is a pure function of the merged state.
//!
//! # Determinism rules
//!
//! The merged trajectory is bit-identical for any worker count
//! (including 1, which runs inline with no threads) and any queue
//! backend, because:
//!
//!  1. **The shard partition is fixed by the topology** (edge → shard by
//!     index), never by the worker count. Workers are an execution
//!     detail; shards are the unit of determinism.
//!  2. **RNG streams are forked per shard and per device at
//!     construction**, in one canonical serial order. No stream is ever
//!     shared across shards, so event-processing order inside one shard
//!     (which is itself deterministic — seeded [`EventQueue`]) fully
//!     determines every draw.
//!  3. **Merges happen in fixed shard order** at every barrier,
//!     whatever order worker threads finish in ([`ShardPool::run`]
//!     re-orders reports by shard index).
//!  4. **No wall-clock time ever enters the simulated timeline.** Real
//!     threads race; simulated time comes only from seeded draws and
//!     the event queue. (The adversarial-delay test hook injects real
//!     sleeps precisely to prove they cannot matter.)
//!  5. **Profiling is read-only.** With an observer attached (see
//!     [`ShardedDeviceSim::attach_observer`]) each shard's
//!     [`ShardProfiler`] samples into shard-private counters and the
//!     coordinator folds the per-window profiles — in fixed shard
//!     order, at barriers only — into `Observer::on_shard_barrier`.
//!     Wall-clock is read only when profiling and flows only into
//!     observer records, so profiler-on == profiler-off, bitwise
//!     (`tests/obs_profiler.rs`).
//!
//! This is the same discipline as PR 5's fixed-chunk
//! `aggregate_native_par` — a fixed work grid with order-independent
//! pieces and a deterministic fold — promoted from one kernel to the
//! whole event loop.
//!
//! # Relationship to the engine shards
//!
//! This module simulates *synthetic* devices (no model math) and is the
//! scale harness for benches and CI. The production counterpart is
//! [`crate::hfl::engine_shard`]: [`EngineShard`] applies the identical
//! shard-by-edge / window-barrier / fixed-order-merge discipline to the
//! real `AsyncHflEngine` timer loop, except that shards there emit
//! ordered *action logs* (dispatch, train, aggregate, transfer
//! landings) which the engine replays serially against the model store
//! at each barrier — the model math never runs inside a worker thread.
//! The window bound is exact rather than conservative: every
//! cross-shard coupling in the engine is a ctrl-queue event (cloud
//! window, mobility flip, recluster, seeded fault), so shards may
//! always advance to the next ctrl timestamp. Changes to the barrier
//! rules here should be mirrored there, and vice versa.
//!
//! [`EngineShard`]: crate::hfl::engine_shard::EngineShard

use std::io::Write as _;

use crate::config::FaultConfig;
use crate::hfl::lifecycle::{storm_hits, FaultPlan};
use crate::hfl::model_store::{ModelRef, ModelStore};
use crate::obs::profiler::{
    PoolWindowProfile, ShardProfiler, ShardWindowProfile,
};
use crate::obs::Observer;
use crate::sim::event::{Event, EventQueue, QueueBackend};
use crate::util::rng::Rng;
use crate::util::threadpool::ShardPool;

/// Topology + schedule of a sharded device simulation. All fields are
/// part of the deterministic trajectory **except** `workers`,
/// `backend` and `adversarial_delay_us`, which must never change any
/// output bit (tested).
#[derive(Clone, Debug)]
pub struct ShardSpec {
    pub devices: usize,
    pub edges: usize,
    /// Shard count — part of the topology, NOT derived from `workers`
    /// (rule 1 above). `0` = auto: `min(edges, 64)`.
    pub shards: usize,
    /// Flat model length for the per-shard store slabs.
    pub p: usize,
    /// Cloud decision interval = conservative barrier spacing (sim s).
    pub window: f64,
    pub windows: usize,
    pub seed: u64,
    /// Worker threads (`0` = available parallelism). Execution detail:
    /// bitwise invisible.
    pub workers: usize,
    /// Per-shard event-queue backend. Bitwise invisible.
    pub backend: QueueBackend,
    /// Per-flip leave probability for live devices (0 disables churn
    /// together with `join_prob`).
    pub leave_prob: f64,
    /// Per-flip join probability for departed devices.
    pub join_prob: f64,
    /// Test hook: seeded random worker sleeps (real microseconds, up to
    /// this bound) injected before each shard window — adversarial
    /// thread interleaving that the output must not observe.
    pub adversarial_delay_us: u64,
    /// Injected edge outages over the run (`fault.outages`; 0 disables —
    /// and a zero-fault spec is bitwise identical to one that predates
    /// the fault layer, the sixth no-op guarantee).
    pub outages: usize,
    /// Seconds a failed edge stays down (`fault.outage_duration`).
    pub outage_duration: f64,
    /// Injected edge↔cloud partitions over the run (`fault.partitions`).
    pub partitions: usize,
    /// Seconds a partition stays severed (`fault.partition_duration`).
    pub partition_duration: f64,
    /// Injected crash/rejoin storms over the run (`fault.crash_storms`).
    pub crash_storms: usize,
    /// Fraction of devices each storm crashes (`fault.crash_frac`).
    pub crash_frac: f64,
    /// Seconds between a storm's crash and its rejoin wave
    /// (`fault.rejoin_delay`).
    pub rejoin_delay: f64,
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec {
            devices: 1024,
            edges: 16,
            shards: 0,
            p: 64,
            window: 60.0,
            windows: 5,
            seed: 7,
            workers: 1,
            backend: QueueBackend::Auto,
            leave_prob: 0.05,
            join_prob: 0.3,
            adversarial_delay_us: 0,
            outages: 0,
            outage_duration: 120.0,
            partitions: 0,
            partition_duration: 180.0,
            crash_storms: 0,
            crash_frac: 0.3,
            rejoin_delay: 90.0,
        }
    }
}

impl ShardSpec {
    /// Shard count after resolving `shards == 0` (auto).
    pub fn resolved_shards(&self) -> usize {
        if self.shards > 0 {
            self.shards.min(self.edges.max(1))
        } else {
            self.edges.clamp(1, 64)
        }
    }

    /// Worker count after resolving `workers == 0` (all cores).
    pub fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }

    /// The spec's `fault.*` view, for [`FaultPlan::build`].
    pub fn fault_config(&self) -> FaultConfig {
        FaultConfig {
            outages: self.outages,
            outage_duration: self.outage_duration,
            partitions: self.partitions,
            partition_duration: self.partition_duration,
            crash_storms: self.crash_storms,
            crash_frac: self.crash_frac,
            rejoin_delay: self.rejoin_delay,
        }
    }
}

struct DevState {
    global: usize,
    /// Local index of the owning edge within the shard.
    edge: usize,
    rng: Rng,
    live: bool,
    /// A `DeviceTrainDone` is in flight for this device.
    busy: bool,
    w: ModelRef,
}

struct EdgeState {
    /// Global edge index (for partition masks, which address global
    /// edge bits).
    global: usize,
    version: u64,
    model: ModelRef,
    /// Local device indices of members (canonical order).
    members: Vec<usize>,
    reports: usize,
    /// Down by an injected [`Event::EdgeOutage`]: no dispatch, no
    /// aggregation; landings void through the straggler path.
    faulted: bool,
    /// Severed from the cloud by an injected [`Event::Partition`]:
    /// training continues, broadcasts don't land.
    partitioned: bool,
}

/// One shard's complete private world (see module doc).
struct Shard {
    queue: EventQueue,
    store: ModelStore,
    edges: Vec<EdgeState>,
    devices: Vec<DevState>,
    /// Real-sleep stream for the adversarial-delay hook — separate from
    /// every simulation stream, so injecting delays perturbs nothing.
    jitter: Rng,
    window: f64,
    flip_dt: f64,
    leave_prob: f64,
    join_prob: f64,
    // Per-window accumulators (reset by `advance`).
    events: u64,
    voided: u64,
    aggregates: u64,
    flips: u64,
    outages: u64,
    partitions: u64,
    crashes: u64,
    loss_sum: f64,
    loss_n: u64,
    energy: f64,
    /// Shard-owned hot-path profiler (rule 5) — disabled unless an
    /// observer is attached to the coordinator.
    prof: ShardProfiler,
}

/// What one shard reports home at a barrier. Plain data; the
/// coordinator folds these **in shard order**.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowReport {
    pub events: u64,
    pub voided: u64,
    pub aggregates: u64,
    pub flips: u64,
    pub outages: u64,
    pub partitions: u64,
    pub crashes: u64,
    pub live: usize,
    pub loss_sum: f64,
    pub loss_n: u64,
    pub energy: f64,
    /// Order-sensitive fold over the shard's edge models and versions.
    pub checksum: u64,
    pub store_live: usize,
    pub queue_len: usize,
}

/// One merged row of the run history (what lands in the CSV).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowRow {
    pub window: usize,
    pub sim_time: f64,
    pub events: u64,
    pub live: usize,
    pub loss: f64,
    pub energy: f64,
    pub aggregates: u64,
    pub cloud_version: u64,
    /// Fault events applied this window (outage downs + severed edges +
    /// crashed devices) — 0 on every row of a zero-fault run.
    pub faults: u64,
    /// Fold of per-shard checksums in shard order.
    pub checksum: u64,
}

/// Cumulative merged per-shard metrics (deterministic: every fold is in
/// shard order).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MergedStats {
    pub events: u64,
    pub voided: u64,
    pub aggregates: u64,
    pub flips: u64,
    pub outages: u64,
    pub partitions: u64,
    pub crashes: u64,
    pub peak_queue_len: usize,
    pub store_live: usize,
}

impl Shard {
    fn dur(&mut self, d: usize) -> f64 {
        let u = self.devices[d].rng.uniform();
        self.window * (0.15 + 0.55 * u)
    }

    fn dispatch(&mut self, d: usize, now: f64) {
        let dur = self.dur(d);
        let dev = &mut self.devices[d];
        dev.busy = true;
        let e = dev.edge;
        self.queue
            .schedule(now + dur, Event::DeviceTrainDone { device: d, edge: e });
    }

    fn on_train_done(&mut self, d: usize, e: usize, now: f64) {
        if !self.devices[d].live || self.edges[e].faulted {
            // Departed (or crashed) mid-round, or the edge went down:
            // the straggler's result is void.
            self.devices[d].busy = false;
            self.voided += 1;
            return;
        }
        self.devices[d].busy = false;
        let u = self.devices[d].rng.uniform();
        let u2 = self.devices[d].rng.uniform();
        let version = self.edges[e].version;
        self.loss_sum += 5.0 / (1.0 + version as f64) + 0.2 * u;
        self.loss_n += 1;
        self.energy += 0.5 + u2;
        // Local update: CoW checkout of the device's buffer.
        let global = self.devices[d].global;
        let w = self.store.make_mut(&mut self.devices[d].w);
        let slot = (global + version as usize) % w.len();
        w[slot] += 0.001 * (u as f32 - 0.5);
        self.edges[e].reports += 1;
        self.try_aggregate(e, now);
    }

    /// Aggregate an edge once every live member has reported (the
    /// departed don't count; their in-flight results were voided).
    fn try_aggregate(&mut self, e: usize, now: f64) {
        if self.edges[e].reports == 0 || self.edges[e].faulted {
            return;
        }
        let any_busy = self.edges[e].members.iter().any(|&d| {
            let dv = &self.devices[d];
            dv.live && dv.busy
        });
        if any_busy {
            return;
        }
        self.edges[e].reports = 0;
        let lives: Vec<usize> = self.edges[e]
            .members
            .iter()
            .copied()
            .filter(|&d| self.devices[d].live)
            .collect();
        if lives.is_empty() {
            return;
        }
        let beta = 1.0 / lives.len() as f32;
        for &d in &lives {
            self.store.mix_into(
                &mut self.edges[e].model,
                &self.devices[d].w,
                beta,
            );
        }
        self.edges[e].model.bump_version();
        self.edges[e].version += 1;
        self.aggregates += 1;
        // Sync + redispatch every live member (O(1) re-points).
        for &d in &lives {
            self.store
                .repoint(&mut self.devices[d].w, &self.edges[e].model);
            self.dispatch(d, now);
        }
    }

    fn on_flip(&mut self, now: f64) {
        self.flips += 1;
        for d in 0..self.devices.len() {
            let u = self.devices[d].rng.uniform();
            if self.devices[d].live {
                if u < self.leave_prob {
                    self.devices[d].live = false;
                }
            } else if u < self.join_prob {
                self.devices[d].live = true;
                if !self.devices[d].busy {
                    // Warm start from the current edge model, then train
                    // (a faulted edge re-dispatches on recovery instead).
                    let e = self.devices[d].edge;
                    self.store.repoint(
                        &mut self.devices[d].w,
                        &self.edges[e].model,
                    );
                    if !self.edges[e].faulted {
                        self.dispatch(d, now);
                    }
                }
            }
        }
        // Departures may have completed a round; re-check every edge.
        for e in 0..self.edges.len() {
            self.try_aggregate(e, now);
        }
        self.queue
            .schedule(now + self.flip_dt, Event::MobilityFlip);
    }

    /// An injected edge failure (`up == false`) or recovery. Down: the
    /// edge stops dispatching and aggregating; every in-flight member
    /// result will void on landing. Up: warm-restart every live,
    /// non-busy member so the edge resumes making progress.
    fn on_edge_outage(&mut self, e: usize, up: bool, now: f64) {
        if up {
            self.edges[e].faulted = false;
            self.edges[e].reports = 0;
            let members = self.edges[e].members.clone();
            for d in members {
                let dv = &self.devices[d];
                if dv.live && !dv.busy {
                    self.store.repoint(
                        &mut self.devices[d].w,
                        &self.edges[e].model,
                    );
                    self.dispatch(d, now);
                }
            }
        } else if !self.edges[e].faulted {
            self.edges[e].faulted = true;
            self.edges[e].reports = 0;
            self.outages += 1;
        }
    }

    /// An injected partition severs (`up == false`) / heals the
    /// edge↔cloud path of every owned edge whose global-index bit is in
    /// `mask`. Training under a severed edge continues; only broadcasts
    /// stop landing.
    fn on_partition(&mut self, mask: u64, up: bool) {
        for e in 0..self.edges.len() {
            if mask >> (self.edges[e].global % 64) & 1 == 1 {
                if !up && !self.edges[e].partitioned {
                    self.partitions += 1;
                }
                self.edges[e].partitioned = !up;
            }
        }
    }

    /// An injected crash (`up == false`) / rejoin storm. Membership is
    /// the pure predicate `storm_hits(seed, global, frac_bits)` — the
    /// rejoin wave recomputes exactly the crash set, on any worker.
    fn on_crash_storm(
        &mut self,
        seed: u64,
        frac_bits: u32,
        up: bool,
        now: f64,
    ) {
        for d in 0..self.devices.len() {
            if !storm_hits(seed, self.devices[d].global, frac_bits) {
                continue;
            }
            if !up {
                if self.devices[d].live {
                    self.devices[d].live = false;
                    self.crashes += 1;
                }
            } else if !self.devices[d].live {
                self.devices[d].live = true;
                if !self.devices[d].busy {
                    let e = self.devices[d].edge;
                    self.store.repoint(
                        &mut self.devices[d].w,
                        &self.edges[e].model,
                    );
                    if !self.edges[e].faulted {
                        self.dispatch(d, now);
                    }
                }
            }
        }
        if !up {
            // Crashes may have completed rounds; re-check every edge.
            for e in 0..self.edges.len() {
                self.try_aggregate(e, now);
            }
        }
    }

    /// Fold the cloud broadcast into every owned edge (window start).
    /// Partitioned edges are severed from the cloud: no broadcast lands.
    fn apply_broadcast(&mut self, b: f64) {
        for e in 0..self.edges.len() {
            if self.edges[e].partitioned {
                continue;
            }
            let w = self.store.make_mut(&mut self.edges[e].model);
            w[0] += (b as f32) * 1e-3;
        }
    }

    /// Process every event strictly before `barrier`, then report.
    fn advance(&mut self, barrier: f64) -> WindowReport {
        while let Some(t) = self.queue.peek_time() {
            if t >= barrier {
                break;
            }
            let (t, ev) = self.queue.pop().unwrap();
            self.events += 1;
            match ev {
                Event::DeviceTrainDone { device, edge } => {
                    self.on_train_done(device, edge, t)
                }
                Event::MobilityFlip => self.on_flip(t),
                Event::EdgeOutage { edge, up } => {
                    self.on_edge_outage(edge, up, t)
                }
                Event::Partition { mask, up } => self.on_partition(mask, up),
                Event::CrashStorm { seed, frac_bits, up } => {
                    self.on_crash_storm(seed, frac_bits, up, t)
                }
                _ => {}
            }
            self.prof.sample_queue_depth(self.queue.len());
        }
        let mut h = 0x9e37_79b9_7f4a_7c15u64;
        for e in &self.edges {
            h = h.rotate_left(9) ^ e.version;
            for &x in self.store.slice(&e.model) {
                h = h.rotate_left(7) ^ (x.to_bits() as u64);
            }
        }
        let report = WindowReport {
            events: self.events,
            voided: self.voided,
            aggregates: self.aggregates,
            flips: self.flips,
            outages: self.outages,
            partitions: self.partitions,
            crashes: self.crashes,
            live: self.devices.iter().filter(|d| d.live).count(),
            loss_sum: self.loss_sum,
            loss_n: self.loss_n,
            energy: self.energy,
            checksum: h,
            store_live: self.store.live_buffers(),
            queue_len: self.queue.len(),
        };
        self.events = 0;
        self.voided = 0;
        self.aggregates = 0;
        self.flips = 0;
        self.outages = 0;
        self.partitions = 0;
        self.crashes = 0;
        self.loss_sum = 0.0;
        self.loss_n = 0;
        self.energy = 0.0;
        report
    }

    /// Build this window's profile from the just-produced report plus
    /// the profiler's drained accumulators. Runs on the worker thread,
    /// after `advance`; `t0` is the window's start on this worker and
    /// `epoch` the coordinator's window start (for `done_at_ns`).
    fn window_profile(
        &mut self,
        shard: usize,
        rep: &WindowReport,
        t0: std::time::Instant,
        epoch: std::time::Instant,
    ) -> ShardWindowProfile {
        let advance_wall_ns = t0.elapsed().as_nanos() as u64;
        let shared = self
            .devices
            .iter()
            .filter(|d| self.store.is_shared(&d.w))
            .count();
        let mut p = ShardWindowProfile {
            shard,
            events: rep.events,
            voided: rep.voided,
            aggregates: rep.aggregates,
            flips: rep.flips,
            outages: rep.outages,
            partitions: rep.partitions,
            crashes: rep.crashes,
            live_devices: rep.live,
            queue_len_end: rep.queue_len,
            store_live_buffers: rep.store_live,
            store_peak_bytes: self.store.peak_model_bytes(),
            store_shared_handles: shared,
            store_handles: self.devices.len(),
            advance_wall_ns,
            done_at_ns: epoch.elapsed().as_nanos() as u64,
            ..Default::default()
        };
        self.prof.drain_into(&mut p);
        p
    }
}

/// The sharded simulation: a [`ShardPool`] of private shard worlds plus
/// the cloud-side merge state and run history.
pub struct ShardedDeviceSim {
    pool: ShardPool<Shard, (WindowReport, Option<ShardWindowProfile>)>,
    window: f64,
    windows: usize,
    next_window: usize,
    cloud_version: u64,
    /// Next window's broadcast (pure function of the merged state).
    broadcast: f64,
    delay_us: u64,
    history: Vec<WindowRow>,
    stats: MergedStats,
    /// Read-only instrumentation; profiles flow here at barriers only.
    obs: Option<Box<dyn Observer>>,
    /// Per-shard profiling toggle (`sim.profiler`). Only meaningful
    /// with an observer attached; on by default.
    profiler: bool,
}

impl ShardedDeviceSim {
    pub fn new(spec: &ShardSpec) -> Self {
        assert!(spec.devices >= spec.edges && spec.edges > 0);
        assert!(spec.p > 0 && spec.window > 0.0);
        let n_shards = spec.resolved_shards();
        let workers = spec.resolved_workers();
        let churn = spec.leave_prob + spec.join_prob > 0.0;
        // Fault plan: expanded once from its own stream, then scheduled
        // per shard below. Zero counts → empty plan → zero schedule
        // calls → bitwise identical to a pre-fault-layer run.
        let plan = FaultPlan::build(
            &spec.fault_config(),
            spec.edges,
            spec.window * spec.windows as f64,
            spec.seed,
        );
        // Canonical serial construction: master -> shard seeds in shard
        // order, then per-shard streams in edge-major member order.
        let mut master = Rng::new(spec.seed ^ 0x5a4d);
        let shard_seeds: Vec<u64> = (0..n_shards)
            .map(|s| master.fork(0x50 ^ s as u64).next_u64())
            .collect();
        let mut shards = Vec::with_capacity(n_shards);
        for (s, &sseed) in shard_seeds.iter().enumerate() {
            let mut srng = Rng::new(sseed);
            let jitter = srng.fork(0x71);
            let owned: Vec<usize> =
                (s..spec.edges).step_by(n_shards).collect();
            let per_shard_devs = spec.devices / n_shards + spec.edges;
            let mut shard = Shard {
                queue: EventQueue::for_scale(
                    sseed ^ 0x0e7,
                    per_shard_devs * 4 + 64,
                    spec.backend,
                ),
                store: ModelStore::new(spec.p),
                edges: Vec::with_capacity(owned.len()),
                devices: Vec::new(),
                jitter,
                window: spec.window,
                flip_dt: spec.window * 0.25,
                leave_prob: spec.leave_prob,
                join_prob: spec.join_prob,
                events: 0,
                voided: 0,
                aggregates: 0,
                flips: 0,
                outages: 0,
                partitions: 0,
                crashes: 0,
                loss_sum: 0.0,
                loss_n: 0,
                energy: 0.0,
                prof: ShardProfiler::new(),
            };
            for &ge in &owned {
                let init = ((ge + 1) as f32) * 0.01;
                let model = shard.store.insert(vec![init; spec.p], 0);
                let le = shard.edges.len();
                let mut members = Vec::new();
                for gd in (ge..spec.devices).step_by(spec.edges) {
                    let ld = shard.devices.len();
                    let rng = srng.fork(0x0d00 ^ gd as u64);
                    let w = shard.store.share(&model);
                    shard.devices.push(DevState {
                        global: gd,
                        edge: le,
                        rng,
                        live: true,
                        busy: false,
                        w,
                    });
                    members.push(ld);
                }
                shard.edges.push(EdgeState {
                    global: ge,
                    version: 0,
                    model,
                    members,
                    reports: 0,
                    faulted: false,
                    partitioned: false,
                });
            }
            // Initial dispatch wave + the churn clock.
            for d in 0..shard.devices.len() {
                shard.dispatch(d, 0.0);
            }
            if churn {
                let t0 = shard.flip_dt * 0.5;
                shard.queue.schedule(t0, Event::MobilityFlip);
            }
            // Fault schedule, in plan order: outages route to the shard
            // owning the edge (local index = global / n_shards);
            // partitions and storms broadcast to every shard.
            for &(t, ev) in plan.events() {
                match ev {
                    Event::EdgeOutage { edge, up } => {
                        if edge % n_shards == s {
                            shard.queue.schedule(
                                t,
                                Event::EdgeOutage {
                                    edge: edge / n_shards,
                                    up,
                                },
                            );
                        }
                    }
                    Event::Partition { .. } | Event::CrashStorm { .. } => {
                        shard.queue.schedule(t, ev);
                    }
                    _ => unreachable!("FaultPlan emits only fault events"),
                }
            }
            shards.push(shard);
        }
        ShardedDeviceSim {
            pool: ShardPool::new(workers, shards),
            window: spec.window,
            windows: spec.windows,
            next_window: 0,
            cloud_version: 0,
            broadcast: 0.0,
            delay_us: spec.adversarial_delay_us,
            history: Vec::with_capacity(spec.windows),
            stats: MergedStats::default(),
            obs: None,
            profiler: true,
        }
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    pub fn n_shards(&self) -> usize {
        self.pool.n_shards()
    }

    /// Attach a read-only observer. With the profiler on (the default)
    /// every barrier hands it the per-shard window profiles and the
    /// pool occupancy view via `Observer::on_shard_barrier` — in fixed
    /// shard order, bitwise invisible to the trajectory (rule 5).
    pub fn attach_observer(&mut self, obs: Box<dyn Observer>) {
        self.obs = Some(obs);
    }

    /// Detach and return the observer (e.g. to hand it to another
    /// engine phase or read its accumulated state).
    pub fn detach_observer(&mut self) -> Option<Box<dyn Observer>> {
        self.obs.take()
    }

    /// Toggle per-shard profiling (`sim.profiler`). Off, an attached
    /// observer still exists but no wall-clock is read and no
    /// `on_shard_barrier` fires.
    pub fn set_profiler(&mut self, on: bool) {
        self.profiler = on;
    }

    /// Advance every shard to the next barrier and merge. Returns the
    /// merged row (also appended to the history).
    pub fn run_window(&mut self) -> &WindowRow {
        let w = self.next_window;
        self.next_window += 1;
        let barrier = (w as f64 + 1.0) * self.window;
        let b = self.broadcast;
        let delay = self.delay_us;
        let first = w == 0;
        // Wall-clock is read only when profiling (rules 4 + 5): with no
        // observer attached, or the profiler off, no `Instant` exists.
        let profile = self.profiler && self.obs.is_some();
        let epoch = if profile {
            Some(std::time::Instant::now())
        } else {
            None
        };
        let reports = self.pool.run(move |idx, shard: &mut Shard| {
            shard.prof.set_enabled(profile);
            let t0 = epoch.map(|_| std::time::Instant::now());
            if delay > 0 {
                // Real-time jitter only — rule 4: the simulated
                // timeline cannot see it.
                let us = shard.jitter.below(delay.max(1) as usize);
                std::thread::sleep(std::time::Duration::from_micros(
                    us as u64,
                ));
            }
            if !first {
                shard.apply_broadcast(b);
            }
            let rep = shard.advance(barrier);
            let prof = t0.map(|t0| {
                shard.window_profile(idx, &rep, t0, epoch.unwrap())
            });
            (rep, prof)
        });
        // Fixed-shard-order merge (reports arrive already ordered).
        self.cloud_version += 1;
        let mut h = 0u64;
        let mut row = WindowRow {
            window: w,
            sim_time: barrier,
            events: 0,
            live: 0,
            loss: 0.0,
            energy: 0.0,
            aggregates: 0,
            cloud_version: self.cloud_version,
            faults: 0,
            checksum: 0,
        };
        let mut loss_sum = 0.0;
        let mut loss_n = 0u64;
        let mut store_live = 0usize;
        for (r, _) in &reports {
            h = h.rotate_left(11) ^ r.checksum;
            row.events += r.events;
            row.live += r.live;
            row.aggregates += r.aggregates;
            row.energy += r.energy;
            loss_sum += r.loss_sum;
            loss_n += r.loss_n;
            store_live += r.store_live;
            row.faults += r.outages + r.partitions + r.crashes;
            self.stats.events += r.events;
            self.stats.voided += r.voided;
            self.stats.aggregates += r.aggregates;
            self.stats.flips += r.flips;
            self.stats.outages += r.outages;
            self.stats.partitions += r.partitions;
            self.stats.crashes += r.crashes;
            if r.queue_len > self.stats.peak_queue_len {
                self.stats.peak_queue_len = r.queue_len;
            }
        }
        self.stats.store_live = store_live;
        row.loss = loss_sum / loss_n.max(1) as f64;
        row.checksum = h;
        // Next broadcast: a pure function of the merged state.
        self.broadcast = (h >> 40) as f64 * 1e-9
            + self.cloud_version as f64 * 1e-3;
        self.history.push(row);
        // Profile hand-off: fixed shard order (the pool re-ordered the
        // results), barrier stall relative to the straggler, busy time
        // attributed to each shard's owning worker. Observer-only.
        if profile {
            let mut profs: Vec<ShardWindowProfile> = reports
                .into_iter()
                .filter_map(|(_, p)| p)
                .collect();
            let last_done =
                profs.iter().map(|p| p.done_at_ns).max().unwrap_or(0);
            let mut busy = vec![0u64; self.pool.workers()];
            for p in &mut profs {
                p.barrier_stall_ns = last_done - p.done_at_ns;
                busy[self.pool.shard_worker(p.shard)] +=
                    p.advance_wall_ns;
            }
            let pool_profile = PoolWindowProfile {
                window: w,
                t0_sim: w as f64 * self.window,
                t1_sim: barrier,
                workers: self.pool.workers(),
                n_shards: self.pool.n_shards(),
                window_wall_ns: epoch
                    .map(|e| e.elapsed().as_nanos() as u64)
                    .unwrap_or(0),
                worker_busy_ns: busy,
            };
            let row = self.history.last().unwrap();
            if let Some(obs) = self.obs.as_mut() {
                obs.on_shard_barrier(row, &profs, &pool_profile);
            }
        }
        self.history.last().unwrap()
    }

    /// Run every remaining window; returns the full history.
    pub fn run(&mut self) -> &[WindowRow] {
        while self.next_window < self.windows {
            self.run_window();
        }
        &self.history
    }

    pub fn history(&self) -> &[WindowRow] {
        &self.history
    }

    pub fn stats(&self) -> &MergedStats {
        &self.stats
    }

    /// The run history as CSV text — the byte-equality surface for the
    /// determinism tests and the CI multithread-determinism job.
    pub fn csv_string(&self) -> String {
        let mut out = String::from(
            "window,sim_time,events,live,loss,energy,aggregates,\
             cloud_version,faults,checksum\n",
        );
        for r in &self.history {
            out.push_str(&format!(
                "{},{:.6},{},{},{:.9e},{:.9e},{},{},{},{:016x}\n",
                r.window,
                r.sim_time,
                r.events,
                r.live,
                r.loss,
                r.energy,
                r.aggregates,
                r.cloud_version,
                r.faults,
                r.checksum,
            ));
        }
        out
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.csv_string().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    fn run_spec(spec: &ShardSpec) -> (String, MergedStats) {
        let mut sim = ShardedDeviceSim::new(spec);
        sim.run();
        (sim.csv_string(), sim.stats().clone())
    }

    #[test]
    fn worker_count_is_bitwise_invisible() {
        let base = ShardSpec {
            devices: 96,
            edges: 8,
            shards: 4,
            p: 16,
            windows: 4,
            ..ShardSpec::default()
        };
        let (ref_csv, ref_stats) = run_spec(&base);
        assert!(ref_stats.events > 0, "simulation must actually run");
        assert!(ref_stats.aggregates > 0, "edges must aggregate");
        for workers in [2usize, 4, 8] {
            let spec = ShardSpec {
                workers,
                ..base.clone()
            };
            let (csv, stats) = run_spec(&spec);
            assert_eq!(csv, ref_csv, "workers={workers} diverged");
            assert_eq!(stats, ref_stats, "workers={workers} stats");
        }
    }

    #[test]
    fn queue_backend_is_bitwise_invisible() {
        let base = ShardSpec {
            devices: 64,
            edges: 4,
            shards: 2,
            p: 8,
            windows: 3,
            workers: 2,
            ..ShardSpec::default()
        };
        let (a, _) = run_spec(&ShardSpec {
            backend: QueueBackend::Binary,
            ..base.clone()
        });
        let (b, _) = run_spec(&ShardSpec {
            backend: QueueBackend::Calendar,
            ..base
        });
        assert_eq!(a, b, "queue backend leaked into the trajectory");
    }

    #[test]
    fn zero_churn_population_never_changes() {
        let spec = ShardSpec {
            devices: 48,
            edges: 4,
            p: 8,
            windows: 3,
            leave_prob: 0.0,
            join_prob: 0.0,
            ..ShardSpec::default()
        };
        let mut sim = ShardedDeviceSim::new(&spec);
        sim.run();
        for row in sim.history() {
            assert_eq!(row.live, 48);
        }
        assert_eq!(sim.stats().flips, 0);
        assert_eq!(sim.stats().voided, 0);
    }

    #[test]
    fn seeds_change_the_trajectory() {
        let base = ShardSpec {
            devices: 64,
            edges: 4,
            p: 8,
            windows: 3,
            ..ShardSpec::default()
        };
        let (a, _) = run_spec(&base);
        let (b, _) = run_spec(&ShardSpec {
            seed: base.seed + 1,
            ..base
        });
        assert_ne!(a, b, "seed must matter");
    }

    #[test]
    fn shard_count_is_part_of_the_topology() {
        // Different shard counts are *allowed* to give different
        // trajectories (RNG forking differs); what matters is that each
        // is internally deterministic.
        for shards in [1usize, 2, 4] {
            let spec = ShardSpec {
                devices: 64,
                edges: 8,
                shards,
                p: 8,
                windows: 2,
                ..ShardSpec::default()
            };
            let (a, _) = run_spec(&spec);
            let (b, _) = run_spec(&spec);
            assert_eq!(a, b, "shards={shards} not reproducible");
        }
    }

    fn chaos_spec() -> ShardSpec {
        ShardSpec {
            devices: 96,
            edges: 8,
            shards: 4,
            p: 16,
            windows: 5,
            outages: 2,
            outage_duration: 70.0,
            partitions: 1,
            partition_duration: 100.0,
            crash_storms: 1,
            crash_frac: 0.4,
            rejoin_delay: 50.0,
            ..ShardSpec::default()
        }
    }

    /// The worker-count / queue-backend bitwise guarantee extends to
    /// fault-injected runs: chaos is scheduled, never ambient.
    #[test]
    fn fault_injection_is_worker_and_backend_invariant() {
        let base = chaos_spec();
        let (ref_csv, ref_stats) = run_spec(&base);
        assert!(ref_stats.outages > 0, "outages must fire");
        assert!(ref_stats.partitions > 0, "partitions must fire");
        assert!(ref_stats.crashes > 0, "storms must crash devices");
        assert!(
            ref_csv.lines().skip(1).any(|l| {
                l.rsplit(',').nth(1).is_some_and(|f| f != "0")
            }),
            "faults column must be non-zero somewhere:\n{ref_csv}"
        );
        for (workers, backend) in [
            (4usize, QueueBackend::Auto),
            (1, QueueBackend::Calendar),
            (4, QueueBackend::Calendar),
        ] {
            let (csv, stats) = run_spec(&ShardSpec {
                workers,
                backend,
                ..base.clone()
            });
            assert_eq!(
                csv, ref_csv,
                "chaos diverged at workers={workers} {backend:?}"
            );
            assert_eq!(stats, ref_stats);
        }
    }

    /// Sixth no-op guarantee, sharded flavor: a zero-count fault config
    /// (with non-default durations — inert knobs) is bitwise identical
    /// to the default spec.
    #[test]
    fn zero_fault_plan_is_bitwise_noop() {
        let base = ShardSpec {
            devices: 96,
            edges: 8,
            shards: 4,
            p: 16,
            windows: 4,
            ..ShardSpec::default()
        };
        let armed = ShardSpec {
            outage_duration: 33.0,
            partition_duration: 44.0,
            crash_frac: 0.9,
            rejoin_delay: 5.0,
            ..base.clone()
        };
        let (a, sa) = run_spec(&base);
        let (b, sb) = run_spec(&armed);
        assert_eq!(a, b, "disabled fault layer must be bitwise invisible");
        assert_eq!(sa, sb);
        assert_eq!(sa.outages + sa.partitions + sa.crashes, 0);
    }

    #[test]
    fn faults_perturb_the_trajectory() {
        let calm = ShardSpec { windows: 5, ..chaos_spec() };
        let (with_faults, _) = run_spec(&calm);
        let (without, _) = run_spec(&ShardSpec {
            outages: 0,
            partitions: 0,
            crash_storms: 0,
            ..calm
        });
        assert_ne!(with_faults, without, "chaos must actually bite");
    }

    /// Property: the merged trajectory is independent of thread
    /// interleaving, even under seeded adversarial per-shard delays
    /// (rule 4 of the module doc).
    #[test]
    fn prop_merge_order_independent_of_interleaving() {
        check(
            "shard/merge_order_vs_interleaving",
            24,
            |g: &mut Gen| {
                let edges = g.usize_in(2, 6);
                let devices = edges * g.usize_in(3, 10);
                ShardSpec {
                    devices,
                    edges,
                    shards: g.usize_in(1, 4),
                    p: g.usize_in(4, 12),
                    window: 30.0,
                    windows: g.usize_in(2, 4),
                    seed: g.usize_in(1, 1 << 20) as u64,
                    leave_prob: if g.bool() { 0.1 } else { 0.0 },
                    join_prob: 0.4,
                    outages: g.usize_in(0, 2),
                    partitions: g.usize_in(0, 1),
                    crash_storms: g.usize_in(0, 1),
                    outage_duration: 40.0,
                    partition_duration: 50.0,
                    crash_frac: 0.3,
                    rejoin_delay: 25.0,
                    ..ShardSpec::default()
                }
            },
            |spec: &ShardSpec| {
                let (serial, _) = run_spec(spec);
                for workers in [2usize, 4] {
                    let adversarial = ShardSpec {
                        workers,
                        adversarial_delay_us: 300,
                        ..spec.clone()
                    };
                    let (par, _) = run_spec(&adversarial);
                    if par != serial {
                        return Err(format!(
                            "trajectory diverged at workers={workers} \
                             for {spec:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
