//! Edge-to-cloud communication model (paper Fig. 4).
//!
//! The paper's cloud sits in Silicon Valley; edges in Beijing (cn) see
//! ~10x the latency and a fraction of the bandwidth of edges in
//! Washington DC (us). Communication time grows linearly with model size
//! plus a per-transfer latency floor, with log-normal jitter:
//!     t = (latency + bytes/bandwidth) · LogNormal(0, σ)
//! Device↔edge LAN transfers are millisecond-scale and ignored (§2.3).

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Region {
    Cn,
    Us,
}

impl Region {
    pub fn name(self) -> &'static str {
        match self {
            Region::Cn => "cn",
            Region::Us => "us",
        }
    }
}

#[derive(Clone, Debug)]
pub struct NetworkModel {
    pub cn_latency: f64,
    pub cn_bandwidth: f64,
    pub us_latency: f64,
    pub us_bandwidth: f64,
    pub jitter: f64,
}

impl NetworkModel {
    pub fn from_config(sim: &crate::config::SimConfig) -> Self {
        NetworkModel {
            cn_latency: sim.cn_latency,
            cn_bandwidth: sim.cn_bandwidth,
            us_latency: sim.us_latency,
            us_bandwidth: sim.us_bandwidth,
            jitter: sim.comm_jitter,
        }
    }

    fn params(&self, region: Region) -> (f64, f64) {
        match region {
            Region::Cn => (self.cn_latency, self.cn_bandwidth),
            Region::Us => (self.us_latency, self.us_bandwidth),
        }
    }

    /// Mean edge→cloud time for a model of `bytes` (deterministic part).
    pub fn mean_comm_time(&self, region: Region, bytes: usize) -> f64 {
        let (lat, bw) = self.params(region);
        lat + bytes as f64 / bw
    }

    /// Mean one-way time with the region bandwidth scaled by `bw_scale`
    /// (the `link.{up,down}_bandwidth_scale` knobs: uplinks and downlinks
    /// can be provisioned asymmetrically).
    pub fn one_way_mean(
        &self,
        region: Region,
        bytes: usize,
        bw_scale: f64,
    ) -> f64 {
        let (lat, bw) = self.params(region);
        lat + bytes as f64 / (bw * bw_scale)
    }

    /// Sampled one-way transfer work (seconds of exclusive link time) for
    /// the transfer layer: mean with scaled bandwidth, log-normal jitter.
    pub fn one_way_time(
        &self,
        region: Region,
        bytes: usize,
        bw_scale: f64,
        rng: &mut Rng,
    ) -> f64 {
        self.one_way_mean(region, bytes, bw_scale)
            * rng.lognormal(0.0, self.jitter)
    }

    /// Sampled round-trip (upload + download ≈ 2x one way). Kept for the
    /// Fig. 4 harness; the engines now route per-direction transfers
    /// through `sim::link` instead.
    pub fn comm_time(
        &self,
        region: Region,
        bytes: usize,
        rng: &mut Rng,
    ) -> f64 {
        2.0 * self.mean_comm_time(region, bytes)
            * rng.lognormal(0.0, self.jitter)
    }
}

/// Bytes on the wire for a model of `params` f32 parameters.
pub fn model_bytes(params: usize) -> usize {
    params * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::util::stats;

    fn net() -> NetworkModel {
        NetworkModel::from_config(&ExperimentConfig::mnist().sim)
    }

    #[test]
    fn grows_with_model_size() {
        // Fig. 4: comm time increases with parameter count.
        let n = net();
        let small = n.mean_comm_time(Region::Cn, model_bytes(21_840));
        let big = n.mean_comm_time(Region::Cn, model_bytes(453_845));
        assert!(big > small * 1.5, "small {small} big {big}");
    }

    #[test]
    fn cn_slower_than_us() {
        // Fig. 4: overseas (cn→SV) link dominates the domestic one.
        let n = net();
        for &p in &[21_840usize, 453_845] {
            let cn = n.mean_comm_time(Region::Cn, model_bytes(p));
            let us = n.mean_comm_time(Region::Us, model_bytes(p));
            assert!(cn > 2.0 * us, "p={p}: cn {cn} us {us}");
        }
    }

    #[test]
    fn one_way_mean_scales_bandwidth_only() {
        let n = net();
        let bytes = model_bytes(100_000);
        let base = n.one_way_mean(Region::Us, bytes, 1.0);
        assert!((base - n.mean_comm_time(Region::Us, bytes)).abs() < 1e-12);
        // Doubling bandwidth halves the transfer part, not the latency.
        let fast = n.one_way_mean(Region::Us, bytes, 2.0);
        let transfer = base - n.us_latency;
        assert!((fast - (n.us_latency + transfer / 2.0)).abs() < 1e-9);
    }

    #[test]
    fn sampled_time_centers_on_mean() {
        let n = net();
        let mut rng = Rng::new(4);
        let bytes = model_bytes(21_840);
        let xs: Vec<f64> = (0..4000)
            .map(|_| n.comm_time(Region::Cn, bytes, &mut rng))
            .collect();
        let want = 2.0 * n.mean_comm_time(Region::Cn, bytes);
        let got = stats::mean(&xs);
        assert!((got - want).abs() / want < 0.1, "got {got} want {want}");
    }
}
