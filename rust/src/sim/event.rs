//! Deterministic discrete-event scheduler — the timing core of the
//! asynchronous HFL engine (`hfl::async_engine`).
//!
//! A binary heap of timestamped [`Event`]s popped in simulated-time order.
//! Equal-timestamp events are ordered by a *seeded* tie-break key drawn at
//! schedule time (plus a monotone insertion sequence as the last resort),
//! so the pop order is a pure function of the queue's seed and the schedule
//! call sequence: two queues built the same way replay identically, while
//! different seeds explore different-but-valid interleavings of simultaneous
//! events. This is what makes asynchronous runs reproducible from the single
//! experiment seed, the same property the synchronous engine gets from
//! threading one `Rng` everywhere.
//!
//! Event kinds mirror the actors of the HFL hierarchy:
//!  * `DeviceTrainDone`  — a device finished its local epochs and reports
//!    to its edge;
//!  * `EdgeAggregate`    — an edge closes its (sub-)round and aggregates;
//!  * `CloudAggregate`   — the cloud aggregates edge models (barrier in
//!    synchronous mode, a timer in semi-sync/async modes);
//!  * `MobilityFlip`     — the join/leave Markov process advances;
//!  * `Recluster`        — the membership subsystem (`hfl::membership`)
//!    re-clusters the live population after the active set drifted past
//!    the configured threshold, migrating devices between edges;
//!  * `TransferDone`     — an in-flight edge↔cloud transfer predicted by
//!    `sim::link::LinkManager` lands. Contention re-predictions leave
//!    stale `TransferDone`s in the queue; the link layer identifies the
//!    live one by bit-exact timestamp match, so poppers must route these
//!    through `LinkManager::poll` and drop the `None`s.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::rng::Rng;

/// A simulation event. Payloads are indices into the engine's topology;
/// all model/metric state lives in the engine, not the queue.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    DeviceTrainDone { device: usize, edge: usize },
    EdgeAggregate { edge: usize },
    CloudAggregate,
    MobilityFlip,
    /// Churn-driven re-clustering of the live population (scheduled when
    /// membership drift crosses `cluster.recluster_threshold`).
    Recluster,
    /// An in-flight transfer's predicted landing (id from the link layer).
    TransferDone { transfer: usize },
}

/// Heap entry: min-ordered by (time, tie, seq).
#[derive(Clone, Debug)]
struct Scheduled {
    time: f64,
    /// Seed-derived tie-break among equal timestamps.
    tie: u64,
    /// Insertion order; makes the order total even on tie collisions.
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        // Times are asserted finite on push, so total_cmp is total order.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.tie.cmp(&self.tie))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Seeded, deterministic event queue.
#[derive(Clone, Debug)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    rng: Rng,
    seq: u64,
    /// High-water mark of popped time; schedules may not precede it.
    now: f64,
}

impl EventQueue {
    pub fn new(seed: u64) -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            rng: Rng::new(seed ^ 0xe7e47),
            seq: 0,
            now: 0.0,
        }
    }

    /// Schedule `event` at absolute simulated time `time`.
    pub fn schedule(&mut self, time: f64, event: Event) {
        assert!(time.is_finite(), "event time must be finite ({time})");
        assert!(
            time >= self.now,
            "cannot schedule into the past: {time} < now {}",
            self.now
        );
        let tie = self.rng.next_u64();
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled {
            time,
            tie,
            seq,
            event,
        });
    }

    /// Earliest pending event time, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.time)
    }

    /// Pop the earliest event; advances the queue's notion of `now`.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let s = self.heap.pop()?;
        self.now = s.time;
        Some((s.time, s.event))
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drop all pending events (keeps seed stream and `now`).
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut EventQueue) -> Vec<(f64, Event)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push(e);
        }
        out
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new(1);
        q.schedule(3.0, Event::CloudAggregate);
        q.schedule(1.0, Event::MobilityFlip);
        q.schedule(2.0, Event::EdgeAggregate { edge: 0 });
        let times: Vec<f64> = drain(&mut q).iter().map(|e| e.0).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn equal_timestamps_replay_identically_per_seed() {
        let build = |seed: u64| {
            let mut q = EventQueue::new(seed);
            for d in 0..64 {
                q.schedule(
                    5.0,
                    Event::DeviceTrainDone {
                        device: d,
                        edge: d % 4,
                    },
                );
            }
            drain(&mut q)
        };
        // Same seed -> byte-identical pop order.
        assert_eq!(build(7), build(7));
        // Different seed -> same multiset, (almost surely) different order.
        let a = build(7);
        let b = build(8);
        assert_ne!(
            a, b,
            "64 equal-timestamp events should shuffle across seeds"
        );
    }

    #[test]
    fn tie_break_is_not_insertion_order() {
        // A seeded queue must be able to pop simultaneous events in an
        // order other than FIFO (otherwise the seed does nothing).
        let mut q = EventQueue::new(3);
        for d in 0..32 {
            q.schedule(1.0, Event::DeviceTrainDone { device: d, edge: 0 });
        }
        let devs: Vec<usize> = drain(&mut q)
            .iter()
            .map(|(_, e)| match e {
                Event::DeviceTrainDone { device, .. } => *device,
                _ => unreachable!(),
            })
            .collect();
        assert_ne!(devs, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut q = EventQueue::new(9);
        q.schedule(1.0, Event::MobilityFlip);
        q.schedule(4.0, Event::CloudAggregate);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 1.0);
        assert_eq!(q.now(), 1.0);
        // Scheduling relative to popped time is fine; the past is not.
        q.schedule(2.0, Event::EdgeAggregate { edge: 1 });
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (2.0, Event::EdgeAggregate { edge: 1 }));
        assert_eq!(q.pop().unwrap().0, 4.0);
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "cannot schedule into the past")]
    fn rejects_past_schedules() {
        let mut q = EventQueue::new(2);
        q.schedule(5.0, Event::CloudAggregate);
        q.pop();
        q.schedule(1.0, Event::MobilityFlip);
    }

    #[test]
    fn ten_thousand_events_stay_sorted() {
        let mut q = EventQueue::new(11);
        let mut rng = Rng::new(12);
        for i in 0..10_000 {
            // Coarse times force many collisions through the tie-break.
            let t = (rng.below(512)) as f64 * 0.25;
            q.schedule(t, Event::DeviceTrainDone { device: i, edge: i % 8 });
        }
        assert_eq!(q.len(), 10_000);
        let mut last = f64::NEG_INFINITY;
        let mut n = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            n += 1;
        }
        assert_eq!(n, 10_000);
    }
}
