//! Deterministic discrete-event scheduler — the timing core of the
//! asynchronous HFL engine (`hfl::async_engine`) and the sharded
//! execution layer (`sim::shard`).
//!
//! A priority queue of timestamped [`Event`]s popped in simulated-time
//! order. Equal-timestamp events are ordered by a *seeded* tie-break key
//! drawn at schedule time (plus a monotone insertion sequence as the last
//! resort), so the pop order is a pure function of the queue's seed and
//! the schedule call sequence: two queues built the same way replay
//! identically, while different seeds explore different-but-valid
//! interleavings of simultaneous events. This is what makes asynchronous
//! runs reproducible from the single experiment seed, the same property
//! the synchronous engine gets from threading one `Rng` everywhere.
//!
//! # Backends
//!
//! The `(time, tie, seq)` key is a *total* order, so any correct priority
//! queue yields the same pop sequence — the storage backend is bitwise
//! invisible. Two are provided behind the one [`EventQueue`] API
//! (selected by [`QueueBackend`], config knob `sim.queue_backend`):
//!
//! * **Binary** — `std::collections::BinaryHeap`. O(log n) everywhere;
//!   the right default at engine scale (thousands of events).
//! * **Calendar** — a calendar queue: events bucketed by coarse time
//!   slot, buckets sorted lazily when the cursor reaches them. Past ~1M
//!   pending events the binary heap's cache-hostile sift dominates an
//!   async-run profile; the calendar's bucket-local sorts stay cache
//!   resident. `Auto` picks it above [`CALENDAR_THRESHOLD`] expected
//!   events.
//!
//! Event kinds mirror the actors of the HFL hierarchy:
//!  * `DeviceTrainDone`  — a device finished its local epochs and reports
//!    to its edge;
//!  * `EdgeAggregate`    — an edge closes its (sub-)round and aggregates;
//!  * `CloudAggregate`   — the cloud aggregates edge models (barrier in
//!    synchronous mode, a timer in semi-sync/async modes);
//!  * `MobilityFlip`     — the join/leave Markov process advances;
//!  * `Recluster`        — the membership subsystem (`hfl::membership`)
//!    re-clusters the live population after the active set drifted past
//!    the configured threshold, migrating devices between edges;
//!  * `TransferDone`     — an in-flight edge↔cloud transfer predicted by
//!    `sim::link::LinkManager` lands. Contention re-predictions leave
//!    stale `TransferDone`s in the queue; the link layer identifies the
//!    live one by bit-exact timestamp match, so poppers must route these
//!    through `LinkManager::poll` and drop the `None`s;
//!  * `EdgeOutage` / `Partition` / `CrashStorm` — injected failures
//!    scheduled from a seeded `hfl::lifecycle::FaultPlan`: an edge
//!    server going down/up, an edge↔cloud partition over a bitmask of
//!    edges, and a mid-round device crash/rejoin wave selected by a
//!    pure integer predicate. Faults are scheduled events, never
//!    ambient state, so chaos runs stay bitwise reproducible.
//!
//! # The engine's ctrl/shard queue split
//!
//! The sharded `AsyncHflEngine` loop (`hfl::engine_shard`) partitions
//! these kinds across queues: `CloudAggregate`, `MobilityFlip`,
//! `Recluster` and the three fault kinds live on one serial **ctrl**
//! queue (they are the only cross-shard couplings, handled as
//! barriers), while `DeviceTrainDone` / `EdgeAggregate` /
//! `TransferDone` live on per-shard queues seeded per shard. Each
//! queue's pop order is still a pure function of its own seed and
//! schedule sequence, so the split trajectory is deterministic — and
//! the backend invisibility above holds per queue, letting
//! `sim.queue_backend` apply to ctrl and shard heaps alike.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BinaryHeap};

use crate::util::rng::Rng;

/// A simulation event. Payloads are indices into the engine's topology;
/// all model/metric state lives in the engine, not the queue. `Copy` on
/// purpose: events move through schedule/pop/re-schedule cycles (the
/// link layer's re-prediction pattern) as plain registers — no boxing,
/// no per-hop allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    DeviceTrainDone { device: usize, edge: usize },
    EdgeAggregate { edge: usize },
    CloudAggregate,
    MobilityFlip,
    /// Churn-driven re-clustering of the live population (scheduled when
    /// membership drift crosses `cluster.recluster_threshold`).
    Recluster,
    /// An in-flight transfer's predicted landing (id from the link layer).
    TransferDone { transfer: usize },
    /// An edge server fails (`up == false`) or recovers (`up == true`).
    /// Scheduled from a seeded `hfl::lifecycle::FaultPlan` — faults are
    /// events, never ambient state, so chaos runs replay bitwise.
    EdgeOutage { edge: usize, up: bool },
    /// A network partition severs (`up == false`) or heals
    /// (`up == true`) the edge↔cloud path of every edge whose
    /// `index % 64` bit is set in `mask`.
    Partition { mask: u64, up: bool },
    /// A mid-round crash (`up == false`) / rejoin (`up == true`) storm:
    /// device `d` is hit iff `hfl::lifecycle::storm_hits(seed, d,
    /// frac_bits)` — a pure integer predicate, so the crash set and the
    /// rejoin set are identical and worker-count invariant.
    CrashStorm { seed: u64, frac_bits: u32, up: bool },
}

/// Storage backend selector for [`EventQueue`] (`sim.queue_backend`).
/// Backend choice never changes a pop sequence — only its speed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueBackend {
    /// Binary below [`CALENDAR_THRESHOLD`] expected events, calendar
    /// above (the default).
    Auto,
    Binary,
    Calendar,
}

impl QueueBackend {
    pub fn name(self) -> &'static str {
        match self {
            QueueBackend::Auto => "auto",
            QueueBackend::Binary => "binary",
            QueueBackend::Calendar => "calendar",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "auto" => Ok(QueueBackend::Auto),
            "binary" | "heap" => Ok(QueueBackend::Binary),
            "calendar" => Ok(QueueBackend::Calendar),
            _ => anyhow::bail!(
                "unknown queue backend '{s}' (auto|binary|calendar)"
            ),
        }
    }
}

/// Pending-event count above which `QueueBackend::Auto` picks the
/// calendar backend (the profile point where `BinaryHeap` sift traffic
/// starts dominating a 1M+-device drain).
pub const CALENDAR_THRESHOLD: usize = 1 << 20;

/// Heap entry: min-ordered by (time, tie, seq).
#[derive(Clone, Copy, Debug)]
struct Scheduled {
    time: f64,
    /// Seed-derived tie-break among equal timestamps.
    tie: u64,
    /// Insertion order; makes the order total even on tie collisions.
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        // Times are asserted finite on push, so total_cmp is total order.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.tie.cmp(&self.tie))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One calendar day: events of one coarse time slot. Unsorted while the
/// cursor is elsewhere (O(1) push); sorted once when the slot becomes
/// the front, after which the earliest entry sits at the *end* of the
/// Vec (the [`Scheduled`] order is reversed) and pops are O(1).
#[derive(Clone, Debug, Default)]
struct Bucket {
    items: Vec<Scheduled>,
    sorted: bool,
    /// Earliest time in the bucket — maintained on every push/pop so
    /// `peek_time` needs no sort and no scan.
    min_time: f64,
}

/// Calendar-queue backend: buckets keyed by `floor(time / width)` in a
/// `BTreeMap`, so the first entry always holds the globally earliest
/// event (the bucket index is monotone in time) and far-future or
/// sparse schedules cost one map insert instead of a ring resize.
#[derive(Clone, Debug)]
struct CalendarQueue {
    buckets: BTreeMap<u64, Bucket>,
    /// Coarse slot width in simulated seconds.
    width: f64,
    len: usize,
    /// Emptied bucket Vecs, kept warm for reuse (drain scratch pool —
    /// steady-state drains allocate nothing).
    spare: Vec<Vec<Scheduled>>,
}

/// Bucket-Vec pool cap: enough to absorb a drain wave without holding
/// unbounded memory afterwards.
const SPARE_BUCKETS: usize = 32;

impl CalendarQueue {
    fn new() -> Self {
        CalendarQueue {
            buckets: BTreeMap::new(),
            width: 1.0,
            len: 0,
            spare: Vec::new(),
        }
    }

    fn slot(&self, time: f64) -> u64 {
        // Saturating float->int cast; monotone, so bucket order is time
        // order even at the clamp.
        (time / self.width) as u64
    }

    fn push(&mut self, s: Scheduled) {
        let key = self.slot(s.time);
        let spare = &mut self.spare;
        let b = self.buckets.entry(key).or_insert_with(|| Bucket {
            items: spare.pop().unwrap_or_default(),
            sorted: false,
            min_time: f64::INFINITY,
        });
        if s.time < b.min_time {
            b.min_time = s.time;
        }
        if b.sorted {
            // Active (front) bucket: keep it sorted. Near-now inserts
            // land near the tail, so the memmove is short.
            let at = match b.items.binary_search(&s) {
                Ok(i) | Err(i) => i,
            };
            b.items.insert(at, s);
        } else {
            b.items.push(s);
        }
        self.len += 1;
    }

    fn peek_time(&self) -> Option<f64> {
        self.buckets.values().next().map(|b| b.min_time)
    }

    fn pop(&mut self) -> Option<Scheduled> {
        let mut entry = self.buckets.first_entry()?;
        let b = entry.get_mut();
        if !b.sorted {
            // First visit: one bucket-local sort. The reversed Scheduled
            // order puts the earliest event last, so pops are Vec::pop.
            b.items.sort_unstable();
            b.sorted = true;
        }
        let s = b.items.pop().expect("empty bucket left in calendar");
        if let Some(next) = b.items.last() {
            b.min_time = next.time;
        } else {
            let mut v = entry.remove().items;
            if self.spare.len() < SPARE_BUCKETS {
                v.clear();
                self.spare.push(v);
            }
        }
        self.len -= 1;
        Some(s)
    }

    fn clear(&mut self) {
        while let Some((_, b)) = self.buckets.pop_first() {
            if self.spare.len() < SPARE_BUCKETS {
                let mut v = b.items;
                v.clear();
                self.spare.push(v);
            }
        }
        self.len = 0;
    }
}

#[derive(Clone, Debug)]
enum Heap {
    Binary(BinaryHeap<Scheduled>),
    Calendar(CalendarQueue),
}

/// Seeded, deterministic event queue (see module doc for the ordering
/// contract and the backend choices).
#[derive(Clone, Debug)]
pub struct EventQueue {
    heap: Heap,
    rng: Rng,
    seq: u64,
    /// High-water mark of popped time; schedules may not precede it.
    now: f64,
}

impl EventQueue {
    pub fn new(seed: u64) -> Self {
        Self::with_capacity(seed, 0)
    }

    /// Binary-backed queue with `capacity` preallocated entries — size it
    /// from the topology (≈ devices + edges + in-flight transfers) so a
    /// dispatch wave never reallocates mid-drain.
    pub fn with_capacity(seed: u64, capacity: usize) -> Self {
        EventQueue {
            heap: Heap::Binary(BinaryHeap::with_capacity(capacity)),
            rng: Rng::new(seed ^ 0xe7e47),
            seq: 0,
            now: 0.0,
        }
    }

    /// Queue sized and backed for an expected event population:
    /// `Auto` switches to the calendar backend at
    /// [`CALENDAR_THRESHOLD`] expected events. The seeded tie-break
    /// stream is identical across backends, so the choice is bitwise
    /// invisible to the simulation.
    pub fn for_scale(
        seed: u64,
        expected_events: usize,
        backend: QueueBackend,
    ) -> Self {
        let calendar = match backend {
            QueueBackend::Auto => expected_events >= CALENDAR_THRESHOLD,
            QueueBackend::Binary => false,
            QueueBackend::Calendar => true,
        };
        if calendar {
            EventQueue {
                heap: Heap::Calendar(CalendarQueue::new()),
                rng: Rng::new(seed ^ 0xe7e47),
                seq: 0,
                now: 0.0,
            }
        } else {
            Self::with_capacity(seed, expected_events)
        }
    }

    /// Active backend ("binary" | "calendar") — diagnostics only.
    pub fn backend_name(&self) -> &'static str {
        match self.heap {
            Heap::Binary(_) => "binary",
            Heap::Calendar(_) => "calendar",
        }
    }

    /// Schedule `event` at absolute simulated time `time`.
    pub fn schedule(&mut self, time: f64, event: Event) {
        assert!(time.is_finite(), "event time must be finite ({time})");
        assert!(
            time >= self.now,
            "cannot schedule into the past: {time} < now {}",
            self.now
        );
        let tie = self.rng.next_u64();
        let seq = self.seq;
        self.seq += 1;
        let s = Scheduled {
            time,
            tie,
            seq,
            event,
        };
        match &mut self.heap {
            Heap::Binary(h) => h.push(s),
            Heap::Calendar(c) => c.push(s),
        }
    }

    /// Earliest pending event time, if any.
    pub fn peek_time(&self) -> Option<f64> {
        match &self.heap {
            Heap::Binary(h) => h.peek().map(|s| s.time),
            Heap::Calendar(c) => c.peek_time(),
        }
    }

    /// Pop the earliest event; advances the queue's notion of `now`.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let s = match &mut self.heap {
            Heap::Binary(h) => h.pop()?,
            Heap::Calendar(c) => c.pop()?,
        };
        self.now = s.time;
        Some((s.time, s.event))
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        match &self.heap {
            Heap::Binary(h) => h.len(),
            Heap::Calendar(c) => c.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all pending events (keeps seed stream and `now`).
    pub fn clear(&mut self) {
        match &mut self.heap {
            Heap::Binary(h) => h.clear(),
            Heap::Calendar(c) => c.clear(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut EventQueue) -> Vec<(f64, Event)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push(e);
        }
        out
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new(1);
        q.schedule(3.0, Event::CloudAggregate);
        q.schedule(1.0, Event::MobilityFlip);
        q.schedule(2.0, Event::EdgeAggregate { edge: 0 });
        let times: Vec<f64> = drain(&mut q).iter().map(|e| e.0).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn equal_timestamps_replay_identically_per_seed() {
        let build = |seed: u64| {
            let mut q = EventQueue::new(seed);
            for d in 0..64 {
                q.schedule(
                    5.0,
                    Event::DeviceTrainDone {
                        device: d,
                        edge: d % 4,
                    },
                );
            }
            drain(&mut q)
        };
        // Same seed -> byte-identical pop order.
        assert_eq!(build(7), build(7));
        // Different seed -> same multiset, (almost surely) different order.
        let a = build(7);
        let b = build(8);
        assert_ne!(
            a, b,
            "64 equal-timestamp events should shuffle across seeds"
        );
    }

    #[test]
    fn tie_break_is_not_insertion_order() {
        // A seeded queue must be able to pop simultaneous events in an
        // order other than FIFO (otherwise the seed does nothing).
        let mut q = EventQueue::new(3);
        for d in 0..32 {
            q.schedule(1.0, Event::DeviceTrainDone { device: d, edge: 0 });
        }
        let devs: Vec<usize> = drain(&mut q)
            .iter()
            .map(|(_, e)| match e {
                Event::DeviceTrainDone { device, .. } => *device,
                _ => unreachable!(),
            })
            .collect();
        assert_ne!(devs, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut q = EventQueue::new(9);
        q.schedule(1.0, Event::MobilityFlip);
        q.schedule(4.0, Event::CloudAggregate);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 1.0);
        assert_eq!(q.now(), 1.0);
        // Scheduling relative to popped time is fine; the past is not.
        q.schedule(2.0, Event::EdgeAggregate { edge: 1 });
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (2.0, Event::EdgeAggregate { edge: 1 }));
        assert_eq!(q.pop().unwrap().0, 4.0);
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "cannot schedule into the past")]
    fn rejects_past_schedules() {
        let mut q = EventQueue::new(2);
        q.schedule(5.0, Event::CloudAggregate);
        q.pop();
        q.schedule(1.0, Event::MobilityFlip);
    }

    #[test]
    fn ten_thousand_events_stay_sorted() {
        let mut q = EventQueue::new(11);
        let mut rng = Rng::new(12);
        for i in 0..10_000 {
            // Coarse times force many collisions through the tie-break.
            let t = (rng.below(512)) as f64 * 0.25;
            q.schedule(t, Event::DeviceTrainDone { device: i, edge: i % 8 });
        }
        assert_eq!(q.len(), 10_000);
        let mut last = f64::NEG_INFINITY;
        let mut n = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            n += 1;
        }
        assert_eq!(n, 10_000);
    }

    /// The backend contract: a binary and a calendar queue fed the same
    /// seed and schedule sequence pop the same events at the same times
    /// in the same order — including through interleaved pops, ties,
    /// and the link layer's re-prediction pattern.
    #[test]
    fn backends_are_bitwise_equivalent() {
        let run = |backend: QueueBackend| {
            let mut q = EventQueue::for_scale(99, 4096, backend);
            let mut out = Vec::new();
            // Dense tie-heavy fill.
            for i in 0..2000usize {
                let t = ((i * 7919) % 37) as f64 * 0.5;
                q.schedule(
                    t,
                    Event::DeviceTrainDone {
                        device: i,
                        edge: i % 8,
                    },
                );
            }
            // Interleave pops with re-predictions (pop one, push one at
            // t + delta) and far-future sparse events.
            q.schedule(1.0e7, Event::CloudAggregate);
            q.schedule(2.5e4, Event::MobilityFlip);
            let mut budget = 1500usize;
            while let Some((t, ev)) = q.pop() {
                out.push((t, ev));
                if budget > 0 {
                    if let Event::DeviceTrainDone { device, edge } = ev {
                        q.schedule(
                            t + 0.25 * ((device % 5) as f64),
                            Event::TransferDone {
                                transfer: device ^ edge,
                            },
                        );
                        budget -= 1;
                    }
                }
            }
            out
        };
        let a = run(QueueBackend::Binary);
        let b = run(QueueBackend::Calendar);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                x.0.to_bits(),
                y.0.to_bits(),
                "time diverged at pop {i}"
            );
            assert_eq!(x.1, y.1, "event diverged at pop {i}");
        }
    }

    #[test]
    fn calendar_handles_same_slot_inserts_after_activation() {
        // Push into the *front* (already sorted) bucket mid-drain: the
        // sorted-insert path must keep the order exact.
        let mut q = EventQueue::for_scale(5, 0, QueueBackend::Calendar);
        for d in 0..16 {
            q.schedule(0.5, Event::DeviceTrainDone { device: d, edge: 0 });
        }
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 0.5);
        // Same slot, later time; and same slot, same time (tie).
        q.schedule(0.9, Event::EdgeAggregate { edge: 1 });
        q.schedule(0.5, Event::EdgeAggregate { edge: 2 });
        let rest = drain(&mut q);
        assert_eq!(rest.len(), 17);
        let mut last = 0.0f64;
        for (t, _) in &rest {
            assert!(*t >= last);
            last = *t;
        }
        assert_eq!(rest.last().unwrap().0, 0.9);
    }

    #[test]
    fn for_scale_auto_selects_by_threshold() {
        let small = EventQueue::for_scale(1, 1024, QueueBackend::Auto);
        assert_eq!(small.backend_name(), "binary");
        let big =
            EventQueue::for_scale(1, CALENDAR_THRESHOLD, QueueBackend::Auto);
        assert_eq!(big.backend_name(), "calendar");
        assert_eq!(
            EventQueue::for_scale(1, 0, QueueBackend::Calendar)
                .backend_name(),
            "calendar"
        );
        // Capacity/backend choice never touches the tie-break stream:
        // all constructions replay the same order.
        let fill = |mut q: EventQueue| {
            for d in 0..64 {
                q.schedule(
                    1.0,
                    Event::DeviceTrainDone { device: d, edge: 0 },
                );
            }
            drain(&mut q)
        };
        let a = fill(EventQueue::new(7));
        let b = fill(EventQueue::with_capacity(7, 4096));
        let c = fill(EventQueue::for_scale(7, 1 << 21, QueueBackend::Auto));
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn queue_backend_parse_roundtrip() {
        for b in
            [QueueBackend::Auto, QueueBackend::Binary, QueueBackend::Calendar]
        {
            assert_eq!(QueueBackend::parse(b.name()).unwrap(), b);
        }
        assert_eq!(
            QueueBackend::parse("heap").unwrap(),
            QueueBackend::Binary
        );
        assert!(QueueBackend::parse("bogus").is_err());
    }

    #[test]
    fn calendar_clear_and_reuse() {
        let mut q = EventQueue::for_scale(3, 0, QueueBackend::Calendar);
        for i in 0..100 {
            q.schedule(i as f64, Event::TransferDone { transfer: i });
        }
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.schedule(5.0, Event::CloudAggregate);
        assert_eq!(q.pop().unwrap().0, 5.0);
        assert!(q.pop().is_none());
    }
}
