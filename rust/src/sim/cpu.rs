//! Per-device compute model (paper Fig. 3).
//!
//! Each device carries an interference level u ∈ [0.05, 0.95] — the CPU
//! share consumed by co-running programs (stress-ng in the paper's
//! profiling). Per-SGD-batch time follows
//!     t = base · (1 + κ · u/(1-u)) · LogNormal(0, σ)
//! which reproduces Fig. 3's two observations: training time grows
//! super-linearly with CPU usage, and fluctuation grows with it too (the
//! governor + interference noise). The level itself random-walks (AR(1))
//! around the device's base — the "dynamic available CPU resources" of
//! §2.3.
//!
//! The paper's population: 5 interference classes from 10% to 50%, 10
//! devices per class (§4.1).

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct CpuModel {
    /// Device's long-run interference level.
    pub base_usage: f64,
    /// Current (wandering) level.
    pub usage: f64,
    /// Base per-batch seconds at zero interference.
    pub base_time: f64,
    /// Sensitivity κ.
    pub kappa: f64,
    /// Log-normal jitter σ.
    pub jitter: f64,
    /// Conservative-governor frequency band, GHz (paper: 0.6–1.5 GHz).
    pub freq_min: f64,
    pub freq_max: f64,
    rng: Rng,
}

impl CpuModel {
    pub fn new(
        base_usage: f64,
        base_time: f64,
        kappa: f64,
        jitter: f64,
        rng: Rng,
    ) -> Self {
        CpuModel {
            base_usage,
            usage: base_usage,
            base_time,
            kappa,
            jitter,
            freq_min: 0.6,
            freq_max: 1.5,
            rng,
        }
    }

    /// Paper §4.1 population: class c in 0..5 → 10%..50% interference.
    pub fn paper_class(c: usize) -> f64 {
        0.10 + 0.10 * (c % 5) as f64
    }

    /// AR(1) wander of the interference level (call once per epoch).
    pub fn step_usage(&mut self) {
        let noise = self.rng.normal() * 0.04;
        self.usage = (0.9 * self.usage + 0.1 * self.base_usage + noise)
            .clamp(0.05, 0.95);
    }

    /// Slowdown multiplier at the current usage.
    pub fn slowdown(&self) -> f64 {
        1.0 + self.kappa * self.usage / (1.0 - self.usage)
    }

    /// Seconds for one SGD minibatch right now (stochastic).
    pub fn sgd_time(&mut self) -> f64 {
        let jitter = self.rng.lognormal(0.0, self.jitter * (1.0 + self.usage));
        self.base_time * self.slowdown() * jitter
    }

    /// Conservative-governor clock: interference pushes the governor up.
    pub fn frequency_ghz(&self) -> f64 {
        self.freq_min + (self.freq_max - self.freq_min) * self.usage
    }

    /// Effective GFLOPS available to the training task.
    pub fn available_gflops(&self) -> f64 {
        // 4-wide NEON-ish FLOPs/cycle on the free share of the CPU.
        4.0 * self.frequency_ghz() * (1.0 - self.usage)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn model(u: f64, seed: u64) -> CpuModel {
        CpuModel::new(u, 2.0, 1.2, 0.18, Rng::new(seed))
    }

    #[test]
    fn time_grows_with_usage() {
        // Fig. 3a shape: mean per-batch time monotone in interference.
        let mut means = Vec::new();
        for &u in &[0.1, 0.3, 0.5, 0.7, 0.9] {
            let mut m = model(u, 42);
            let xs: Vec<f64> = (0..2000).map(|_| m.sgd_time()).collect();
            means.push(stats::mean(&xs));
        }
        for w in means.windows(2) {
            assert!(w[1] > w[0], "not monotone: {means:?}");
        }
    }

    #[test]
    fn fluctuation_grows_with_usage() {
        // Fig. 3 error bars: relative spread increases with usage.
        let spread = |u: f64| {
            let mut m = model(u, 7);
            let xs: Vec<f64> = (0..4000).map(|_| m.sgd_time()).collect();
            stats::std(&xs) / stats::mean(&xs)
        };
        assert!(spread(0.9) > 1.5 * spread(0.1));
    }

    #[test]
    fn usage_stays_in_bounds_under_wander() {
        let mut m = model(0.5, 9);
        for _ in 0..10_000 {
            m.step_usage();
            assert!((0.05..=0.95).contains(&m.usage));
        }
    }

    #[test]
    fn wander_stays_near_base() {
        let mut m = model(0.3, 11);
        let mut xs = Vec::new();
        for _ in 0..5_000 {
            m.step_usage();
            xs.push(m.usage);
        }
        let mean = stats::mean(&xs);
        assert!((mean - 0.3).abs() < 0.05, "mean usage {mean}");
    }

    #[test]
    fn paper_classes_cover_10_to_50_percent() {
        let us: Vec<f64> = (0..5).map(CpuModel::paper_class).collect();
        assert!((us[0] - 0.10).abs() < 1e-12);
        assert!((us[4] - 0.50).abs() < 1e-12);
    }

    #[test]
    fn gflops_decreases_with_usage() {
        assert!(
            model(0.1, 1).available_gflops()
                > model(0.8, 1).available_gflops()
        );
    }
}
