//! # Arena: learning-based synchronization for hierarchical federated learning
//!
//! A rust + JAX + Pallas reproduction of *"Arena: A Learning-based
//! Synchronization Scheme for Hierarchical Federated Learning"* (Qi et al.,
//! cs.DC 2023). The rust coordinator owns the HFL hierarchy, the testbed
//! simulation and the PPO control loop; all tensor compute (device SGD,
//! aggregation, PCA projection, PPO updates) runs through AOT-lowered
//! XLA artifacts built once by `python/compile/aot.py` and executed via
//! PJRT — python is never on the hot path.
//!
//! See DESIGN.md for the full module map and per-figure experiment index.

pub mod baselines;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod data;
pub mod exp;
pub mod hfl;
pub mod linalg;
pub mod nn;
pub mod obs;
pub mod pca;
pub mod runtime;
pub mod sim;
pub mod util;

pub mod agent;

pub use config::ExperimentConfig;
