//! `arena` CLI: the L3 coordinator launcher.

use anyhow::Result;

fn main() -> Result<()> {
    // Silence TfrtCpuClient created/destroyed chatter unless asked for.
    if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
        std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    }
    arena::cli::run(std::env::args().skip(1).collect())
}
