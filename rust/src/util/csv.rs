//! CSV writer for experiment results (`results/*.csv`): the figure/table
//! harnesses emit one row per measured point so the paper plots can be
//! regenerated with any external plotting tool.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create(
        path: impl AsRef<Path>,
        header: &[&str],
    ) -> std::io::Result<Self> {
        Self::create_with_comment(path, None, header)
    }

    /// Like [`CsvWriter::create`], with an optional `#`-prefixed comment
    /// line above the header (e.g. a schema version marker). Consumers
    /// that split on commas skip it via the leading `#`.
    pub fn create_with_comment(
        path: impl AsRef<Path>,
        comment: Option<&str>,
        header: &[&str],
    ) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        if let Some(c) = comment {
            writeln!(out, "# {c}")?;
        }
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        assert_eq!(fields.len(), self.cols, "csv row width mismatch");
        let escaped: Vec<String> = fields.iter().map(|f| escape(f)).collect();
        writeln!(self.out, "{}", escaped.join(","))
    }

    /// Convenience: first column is a label, the rest are numbers.
    pub fn row_mixed(
        &mut self,
        label: &str,
        nums: &[f64],
    ) -> std::io::Result<()> {
        let mut fields = vec![label.to_string()];
        fields.extend(nums.iter().map(|x| format_num(*x)));
        self.row(&fields)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

pub fn format_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6}")
    }
}

fn escape(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join("arena_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["x,y".into(), "1".into()]).unwrap();
        w.row_mixed("plain", &[2.5]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n\"x,y\",1\nplain,2.500000\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn comment_line_precedes_header() {
        let dir = std::env::temp_dir().join("arena_csv_test3");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create_with_comment(
            &path,
            Some("schema_version=1"),
            &["a"],
        )
        .unwrap();
        w.row(&["1".into()]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "# schema_version=1\na\n1\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn rejects_wrong_width() {
        let dir = std::env::temp_dir().join("arena_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = w.row(&["only-one".into()]);
    }
}
