//! Self-contained substrate utilities (the build environment is offline, so
//! PRNG, JSON, CSV, stats, thread pool, property testing and micro-bench
//! harness are implemented in-crate rather than pulled from crates.io).

pub mod csv;
pub mod json;
pub mod microbench;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod threadpool;
