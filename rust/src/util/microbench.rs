//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by `rust/benches/*.rs` (built with `harness = false`) and by the
//! §Perf pass: warmup, fixed-duration sampling, and a summary line with
//! mean / p50 / p99 per iteration. Set `ARENA_BENCH_FAST=1` to shrink
//! sample time (CI smoke mode).

use std::time::{Duration, Instant};

use super::stats;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns)
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

fn budget() -> (Duration, Duration) {
    if std::env::var("ARENA_BENCH_FAST").is_ok() {
        (Duration::from_millis(50), Duration::from_millis(200))
    } else {
        (Duration::from_millis(300), Duration::from_secs(2))
    }
}

/// Benchmark `f`, returning per-iteration timing statistics.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    let (warmup, sample) = budget();
    // Warmup.
    let start = Instant::now();
    let mut warm_iters = 0u64;
    while start.elapsed() < warmup || warm_iters == 0 {
        f();
        warm_iters += 1;
    }
    // Estimate batch size so each timed sample is ~1ms.
    let per = warmup.as_nanos() as f64 / warm_iters as f64;
    let batch = ((1e6 / per).ceil() as u64).max(1);
    let mut samples = Vec::new();
    let mut iters = 0u64;
    let start = Instant::now();
    while start.elapsed() < sample || samples.is_empty() {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
        iters += batch;
    }
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p99_ns: stats::percentile(&samples, 99.0),
    };
    res.report();
    res
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        std::env::set_var("ARENA_BENCH_FAST", "1");
        let r = bench("noop-ish", || {
            black_box((0..100u64).sum::<u64>());
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }
}
