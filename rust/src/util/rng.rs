//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core, with the
//! distribution helpers the simulator and the agent need (uniform, normal
//! via Box–Muller, log-normal, shuffle, weighted choice).
//!
//! Every stochastic component of the system takes an explicit `Rng` so runs
//! are reproducible from a single seed in the experiment config.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded through SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (e.g. one per device) from this rng.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (caches the second variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with the given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Index sampled proportionally to non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive total weight");
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample k distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Dirichlet(alpha) sample of dimension k via Gamma(alpha,1) draws
    /// (Marsaglia–Tsang, with the alpha<1 boost).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in g.iter_mut() {
            *x /= sum;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(4);
        for &alpha in &[0.1, 0.5, 1.0, 5.0] {
            let d = r.dirichlet(alpha, 10);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(50, 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn weighted_prefers_heavy_index() {
        let mut r = Rng::new(6);
        let w = [0.05, 0.05, 0.9];
        let mut heavy = 0;
        for _ in 0..10_000 {
            if r.weighted(&w) == 2 {
                heavy += 1;
            }
        }
        assert!(heavy > 8_500, "heavy={heavy}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
