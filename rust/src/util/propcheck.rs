//! Minimal property-based testing harness (proptest is unavailable in the
//! offline build environment).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` inputs produced
//! by `gen` from independent deterministic seeds; on failure it retries the
//! failing input with progressively "smaller" regenerations (shrink-lite:
//! the generator receives a shrink level it can use to reduce sizes) and
//! panics with the reproducing seed.

use super::rng::Rng;

pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    /// 0 = full size; higher values ask the generator to produce smaller
    /// inputs (used when re-generating around a failure).
    pub shrink: u32,
}

impl<'a> Gen<'a> {
    /// Size helper: scales `max` down with the shrink level (never below 1).
    pub fn size(&mut self, max: usize) -> usize {
        let cap = (max >> self.shrink).max(1);
        1 + self.rng.below(cap)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.range(lo, hi)).collect()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run a property over `cases` generated inputs. Panics on first failure
/// with the seed that reproduces it.
pub fn check<T, G, P>(name: &str, cases: u64, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base = 0xa5e1_0000u64;
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Rng::new(seed);
        let mut g = Gen {
            rng: &mut rng,
            shrink: 0,
        };
        let input = gen(&mut g);
        if let Err(msg) = prop(&input) {
            // shrink-lite: regenerate from the same seed at higher shrink
            // levels; report the smallest still-failing level.
            let mut level = 0;
            for s in 1..=4u32 {
                let mut rng = Rng::new(seed);
                let mut g = Gen {
                    rng: &mut rng,
                    shrink: s,
                };
                let smaller = gen(&mut g);
                if prop(&smaller).is_err() {
                    level = s;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 min failing shrink level {level}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "reverse-twice",
            100,
            |g| {
                let n = g.size(64);
                g.vec_f64(n, -1.0, 1.0)
            },
            |v| {
                let mut r = v.clone();
                r.reverse();
                r.reverse();
                if r == *v {
                    Ok(())
                } else {
                    Err("reverse^2 != id".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check(
            "always-fails",
            10,
            |g| g.usize_in(0, 10),
            |_| Err("nope".into()),
        );
    }
}
