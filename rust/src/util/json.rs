//! Minimal JSON value model, recursive-descent parser and writer.
//!
//! Used for `artifacts/manifest.json` (written by the python AOT pass),
//! experiment config files, and result dumps under `results/`. Supports
//! the full JSON grammar except `\u` surrogate pairs outside the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Indexed access for arrays.
    pub fn at(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Dotted-path lookup: `j.path("config.lr.mnist")`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- parsing ---------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- writing ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    e.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = &self.b[self.pos..];
                    let ch = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))
                        .map(|t| t.chars().next().unwrap())?;
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path("b.c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(
            v.get("a").unwrap().at(2).unwrap().as_f64().unwrap(),
            -300.0
        );
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn parses_nested_deep() {
        let mut s = String::new();
        for _ in 0..64 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..64 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::str("arena")),
            ("xs", Json::arr_f64(&[1.0, 2.0, 3.5])),
            ("nested", Json::obj(vec![("k", Json::Bool(false))])),
        ]);
        let re = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""é\tA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\tA");
        let s = Json::Str("quote\"back\\slash".into()).to_string();
        assert_eq!(
            Json::parse(&s).unwrap().as_str().unwrap(),
            "quote\"back\\slash"
        );
    }

    #[test]
    fn integer_formatting_stays_integral() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }
}
