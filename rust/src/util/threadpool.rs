//! Fixed-size worker pool with thread-local worker state.
//!
//! The xla crate's PJRT handles are not `Send`, so the pool is built around
//! *worker-owned* state: each worker thread constructs its own state (its
//! own `PjRtClient` + compiled executables) via an `init` closure, and jobs
//! are plain `Send` data mapped to plain `Send` results. Results are
//! returned in submission order.

use std::sync::mpsc;
use std::thread::JoinHandle;

pub struct Pool<J: Send + 'static, R: Send + 'static> {
    job_tx: Vec<mpsc::Sender<(usize, J)>>,
    res_rx: mpsc::Receiver<(usize, R)>,
    handles: Vec<JoinHandle<()>>,
    next_worker: usize,
}

impl<J: Send + 'static, R: Send + 'static> Pool<J, R> {
    /// Spawn `n` workers. `init(worker_idx)` builds the thread-local state;
    /// `work(&mut state, job)` maps a job to a result.
    pub fn new<S, I, W>(n: usize, init: I, work: W) -> Self
    where
        S: 'static,
        I: Fn(usize) -> S + Send + Sync + Clone + 'static,
        W: Fn(&mut S, J) -> R + Send + Sync + Clone + 'static,
    {
        assert!(n > 0);
        let (res_tx, res_rx) = mpsc::channel::<(usize, R)>();
        let mut job_tx = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, rx) = mpsc::channel::<(usize, J)>();
            job_tx.push(tx);
            let res_tx = res_tx.clone();
            let init = init.clone();
            let work = work.clone();
            handles.push(std::thread::spawn(move || {
                let mut state = init(w);
                while let Ok((id, job)) = rx.recv() {
                    let r = work(&mut state, job);
                    if res_tx.send((id, r)).is_err() {
                        break;
                    }
                }
            }));
        }
        Pool {
            job_tx,
            res_rx,
            handles,
            next_worker: 0,
        }
    }

    pub fn workers(&self) -> usize {
        self.job_tx.len()
    }

    /// Run all jobs across the pool; returns results in job order.
    pub fn map(&mut self, jobs: Vec<J>) -> Vec<R> {
        let n = jobs.len();
        for (id, job) in jobs.into_iter().enumerate() {
            let w = self.next_worker;
            self.next_worker = (self.next_worker + 1) % self.job_tx.len();
            self.job_tx[w]
                .send((id, job))
                .expect("worker thread died");
        }
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (id, r) = self.res_rx.recv().expect("worker thread died");
            slots[id] = Some(r);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl<J: Send + 'static, R: Send + 'static> Drop for Pool<J, R> {
    fn drop(&mut self) {
        self.job_tx.clear(); // closes channels, workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run `f` over `items` across up to `workers` scoped threads, for side
/// effects (items usually carry `&mut` slices into a caller buffer).
///
/// The borrow-friendly sibling of [`Pool`]: `Pool`'s jobs must be
/// `'static` (they cross long-lived worker channels), which rules out
/// borrowing the caller's data — exactly what a chunked in-place kernel
/// like `hfl::aggregate::aggregate_native_par` needs. This helper spawns
/// scoped threads instead, so items may borrow, and joins them all before
/// returning. Items are dealt round-robin; callers must not depend on
/// processing order (the aggregation kernel is order-independent by
/// construction — fixed chunk grid, disjoint outputs).
pub fn par_for_each<T, F>(workers: usize, items: Vec<T>, f: F)
where
    T: Send,
    F: Fn(T) + Sync,
{
    let workers = workers.clamp(1, items.len().max(1));
    if workers == 1 {
        for it in items {
            f(it);
        }
        return;
    }
    let mut queues: Vec<Vec<T>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, it) in items.into_iter().enumerate() {
        queues[i % workers].push(it);
    }
    std::thread::scope(|s| {
        let f = &f;
        for q in queues {
            s.spawn(move || {
                for it in q {
                    f(it);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let mut pool: Pool<u64, u64> = Pool::new(4, |_| (), |_, x| x * x);
        let out = pool.map((0..100).collect());
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn worker_state_is_threadlocal() {
        // Each worker counts its own jobs; total must equal job count.
        let mut pool: Pool<(), usize> = Pool::new(3, |_| 0usize, |c, _| {
            *c += 1;
            *c
        });
        let res = pool.map(vec![(); 30]);
        // per-worker counters never exceed the job count and are >= 1
        assert!(res.iter().all(|&c| (1..=30).contains(&c)));
        let total: usize = res.iter().filter(|&&c| c == 1).count();
        assert_eq!(total, 3); // each worker saw a first job
    }

    #[test]
    fn empty_job_list() {
        let mut pool: Pool<u32, u32> = Pool::new(2, |_| (), |_, x| x);
        assert!(pool.map(vec![]).is_empty());
    }

    #[test]
    fn par_for_each_visits_every_item_once() {
        use std::sync::atomic::{AtomicU64, Ordering};
        for workers in [1usize, 2, 3, 8] {
            let sum = AtomicU64::new(0);
            par_for_each(workers, (1..=100u64).collect(), |x| {
                sum.fetch_add(x, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 5050, "w={workers}");
        }
        // Empty and oversized worker counts are fine.
        par_for_each(4, Vec::<u64>::new(), |_| unreachable!());
        let sum = AtomicU64::new(0);
        par_for_each(16, vec![1u64, 2], |x| {
            sum.fetch_add(x, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn par_for_each_mutates_borrowed_chunks() {
        let mut out = vec![0u64; 64];
        let chunks: Vec<(usize, &mut [u64])> =
            out.chunks_mut(16).enumerate().collect();
        par_for_each(4, chunks, |(ci, seg)| {
            for (i, v) in seg.iter_mut().enumerate() {
                *v = (ci * 16 + i) as u64;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }
}
