//! Fixed-size worker pool with thread-local worker state.
//!
//! The xla crate's PJRT handles are not `Send`, so the pool is built around
//! *worker-owned* state: each worker thread constructs its own state (its
//! own `PjRtClient` + compiled executables) via an `init` closure, and jobs
//! are plain `Send` data mapped to plain `Send` results. Results are
//! returned in submission order.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

pub struct Pool<J: Send + 'static, R: Send + 'static> {
    job_tx: Vec<mpsc::Sender<(usize, J)>>,
    res_rx: mpsc::Receiver<(usize, R)>,
    handles: Vec<JoinHandle<()>>,
    next_worker: usize,
}

impl<J: Send + 'static, R: Send + 'static> Pool<J, R> {
    /// Spawn `n` workers. `init(worker_idx)` builds the thread-local state;
    /// `work(&mut state, job)` maps a job to a result.
    pub fn new<S, I, W>(n: usize, init: I, work: W) -> Self
    where
        S: 'static,
        I: Fn(usize) -> S + Send + Sync + Clone + 'static,
        W: Fn(&mut S, J) -> R + Send + Sync + Clone + 'static,
    {
        assert!(n > 0);
        let (res_tx, res_rx) = mpsc::channel::<(usize, R)>();
        let mut job_tx = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, rx) = mpsc::channel::<(usize, J)>();
            job_tx.push(tx);
            let res_tx = res_tx.clone();
            let init = init.clone();
            let work = work.clone();
            // The one sanctioned spawn site (with ShardPool below):
            // everything else must go through this module so thread
            // lifetimes stay owned and joined.
            #[allow(clippy::disallowed_methods)]
            handles.push(std::thread::spawn(move || {
                let mut state = init(w);
                while let Ok((id, job)) = rx.recv() {
                    let r = work(&mut state, job);
                    if res_tx.send((id, r)).is_err() {
                        break;
                    }
                }
            }));
        }
        Pool {
            job_tx,
            res_rx,
            handles,
            next_worker: 0,
        }
    }

    pub fn workers(&self) -> usize {
        self.job_tx.len()
    }

    /// Run all jobs across the pool; returns results in job order.
    pub fn map(&mut self, jobs: Vec<J>) -> Vec<R> {
        let n = jobs.len();
        for (id, job) in jobs.into_iter().enumerate() {
            let w = self.next_worker;
            self.next_worker = (self.next_worker + 1) % self.job_tx.len();
            self.job_tx[w]
                .send((id, job))
                .expect("worker thread died");
        }
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (id, r) = self.res_rx.recv().expect("worker thread died");
            slots[id] = Some(r);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl<J: Send + 'static, R: Send + 'static> Drop for Pool<J, R> {
    fn drop(&mut self) {
        self.job_tx.clear(); // closes channels, workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run `f` over `items` across up to `workers` scoped threads, for side
/// effects (items usually carry `&mut` slices into a caller buffer).
///
/// The borrow-friendly sibling of [`Pool`]: `Pool`'s jobs must be
/// `'static` (they cross long-lived worker channels), which rules out
/// borrowing the caller's data — exactly what a chunked in-place kernel
/// like `hfl::aggregate::aggregate_native_par` needs. This helper spawns
/// scoped threads instead, so items may borrow, and joins them all before
/// returning. Items are dealt round-robin; callers must not depend on
/// processing order (the aggregation kernel is order-independent by
/// construction — fixed chunk grid, disjoint outputs).
pub fn par_for_each<T, F>(workers: usize, items: Vec<T>, f: F)
where
    T: Send,
    F: Fn(T) + Sync,
{
    let workers = workers.clamp(1, items.len().max(1));
    if workers == 1 {
        for it in items {
            f(it);
        }
        return;
    }
    let mut queues: Vec<Vec<T>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, it) in items.into_iter().enumerate() {
        queues[i % workers].push(it);
    }
    std::thread::scope(|s| {
        let f = &f;
        for q in queues {
            s.spawn(move || {
                for it in q {
                    f(it);
                }
            });
        }
    });
}

/// Advance a slice of borrowed shards through one window and return the
/// per-shard reports **in shard order** — the scoped sibling of
/// [`ShardPool::run`] for engines whose shards cannot be `'static`
/// (e.g. `hfl::engine_shard::EngineShard` inside `AsyncHflEngine`,
/// whose windows interleave with `&mut` barrier access to the same
/// shards). Pinning is identical to `ShardPool` (shard `i` → lane
/// `i % workers`), lanes run on `std::thread::scope` threads, and
/// `workers <= 1` runs inline in shard order with no threads at all —
/// so the single-worker path is the definition of the trajectory and
/// every other worker count must reproduce it exactly.
pub fn shard_scope<S, R, F>(workers: usize, shards: &mut [S], f: F) -> Vec<R>
where
    S: Send,
    R: Send,
    F: Fn(usize, &mut S) -> R + Sync,
{
    let n = shards.len();
    let w = workers.max(1).min(n.max(1));
    if w <= 1 {
        return shards
            .iter_mut()
            .enumerate()
            .map(|(i, s)| f(i, s))
            .collect();
    }
    let mut lanes: Vec<Vec<(usize, &mut S)>> =
        (0..w).map(|_| Vec::new()).collect();
    for (i, s) in shards.iter_mut().enumerate() {
        lanes[i % w].push((i, s));
    }
    let mut slots: Vec<Option<R>> =
        std::iter::repeat_with(|| None).take(n).collect();
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = lanes
            .into_iter()
            .map(|lane| {
                scope.spawn(move || {
                    lane.into_iter()
                        .map(|(i, s)| (i, f(i, s)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("shard_scope worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("shard_scope lost a shard report"))
        .collect()
}

/// A shard-advance job: runs against one shard's owned state, returns
/// that shard's report for the window.
pub type ShardJob<S, R> = Arc<dyn Fn(usize, &mut S) -> R + Send + Sync>;

enum ShardMsg<S, R> {
    Run(ShardJob<S, R>),
    /// Hand every owned shard back (shutdown protocol for
    /// [`ShardPool::into_shards`]).
    Take(mpsc::Sender<(usize, S)>),
}

enum ShardInner<S: Send + 'static, R: Send + 'static> {
    /// `workers <= 1`: shards live on the caller's thread and every
    /// window advances serially in shard order — zero thread, channel,
    /// or `Arc` overhead, so the single-worker path costs exactly what
    /// the pre-shard serial loop did.
    Inline { shards: Vec<S> },
    Threads {
        job_tx: Vec<mpsc::Sender<ShardMsg<S, R>>>,
        res_rx: mpsc::Receiver<(usize, R)>,
        handles: Vec<JoinHandle<()>>,
        n_shards: usize,
    },
}

/// Long-lived worker pool over *partitioned owned state* — the engine
/// room of the sharded simulation layer (`sim::shard`).
///
/// Where [`Pool`] deals independent jobs round-robin, `ShardPool` pins
/// each shard to one worker for the pool's whole life (shard `i` →
/// worker `i % workers`, fixed at construction): shard state never
/// crosses a thread boundary after setup, so per-shard RNGs, event
/// queues, and model slabs stay warm in one worker's cache across every
/// window of a run. Each [`run`](ShardPool::run) call is one
/// conservative time-window: all workers advance their shards
/// independently, reports come home over mpsc in whatever order threads
/// finish, and the caller receives them **re-ordered by shard index** —
/// the fixed-shard-order merge that makes the parallel trajectory
/// bit-identical for any worker count (including 1, which runs inline
/// with no threads at all).
pub struct ShardPool<S: Send + 'static, R: Send + 'static> {
    inner: ShardInner<S, R>,
}

impl<S: Send + 'static, R: Send + 'static> ShardPool<S, R> {
    /// Distribute `shards` across up to `workers` long-lived threads
    /// (clamped to the shard count; `<= 1` runs inline, threadless).
    pub fn new(workers: usize, shards: Vec<S>) -> Self {
        let w = workers.max(1).min(shards.len().max(1));
        if w <= 1 {
            return ShardPool {
                inner: ShardInner::Inline { shards },
            };
        }
        let n_shards = shards.len();
        let (res_tx, res_rx) = mpsc::channel::<(usize, R)>();
        let mut job_tx = Vec::with_capacity(w);
        let mut rxs = Vec::with_capacity(w);
        for _ in 0..w {
            let (tx, rx) = mpsc::channel::<ShardMsg<S, R>>();
            job_tx.push(tx);
            rxs.push(rx);
        }
        let mut owned: Vec<Vec<(usize, S)>> =
            (0..w).map(|_| Vec::new()).collect();
        for (i, s) in shards.into_iter().enumerate() {
            owned[i % w].push((i, s));
        }
        let mut handles = Vec::with_capacity(w);
        for (rx, mut mine) in rxs.into_iter().zip(owned) {
            let res_tx = res_tx.clone();
            // See Pool::new: this module is the sanctioned spawn site.
            #[allow(clippy::disallowed_methods)]
            handles.push(std::thread::spawn(move || {
                while let Ok(msg) = rx.recv() {
                    match msg {
                        ShardMsg::Run(f) => {
                            for (idx, s) in mine.iter_mut() {
                                let r = f(*idx, s);
                                if res_tx.send((*idx, r)).is_err() {
                                    return;
                                }
                            }
                        }
                        ShardMsg::Take(back) => {
                            for pair in mine.drain(..) {
                                let _ = back.send(pair);
                            }
                            return;
                        }
                    }
                }
            }));
        }
        ShardPool {
            inner: ShardInner::Threads {
                job_tx,
                res_rx,
                handles,
                n_shards,
            },
        }
    }

    pub fn workers(&self) -> usize {
        match &self.inner {
            ShardInner::Inline { .. } => 1,
            ShardInner::Threads { job_tx, .. } => job_tx.len(),
        }
    }

    pub fn n_shards(&self) -> usize {
        match &self.inner {
            ShardInner::Inline { shards } => shards.len(),
            ShardInner::Threads { n_shards, .. } => *n_shards,
        }
    }

    /// The worker that owns `shard` for the pool's whole life (the
    /// `shard % workers` pinning above; 0 on the inline pool). Lets
    /// observers attribute per-shard work to the worker that ran it.
    pub fn shard_worker(&self, shard: usize) -> usize {
        match &self.inner {
            ShardInner::Inline { .. } => 0,
            ShardInner::Threads { job_tx, .. } => shard % job_tx.len(),
        }
    }

    /// Advance every shard through one window with `f(shard_idx, state)`
    /// and return the reports **in shard order**, whatever order worker
    /// threads finished in. `f` must depend only on its shard's index
    /// and state (no ambient mutability), which is what makes the
    /// result independent of thread interleaving.
    pub fn run<F>(&mut self, f: F) -> Vec<R>
    where
        F: Fn(usize, &mut S) -> R + Send + Sync + 'static,
    {
        match &mut self.inner {
            ShardInner::Inline { shards } => shards
                .iter_mut()
                .enumerate()
                .map(|(i, s)| f(i, s))
                .collect(),
            ShardInner::Threads {
                job_tx,
                res_rx,
                n_shards,
                ..
            } => {
                let job: ShardJob<S, R> = Arc::new(f);
                for tx in job_tx.iter() {
                    tx.send(ShardMsg::Run(Arc::clone(&job)))
                        .expect("shard worker died");
                }
                let mut slots: Vec<Option<R>> =
                    (0..*n_shards).map(|_| None).collect();
                for _ in 0..*n_shards {
                    let (idx, r) =
                        res_rx.recv().expect("shard worker died");
                    slots[idx] = Some(r);
                }
                slots.into_iter().map(|s| s.unwrap()).collect()
            }
        }
    }

    /// Tear the pool down and hand back every shard's final state, in
    /// shard order.
    pub fn into_shards(mut self) -> Vec<S> {
        let inner = std::mem::replace(
            &mut self.inner,
            ShardInner::Inline { shards: Vec::new() },
        );
        match inner {
            ShardInner::Inline { shards } => shards,
            ShardInner::Threads {
                mut job_tx,
                mut handles,
                n_shards,
                ..
            } => {
                let (back_tx, back_rx) = mpsc::channel::<(usize, S)>();
                for tx in &job_tx {
                    let _ = tx.send(ShardMsg::Take(back_tx.clone()));
                }
                drop(back_tx);
                let mut slots: Vec<Option<S>> =
                    (0..n_shards).map(|_| None).collect();
                while let Ok((idx, s)) = back_rx.recv() {
                    slots[idx] = Some(s);
                }
                job_tx.clear();
                for h in handles.drain(..) {
                    let _ = h.join();
                }
                slots
                    .into_iter()
                    .map(|s| s.expect("worker lost a shard"))
                    .collect()
            }
        }
    }
}

impl<S: Send + 'static, R: Send + 'static> Drop for ShardPool<S, R> {
    fn drop(&mut self) {
        if let ShardInner::Threads {
            job_tx, handles, ..
        } = &mut self.inner
        {
            job_tx.clear(); // closes channels, workers exit
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let mut pool: Pool<u64, u64> = Pool::new(4, |_| (), |_, x| x * x);
        let out = pool.map((0..100).collect());
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn worker_state_is_threadlocal() {
        // Each worker counts its own jobs; total must equal job count.
        let mut pool: Pool<(), usize> = Pool::new(3, |_| 0usize, |c, _| {
            *c += 1;
            *c
        });
        let res = pool.map(vec![(); 30]);
        // per-worker counters never exceed the job count and are >= 1
        assert!(res.iter().all(|&c| (1..=30).contains(&c)));
        let total: usize = res.iter().filter(|&&c| c == 1).count();
        assert_eq!(total, 3); // each worker saw a first job
    }

    #[test]
    fn empty_job_list() {
        let mut pool: Pool<u32, u32> = Pool::new(2, |_| (), |_, x| x);
        assert!(pool.map(vec![]).is_empty());
    }

    #[test]
    fn par_for_each_visits_every_item_once() {
        use std::sync::atomic::{AtomicU64, Ordering};
        for workers in [1usize, 2, 3, 8] {
            let sum = AtomicU64::new(0);
            par_for_each(workers, (1..=100u64).collect(), |x| {
                sum.fetch_add(x, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 5050, "w={workers}");
        }
        // Empty and oversized worker counts are fine.
        par_for_each(4, Vec::<u64>::new(), |_| unreachable!());
        let sum = AtomicU64::new(0);
        par_for_each(16, vec![1u64, 2], |x| {
            sum.fetch_add(x, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn shard_pool_merges_in_shard_order_for_any_worker_count() {
        // Each shard accumulates its own counter across windows; the
        // report stream must come back [shard 0, shard 1, ...] for every
        // worker count, and state must persist across run() calls.
        let reference: Vec<Vec<u64>> = {
            let mut pool: ShardPool<u64, u64> =
                ShardPool::new(1, vec![0; 7]);
            (0..3)
                .map(|w| {
                    pool.run(move |idx, c| {
                        *c += (idx as u64 + 1) * (w + 1);
                        *c
                    })
                })
                .collect()
        };
        for workers in [2usize, 3, 8, 16] {
            let mut pool: ShardPool<u64, u64> =
                ShardPool::new(workers, vec![0; 7]);
            for (w, want) in reference.iter().enumerate() {
                let w = w as u64;
                let got = pool.run(move |idx, c| {
                    *c += (idx as u64 + 1) * (w + 1);
                    *c
                });
                assert_eq!(&got, want, "workers={workers} window={w}");
            }
            assert_eq!(
                pool.into_shards(),
                reference.last().unwrap().clone(),
                "workers={workers}"
            );
        }
    }

    #[test]
    fn shard_pool_order_survives_adversarial_delays() {
        // Seeded per-shard sleeps scramble the mpsc arrival order; the
        // merged report order must not move.
        let mut pool: ShardPool<crate::util::rng::Rng, usize> =
            ShardPool::new(
                4,
                (0..8).map(|i| crate::util::rng::Rng::new(i)).collect(),
            );
        for _ in 0..3 {
            let got = pool.run(|idx, rng| {
                let us = rng.below(500) as u64;
                std::thread::sleep(std::time::Duration::from_micros(us));
                idx
            });
            assert_eq!(got, (0..8).collect::<Vec<_>>());
        }
    }

    #[test]
    fn shard_worker_matches_the_pinning() {
        let p: ShardPool<u32, u32> = ShardPool::new(3, vec![0; 7]);
        assert_eq!(p.workers(), 3);
        for shard in 0..7 {
            assert_eq!(p.shard_worker(shard), shard % 3);
        }
        let inline: ShardPool<u32, u32> = ShardPool::new(1, vec![0; 4]);
        assert_eq!(inline.shard_worker(3), 0);
    }

    #[test]
    fn shard_pool_inline_when_single_worker_or_shard() {
        let mut p: ShardPool<u32, u32> = ShardPool::new(1, vec![5, 6]);
        assert_eq!(p.workers(), 1);
        assert_eq!(p.run(|_, s| *s), vec![5, 6]);
        // More workers than shards clamps; one shard runs inline.
        let p2: ShardPool<u32, u32> = ShardPool::new(8, vec![9]);
        assert_eq!(p2.workers(), 1);
        assert_eq!(p2.n_shards(), 1);
        assert_eq!(p2.into_shards(), vec![9]);
        // Empty shard list is fine too.
        let mut p3: ShardPool<u32, u32> = ShardPool::new(4, vec![]);
        assert!(p3.run(|_, s| *s).is_empty());
        assert!(p3.into_shards().is_empty());
    }

    #[test]
    fn shard_scope_merges_in_shard_order_for_any_worker_count() {
        // Same contract as ShardPool::run, with borrowed shards: the
        // report stream comes back [shard 0, shard 1, ...] for every
        // worker count and state persists across calls.
        let mut reference = vec![0u64; 7];
        let want: Vec<Vec<u64>> = (0..3u64)
            .map(|w| {
                shard_scope(1, &mut reference, |idx, c| {
                    *c += (idx as u64 + 1) * (w + 1);
                    *c
                })
            })
            .collect();
        for workers in [2usize, 3, 8, 16] {
            let mut shards = vec![0u64; 7];
            for (w, expect) in want.iter().enumerate() {
                let w = w as u64;
                let got = shard_scope(workers, &mut shards, |idx, c| {
                    *c += (idx as u64 + 1) * (w + 1);
                    *c
                });
                assert_eq!(&got, expect, "workers={workers} window={w}");
            }
            assert_eq!(&shards, &reference, "workers={workers}");
        }
        // Empty shard list and oversized worker counts are fine.
        let mut none: Vec<u64> = Vec::new();
        assert!(shard_scope(4, &mut none, |_, c| *c).is_empty());
    }

    #[test]
    fn par_for_each_mutates_borrowed_chunks() {
        let mut out = vec![0u64; 64];
        let chunks: Vec<(usize, &mut [u64])> =
            out.chunks_mut(16).enumerate().collect();
        par_for_each(4, chunks, |(ci, seg)| {
            for (i, v) in seg.iter_mut().enumerate() {
                *v = (ci * 16 + i) as u64;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }
}
