//! Small statistics helpers used by the profiler, the experiment harnesses
//! and the micro-benchmark runner.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    mean(&a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).collect::<Vec<_>>())
}

/// Exponential moving average over a series (smoothing for figures).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

/// Histogram of xs into `bins` equal-width buckets over [lo, hi).
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            h[((x - lo) / w) as usize] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ema_converges_to_constant() {
        let xs = vec![5.0; 100];
        let e = ema(&xs, 0.1);
        assert!((e[99] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.9], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 1]);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
