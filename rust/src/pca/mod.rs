//! PCA compression of cloud/edge models for the DRL state (paper §3.2).
//!
//! The paper fits PCA once on the models of the first cloud aggregation
//! and reuses the loading vectors afterwards. With only R = M+1 model rows
//! and P parameters (R ≪ P), we fit through the R x R Gram matrix:
//!     G = X Xᵀ,  G u_k = λ_k u_k,  loading_k = Xᵀ u_k / sqrt(λ_k)
//! (uncentered PCA, so up to R non-zero components are available — the
//! paper's n_PCA = 6 equals M+1 = 6; requesting more yields zero columns,
//! which is exactly the Fig. 12 ablation behaviour at n_PCA = 10).
//!
//! The *transform* of later rounds (models @ loadings) is executed through
//! the `pca_project` Pallas artifact on the request path; `transform_cpu`
//! is the rust fallback used by tests and the Favor baseline.

use crate::linalg::{jacobi_eigen, Mat};

pub struct PcaModel {
    /// P x npca loading matrix, column-major-by-component, flattened f32
    /// in the artifact's expected [P, npca] row-major layout.
    pub loadings: Vec<f32>,
    pub p: usize,
    pub npca: usize,
    /// Explained variance per component (diagnostics).
    pub eigenvalues: Vec<f64>,
}

impl PcaModel {
    /// Fit from R stacked flat models (each length P).
    pub fn fit(models: &[&[f32]], npca: usize) -> PcaModel {
        let r = models.len();
        assert!(r > 0, "need at least one model row");
        let p = models[0].len();
        let x = Mat::from_rows(
            models
                .iter()
                .map(|m| m.iter().map(|&v| v as f64).collect())
                .collect(),
        );
        let g = x.gram();
        let (vals, vecs) = jacobi_eigen(&g, 100);
        let mut loadings = vec![0.0f32; p * npca];
        let mut eigenvalues = Vec::with_capacity(npca);
        for k in 0..npca {
            if k < r && vals[k] > 1e-9 {
                let scale = 1.0 / vals[k].sqrt();
                // loading_k[j] = sum_i X[i][j] * u[i][k] / sqrt(lambda_k)
                for i in 0..r {
                    let w = vecs[(i, k)] * scale;
                    if w == 0.0 {
                        continue;
                    }
                    let row = models[i];
                    for j in 0..p {
                        loadings[j * npca + k] += (row[j] as f64 * w) as f32;
                    }
                }
                eigenvalues.push(vals[k]);
            } else {
                eigenvalues.push(0.0); // zero column (rank-deficient ask)
            }
        }
        PcaModel {
            loadings,
            p,
            npca,
            eigenvalues,
        }
    }

    /// CPU projection of stacked models -> [R, npca] scores.
    pub fn transform_cpu(&self, models: &[&[f32]]) -> Vec<Vec<f32>> {
        models
            .iter()
            .map(|m| {
                assert_eq!(m.len(), self.p);
                let mut out = vec![0.0f32; self.npca];
                for (j, &v) in m.iter().enumerate() {
                    if v == 0.0 {
                        continue;
                    }
                    let base = j * self.npca;
                    for k in 0..self.npca {
                        out[k] += v * self.loadings[base + k];
                    }
                }
                out
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_models(r: usize, p: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..r)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn scores_are_orthogonal_with_unit_scale() {
        // Scores of the fitted rows themselves: S = X L = X Xᵀ U Λ^{-1/2}
        // = U Λ^{1/2}; columns of S are orthogonal with norm sqrt(λ_k).
        let models = rand_models(6, 500, 3);
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let pca = PcaModel::fit(&refs, 6);
        let scores = pca.transform_cpu(&refs);
        for k1 in 0..6 {
            for k2 in 0..6 {
                let dot: f64 = (0..6)
                    .map(|i| scores[i][k1] as f64 * scores[i][k2] as f64)
                    .sum();
                let want = if k1 == k2 { pca.eigenvalues[k1] } else { 0.0 };
                assert!(
                    (dot - want).abs() < 1e-2 * want.abs().max(1.0),
                    "score gram ({k1},{k2}) = {dot}, want {want}"
                );
            }
        }
    }

    #[test]
    fn rank_deficient_request_zero_pads() {
        let models = rand_models(3, 100, 4);
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let pca = PcaModel::fit(&refs, 6);
        // components beyond the rank (3 rows) must be zero
        for k in 3..6 {
            assert_eq!(pca.eigenvalues[k], 0.0);
            let col_norm: f32 = (0..pca.p)
                .map(|j| pca.loadings[j * 6 + k].powi(2))
                .sum();
            assert_eq!(col_norm, 0.0);
        }
    }

    #[test]
    fn separates_distinct_model_clusters() {
        // Two groups of similar models must land far apart in score space.
        let mut rng = Rng::new(9);
        let p = 400;
        let base_a: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
        let base_b: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
        let mut models = Vec::new();
        for g in 0..6 {
            let base = if g < 3 { &base_a } else { &base_b };
            models.push(
                base.iter()
                    .map(|&v| v + 0.01 * rng.normal() as f32)
                    .collect::<Vec<f32>>(),
            );
        }
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let pca = PcaModel::fit(&refs, 2);
        let s = pca.transform_cpu(&refs);
        let d_within = crate::linalg::dist2(
            &[s[0][0] as f64, s[0][1] as f64],
            &[s[1][0] as f64, s[1][1] as f64],
        );
        let d_across = crate::linalg::dist2(
            &[s[0][0] as f64, s[0][1] as f64],
            &[s[4][0] as f64, s[4][1] as f64],
        );
        assert!(
            d_across > 100.0 * d_within.max(1e-12),
            "within {d_within} across {d_across}"
        );
    }

    #[test]
    fn loadings_layout_matches_artifact() {
        // [P, npca] row-major: element (j, k) at j*npca + k.
        let models = rand_models(2, 10, 5);
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let pca = PcaModel::fit(&refs, 3);
        assert_eq!(pca.loadings.len(), 10 * 3);
    }
}
