//! The Arena DRL agent (paper §3.2-3.6): state construction, Gaussian
//! action heads with feasible-solution projection, GAE, PPO updates via the
//! AOT artifacts, and the Algorithm 1 training loop.

pub mod action;
pub mod arena;
pub mod bound;
pub mod gae;
pub mod memory;
pub mod ppo;
pub mod state;

pub use action::{
    decode_async, nearest_feasible, ActionConfig, AsyncActionConfig,
    DecidedAction,
};
pub use arena::{
    run_arena_policy, run_policy_on, train_arena, train_arena_on,
    ArenaOptions, ControlledEngine, EpisodeLog,
};
pub use bound::convergence_bound;
pub use gae::gae_advantages;
pub use memory::{Trajectory, Transition};
pub use ppo::PpoAgent;
pub use state::{StateBuilder, StateScales};
