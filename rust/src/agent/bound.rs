//! Theorem 1 (paper Eq. 16): per-cloud-round convergence bound of the
//! varying-frequency synchronization scheme, plus the Eq. 29 feasibility
//! condition on the step size. Computable diagnostics reported by the
//! Fig. 7 harness next to the measured loss descent.

/// Inputs to the bound.
#[derive(Clone, Copy, Debug)]
pub struct BoundParams {
    /// Max edge / cloud aggregation frequencies γ̃1, γ̃2 this round.
    pub gamma1_max: f64,
    pub gamma2_max: f64,
    pub m_edges: f64,
    pub n_devices: f64,
    /// Learning rate η, smoothness L, gradient-variance bound σ².
    pub eta: f64,
    pub smooth_l: f64,
    pub sigma2: f64,
    /// E‖∇f(w(k))‖² estimate.
    pub grad_norm2: f64,
}

impl BoundParams {
    /// The diagnostic constants (η, L, σ², ‖∇f‖²) over a run's topology
    /// box — one constructor shared by the Fig. 7 bound report and the
    /// per-edge action-decode gate, so the bound the harness reports and
    /// the cap the agent trains under cannot drift apart.
    pub fn diagnostic(cfg: &crate::config::ExperimentConfig) -> Self {
        BoundParams {
            gamma1_max: cfg.hfl.gamma1_max as f64,
            gamma2_max: cfg.hfl.gamma2_max as f64,
            m_edges: cfg.topology.edges as f64,
            n_devices: cfg.topology.devices as f64,
            eta: 0.003,
            smooth_l: 1.0,
            sigma2: 1.0,
            grad_norm2: 1.0,
        }
    }
}

/// RHS of Eq. (16): expected one-round decrease bound
/// E[f(w(k+1))] − E[f(w(k))] ≤ bound(...). Negative = guaranteed descent.
pub fn convergence_bound(p: &BoundParams) -> f64 {
    let g1 = p.gamma1_max;
    let g2 = p.gamma2_max;
    let l = p.smooth_l;
    let eta = p.eta;
    let term1 = l * l * eta.powi(3) / 4.0
        * g1
        * g2
        * ((g1 - 1.0) + p.m_edges / p.n_devices * g1 * (g2 - 1.0))
        * p.sigma2;
    let term2 = l * eta * eta / 2.0 / p.n_devices * g1 * g2 * p.sigma2;
    let term3 = -eta / 2.0 * g1 * g2 * p.grad_norm2;
    term1 + term2 + term3
}

/// Eq. (29): step-size feasibility for a given edge's (γ1ʲ, γ2ʲ).
pub fn step_size_feasible(
    p: &BoundParams,
    gamma1_j: f64,
    gamma2_j: f64,
) -> bool {
    let l = p.smooth_l;
    let eta = p.eta;
    let g1t = p.gamma1_max;
    1.0 - l * l
        * eta
        * eta
        * (gamma1_j * (gamma1_j - 1.0) / 2.0
            + g1t * g1t * gamma2_j * (gamma2_j - 1.0) / 2.0)
        - l * eta * gamma1_j * gamma2_j
        >= 0.0
}

/// Largest γ1ʲ in `[1, gamma1_max]` that keeps the Eq. (29) step-size
/// condition satisfiable at `gamma2_j` — the bound the per-edge action
/// decode clamps against (`agent::action::decode_async`). Falls back to 1
/// when even that is infeasible (the run still has to train).
pub fn max_feasible_gamma1(
    p: &BoundParams,
    gamma1_max: usize,
    gamma2_j: f64,
) -> usize {
    for g1 in (1..=gamma1_max.max(1)).rev() {
        if step_size_feasible(p, g1 as f64, gamma2_j) {
            return g1;
        }
    }
    1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> BoundParams {
        BoundParams {
            gamma1_max: 5.0,
            gamma2_max: 4.0,
            m_edges: 5.0,
            n_devices: 50.0,
            eta: 0.003,
            smooth_l: 1.0,
            sigma2: 1.0,
            grad_norm2: 1.0,
        }
    }

    #[test]
    fn small_eta_guarantees_descent() {
        // With η small the −(η/2)γ̃1γ̃2‖∇f‖² term dominates.
        let b = convergence_bound(&base());
        assert!(b < 0.0, "bound {b} should be negative (descent)");
    }

    #[test]
    fn bound_monotone_in_sigma2() {
        let mut p = base();
        let b1 = convergence_bound(&p);
        p.sigma2 = 10.0;
        let b2 = convergence_bound(&p);
        assert!(b2 > b1, "more gradient noise must weaken the bound");
    }

    #[test]
    fn variance_terms_grow_with_frequencies() {
        // Compare only the positive (noise) part by zeroing grad_norm2.
        let mut p = base();
        p.grad_norm2 = 0.0;
        let b1 = convergence_bound(&p);
        p.gamma1_max = 10.0;
        p.gamma2_max = 5.0;
        let b2 = convergence_bound(&p);
        assert!(b2 > b1);
    }

    #[test]
    fn feasibility_fails_for_huge_eta() {
        let mut p = base();
        assert!(step_size_feasible(&p, 5.0, 4.0));
        p.eta = 10.0;
        assert!(!step_size_feasible(&p, 5.0, 4.0));
    }

    #[test]
    fn max_feasible_gamma1_clamps_with_eta() {
        let mut p = base();
        // Small step size: the whole box is feasible.
        assert_eq!(max_feasible_gamma1(&p, 8, 1.0), 8);
        // A large step size shrinks the feasible γ1 range; the floor is 1
        // even when nothing satisfies Eq. (29).
        p.eta = 0.4;
        let g = max_feasible_gamma1(&p, 8, 1.0);
        assert!(g < 8, "eta=0.4 must cut the feasible range, got {g}");
        p.eta = 10.0;
        assert_eq!(max_feasible_gamma1(&p, 8, 1.0), 1);
    }
}
