//! PPO agent driver: holds the flat actor-critic parameters + Adam state
//! and runs `ppo_actor_fwd` / `ppo_update` through the runtime.

use anyhow::{Context, Result};

use crate::runtime::{HostTensor, Runtime};
use crate::util::rng::Rng;

use super::action::{log_prob, sample_gaussian};
use super::memory::PpoBatch;

pub struct PpoAgent {
    pub theta: Vec<f32>,
    adam_m: Vec<f32>,
    adam_v: Vec<f32>,
    step_t: f64,
    pub m: usize,
    pub npca: usize,
    state_len: usize,
    act_len: usize,
    batch: usize,
    /// Artifact-name suffix ("" for the default n_PCA, "_npca<k>" for the
    /// Fig. 12 ablation variants).
    suffix: String,
}

/// Artifact suffix for a given n_PCA relative to the manifest default.
pub fn npca_suffix(default_npca: usize, npca: usize) -> String {
    if npca == default_npca {
        String::new()
    } else {
        format!("_npca{npca}")
    }
}

#[derive(Clone, Debug)]
pub struct UpdateLosses {
    pub policy: f64,
    pub value: f64,
    pub entropy: f64,
}

impl PpoAgent {
    /// Load initial parameters from the artifact init binaries.
    pub fn new(rt: &Runtime) -> Result<Self> {
        let npca = rt.manifest.config.npca;
        Self::new_variant(rt, npca)
    }

    /// Variant with a non-default n_PCA (requires the matching
    /// `_npca<k>` artifacts — see aot.py --npca-variants).
    pub fn new_variant(rt: &Runtime, npca: usize) -> Result<Self> {
        let c = &rt.manifest.config;
        let suffix = npca_suffix(c.npca, npca);
        let theta = rt.load_init_params(&format!("ppo{suffix}"))?;
        let n = theta.len();
        Ok(PpoAgent {
            theta,
            adam_m: vec![0.0; n],
            adam_v: vec![0.0; n],
            step_t: 0.0,
            m: c.m_edges,
            npca,
            state_len: (c.m_edges + 1) * (npca + 3),
            act_len: 2 * c.m_edges,
            batch: c.traj_batch,
            suffix,
        })
    }

    /// Variant over the extended control-state layout — an
    /// (M+1) x (n_pca + 8) state whose rows carry the per-edge staleness
    /// / in-flight / quorum-fill features plus the lifecycle observables
    /// (abandonment rate, diurnal availability) of the event-driven
    /// engine (`agent::state` ctrl layout). Requires the `_ctrl`
    /// artifacts (aot.py emits them next to the defaults); the action
    /// head stays 2M wide, decoded as per-edge (γ1_j, α_j) instead of
    /// (γ1_j, γ2_j).
    pub fn new_ctrl_variant(rt: &Runtime) -> Result<Self> {
        let c = &rt.manifest.config;
        anyhow::ensure!(
            rt.manifest.artifacts.contains_key("ppo_actor_fwd_ctrl"),
            "no ppo_actor_fwd_ctrl artifact in the manifest — rebuild the \
             artifact set (`make artifacts`) to get the control-state \
             variants"
        );
        let theta = rt.load_init_params("ppo_ctrl")?;
        let n = theta.len();
        Ok(PpoAgent {
            theta,
            adam_m: vec![0.0; n],
            adam_v: vec![0.0; n],
            step_t: 0.0,
            m: c.m_edges,
            npca: c.npca,
            state_len: (c.m_edges + 1) * (c.npca + 8),
            act_len: 2 * c.m_edges,
            batch: c.traj_batch,
            suffix: "_ctrl".into(),
        })
    }

    /// Artifact names this agent executes (for Runtime::load).
    pub fn artifact_names(&self) -> (String, String) {
        (
            format!("ppo_actor_fwd{}", self.suffix),
            format!("ppo_update{}", self.suffix),
        )
    }

    pub fn state_len(&self) -> usize {
        self.state_len
    }

    pub fn act_len(&self) -> usize {
        self.act_len
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Policy forward: (mu, sigma, value) for one state.
    pub fn forward(
        &self,
        rt: &Runtime,
        state: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, f64)> {
        anyhow::ensure!(state.len() == self.state_len, "state length");
        let rows = self.m + 1;
        let cols = self.state_len / rows;
        let out = rt.execute(
            &format!("ppo_actor_fwd{}", self.suffix),
            &[
                HostTensor::f32(vec![self.theta.len()], self.theta.clone()),
                HostTensor::f32(vec![rows, cols], state.to_vec()),
            ],
        )?;
        let mu = out[0].as_f32()?.to_vec();
        let sigma = out[1].as_f32()?.to_vec();
        let value = out[2].scalar()?;
        Ok((mu, sigma, value))
    }

    /// Sample a raw action; returns (raw, log_prob, value).
    pub fn act(
        &self,
        rt: &Runtime,
        state: &[f32],
        rng: &mut Rng,
    ) -> Result<(Vec<f32>, f64, f64)> {
        let (mu, sigma, value) = self.forward(rt, state)?;
        let (raw, lp) = sample_gaussian(&mu, &sigma, rng);
        debug_assert!((log_prob(&mu, &sigma, &raw) - lp).abs() < 1e-6);
        Ok((raw, lp, value))
    }

    /// Deterministic (mean) action — evaluation mode.
    pub fn act_mean(
        &self,
        rt: &Runtime,
        state: &[f32],
    ) -> Result<(Vec<f32>, f64)> {
        let (mu, _, value) = self.forward(rt, state)?;
        Ok((mu, value))
    }

    /// Persist the policy parameters (little-endian f32) for reuse across
    /// experiment harnesses.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut bytes = Vec::with_capacity(self.theta.len() * 4);
        for v in &self.theta {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    /// Restore policy parameters saved by `save`.
    pub fn restore(&mut self, path: &std::path::Path) -> Result<()> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(
            bytes.len() == self.theta.len() * 4,
            "saved policy size mismatch: {} bytes vs {} params",
            bytes.len(),
            self.theta.len()
        );
        self.theta = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(())
    }

    /// One PPO/Adam gradient step over a padded batch.
    pub fn update(
        &mut self,
        rt: &Runtime,
        batch: &PpoBatch,
    ) -> Result<UpdateLosses> {
        let rows = self.m + 1;
        let cols = self.state_len / rows;
        let b = self.batch;
        self.step_t += 1.0;
        let n = self.theta.len();
        let out = rt.execute(
            &format!("ppo_update{}", self.suffix),
            &[
                HostTensor::f32(vec![n], self.theta.clone()),
                HostTensor::f32(vec![n], self.adam_m.clone()),
                HostTensor::f32(vec![n], self.adam_v.clone()),
                HostTensor::f32(vec![1], vec![self.step_t as f32]),
                HostTensor::f32(vec![b, rows, cols], batch.states.clone()),
                HostTensor::f32(vec![b, self.act_len], batch.actions.clone()),
                HostTensor::f32(vec![b], batch.old_logp.clone()),
                HostTensor::f32(vec![b], batch.advantages.clone()),
                HostTensor::f32(vec![b], batch.returns.clone()),
                HostTensor::f32(vec![b], batch.mask.clone()),
            ],
        )?;
        let mut it = out.into_iter();
        self.theta = it.next().context("theta")?.into_f32()?;
        self.adam_m = it.next().context("m")?.into_f32()?;
        self.adam_v = it.next().context("v")?.into_f32()?;
        let losses = it.next().context("losses")?.into_f32()?;
        Ok(UpdateLosses {
            policy: losses[0] as f64,
            value: losses[1] as f64,
            entropy: losses[2] as f64,
        })
    }
}
