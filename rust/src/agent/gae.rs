//! Generalized advantage estimation (paper Eq. 14) and discounted returns.
//!
//! Rust-side scalar recursion over a finished trajectory: the PPO update
//! artifact receives pre-computed advantages + value targets.

/// GAE advantages and bootstrapped returns.
///
/// rewards[t], values[t] for t = 0..T-1, terminal value 0 (episodes end at
/// the time threshold). xi = discount ξ, lambda = GAE λ.
pub fn gae_advantages(
    rewards: &[f64],
    values: &[f64],
    xi: f64,
    lambda: f64,
) -> (Vec<f64>, Vec<f64>) {
    let t_len = rewards.len();
    assert_eq!(values.len(), t_len);
    let mut adv = vec![0.0; t_len];
    let mut gae = 0.0;
    for t in (0..t_len).rev() {
        let next_v = if t + 1 < t_len { values[t + 1] } else { 0.0 };
        let delta = rewards[t] + xi * next_v - values[t];
        gae = delta + xi * lambda * gae;
        adv[t] = gae;
    }
    let returns: Vec<f64> =
        adv.iter().zip(values).map(|(a, v)| a + v).collect();
    (adv, returns)
}

/// Plain discounted returns (the Hwamei ablation: no GAE).
pub fn discounted_returns(rewards: &[f64], xi: f64) -> Vec<f64> {
    let mut out = vec![0.0; rewards.len()];
    let mut acc = 0.0;
    for t in (0..rewards.len()).rev() {
        acc = rewards[t] + xi * acc;
        out[t] = acc;
    }
    out
}

/// Normalize advantages to zero mean / unit std (standard PPO practice).
pub fn normalize(adv: &mut [f64]) {
    if adv.len() < 2 {
        return;
    }
    let m = crate::util::stats::mean(adv);
    let s = crate::util::stats::std(adv).max(1e-8);
    for a in adv.iter_mut() {
        *a = (*a - m) / s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_is_td_error() {
        let (adv, ret) = gae_advantages(&[1.0], &[0.5], 0.9, 0.9);
        assert!((adv[0] - 0.5).abs() < 1e-12); // 1.0 + 0 - 0.5
        assert!((ret[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lambda_one_is_discounted_return_minus_value() {
        let rewards = [1.0, 2.0, 3.0];
        let values = [0.3, 0.2, 0.1];
        let (adv, _) = gae_advantages(&rewards, &values, 0.9, 1.0);
        let returns = discounted_returns(&rewards, 0.9);
        for t in 0..3 {
            assert!(
                (adv[t] - (returns[t] - values[t])).abs() < 1e-10,
                "t={t}"
            );
        }
    }

    #[test]
    fn lambda_zero_is_one_step_td() {
        let rewards = [1.0, 2.0];
        let values = [0.5, 0.4];
        let (adv, _) = gae_advantages(&rewards, &values, 0.9, 0.0);
        assert!((adv[0] - (1.0 + 0.9 * 0.4 - 0.5)).abs() < 1e-12);
        assert!((adv[1] - (2.0 - 0.4)).abs() < 1e-12);
    }

    #[test]
    fn discounted_returns_basic() {
        let r = discounted_returns(&[1.0, 1.0, 1.0], 0.5);
        assert!((r[0] - 1.75).abs() < 1e-12);
        assert!((r[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_zero_mean_unit_std() {
        let mut a = vec![1.0, 2.0, 3.0, 4.0];
        normalize(&mut a);
        assert!(crate::util::stats::mean(&a).abs() < 1e-12);
        assert!((crate::util::stats::std(&a) - 1.0).abs() < 1e-9);
    }
}
