//! Action head (paper §3.3 + §3.6 enhancement).
//!
//! The actor emits 2M Gaussian (mu, sigma) pairs. We sample a raw
//! continuous action a ∈ R^{2M}, map each coordinate affinely into the
//! frequency range, and then pick the *feasible integer solution nearest to
//! the continuous point* (min ||ã - a||², paper §3.6) — feasibility being
//! the box bounds plus the time-budget constraint "expected round time ≤
//! remaining time". Hwamei (the conference version) used naive per-dim
//! rounding; both are implemented for the Table 2 ablation.

use crate::util::rng::Rng;

use super::bound::{max_feasible_gamma1, BoundParams};

#[derive(Clone, Debug)]
pub struct ActionConfig {
    pub m: usize,
    pub gamma1_max: usize,
    pub gamma2_max: usize,
    /// Enable the §3.6 nearest-feasible projection (false = Hwamei rounding).
    pub nearest_solution: bool,
}

#[derive(Clone, Debug)]
pub struct DecidedAction {
    /// Raw Gaussian sample (what PPO's log-prob sees).
    pub raw: Vec<f32>,
    pub log_prob: f64,
    pub value: f64,
    pub gamma1: Vec<usize>,
    pub gamma2: Vec<usize>,
}

/// Map a raw action coordinate affinely into `[lo, hi]`: mid + a * half,
/// clamped — the shared decode for frequency and α coordinates.
pub fn to_range(a: f32, lo: f64, hi: f64) -> f64 {
    let mid = (lo + hi) / 2.0;
    let half = (hi - lo) / 2.0;
    (mid + a as f64 * half).clamp(lo, hi)
}

/// Map a raw action coordinate into the continuous frequency space
/// [1, gmax]: mid + a * half, clamped.
pub fn to_continuous(a: f32, gmax: usize) -> f64 {
    to_range(a, 1.0, gmax as f64)
}

/// Sample raw ~ N(mu, sigma) and return (raw, log_prob).
pub fn sample_gaussian(
    mu: &[f32],
    sigma: &[f32],
    rng: &mut Rng,
) -> (Vec<f32>, f64) {
    let mut raw = Vec::with_capacity(mu.len());
    let mut logp = 0.0;
    for (&m, &s) in mu.iter().zip(sigma) {
        let s = s.max(1e-4);
        let z = rng.normal();
        let a = m + s * z as f32;
        raw.push(a);
        let zz = ((a - m) / s) as f64;
        logp += -0.5 * zz * zz
            - (s as f64).ln()
            - 0.5 * (2.0 * std::f64::consts::PI).ln();
    }
    (raw, logp)
}

/// Log-prob of an existing raw action under (mu, sigma) — PPO ratio input.
pub fn log_prob(mu: &[f32], sigma: &[f32], raw: &[f32]) -> f64 {
    let mut logp = 0.0;
    for ((&m, &s), &a) in mu.iter().zip(sigma).zip(raw) {
        let s = s.max(1e-4) as f64;
        let z = (a - m) as f64 / s;
        logp +=
            -0.5 * z * z - s.ln() - 0.5 * (2.0 * std::f64::consts::PI).ln();
    }
    logp
}

/// Project the continuous per-edge targets onto the feasible integer grid.
///
/// `predict(g1, g2)` estimates the round duration if *this edge's*
/// frequencies were (g1, g2) (other edges held at their own targets);
/// `budget` is the remaining time T_re. Per edge we minimize the squared
/// distance to the continuous target among in-budget pairs; if no pair
/// fits the budget the minimum-duration pair is chosen (the round must
/// still happen — matching the paper's "still trains, then episode ends").
pub fn nearest_feasible(
    cfg: &ActionConfig,
    cont1: &[f64],
    cont2: &[f64],
    mut edge_time: impl FnMut(usize, usize, usize) -> f64,
    budget: f64,
) -> (Vec<usize>, Vec<usize>) {
    let mut g1 = Vec::with_capacity(cfg.m);
    let mut g2 = Vec::with_capacity(cfg.m);
    for j in 0..cfg.m {
        if !cfg.nearest_solution {
            // Hwamei: naive rounding + clamping.
            g1.push((cont1[j].round() as usize).clamp(1, cfg.gamma1_max));
            g2.push((cont2[j].round() as usize).clamp(1, cfg.gamma2_max));
            continue;
        }
        let mut best: Option<(f64, usize, usize)> = None;
        let mut fastest: Option<(f64, usize, usize)> = None;
        for c1 in 1..=cfg.gamma1_max {
            for c2 in 1..=cfg.gamma2_max {
                let t = edge_time(j, c1, c2);
                let d = (c1 as f64 - cont1[j]).powi(2)
                    + (c2 as f64 - cont2[j]).powi(2);
                if fastest.map(|(ft, _, _)| t < ft).unwrap_or(true) {
                    fastest = Some((t, c1, c2));
                }
                if t <= budget
                    && best.map(|(bd, _, _)| d < bd).unwrap_or(true)
                {
                    best = Some((d, c1, c2));
                }
            }
        }
        let (c1, c2) = match best {
            Some((_, c1, c2)) => (c1, c2),
            None => {
                let (_, c1, c2) = fastest.unwrap();
                (c1, c2)
            }
        };
        g1.push(c1);
        g2.push(c2);
    }
    (g1, g2)
}

/// Decode parameters of the event-driven (per-edge γ1_j, α_j) action
/// space. The same 2M raw coordinates the barrier decode interprets as
/// (γ1, γ2) pairs here decode to per-edge local-epoch counts γ1_j — the
/// edge-aggregation period of the event engine, re-armed at cloud
/// decision points — and per-edge staleness-discount exponents α_j.
#[derive(Clone, Debug)]
pub struct AsyncActionConfig {
    pub m: usize,
    pub gamma1_max: usize,
    /// Decode range of the per-edge staleness exponent α_j
    /// (`sync.alpha_min`/`sync.alpha_max`).
    pub alpha_min: f64,
    pub alpha_max: f64,
    /// Eq. (29) step-size feasibility gate on γ1_j (`bound.rs`); None
    /// skips the gate.
    pub bound: Option<BoundParams>,
}

/// Decode a raw 2M-vector into per-edge (γ1_j, α_j): the first M
/// coordinates map affinely into [1, γ̃1] and round to the nearest
/// integer, clamped by the Eq. (29) feasibility bound (γ2 = 1: the cloud
/// timer, not a frequency, is the outer period in the event modes); the
/// second M map affinely into [α_min, α_max].
pub fn decode_async(
    cfg: &AsyncActionConfig,
    raw: &[f32],
) -> (Vec<usize>, Vec<f64>) {
    assert_eq!(raw.len(), 2 * cfg.m, "raw action length");
    let cap = cfg
        .bound
        .as_ref()
        .map(|b| max_feasible_gamma1(b, cfg.gamma1_max, 1.0))
        .unwrap_or(cfg.gamma1_max);
    let mut g1 = Vec::with_capacity(cfg.m);
    let mut alpha = Vec::with_capacity(cfg.m);
    for j in 0..cfg.m {
        let c = to_continuous(raw[j], cfg.gamma1_max);
        g1.push((c.round() as usize).clamp(1, cap.max(1)));
        alpha.push(to_range(raw[cfg.m + j], cfg.alpha_min, cfg.alpha_max));
    }
    (g1, alpha)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    fn cfg(nearest: bool) -> ActionConfig {
        ActionConfig {
            m: 3,
            gamma1_max: 10,
            gamma2_max: 5,
            nearest_solution: nearest,
        }
    }

    #[test]
    fn continuous_mapping_centers_and_clamps() {
        assert!((to_continuous(0.0, 10) - 5.5).abs() < 1e-9);
        assert_eq!(to_continuous(10.0, 10), 10.0);
        assert_eq!(to_continuous(-10.0, 10), 1.0);
    }

    #[test]
    fn sampled_logprob_matches_recomputed() {
        let mut rng = Rng::new(1);
        let mu = vec![0.2f32, -0.5, 1.0];
        let sigma = vec![0.5f32, 1.0, 0.2];
        let (raw, lp) = sample_gaussian(&mu, &sigma, &mut rng);
        let lp2 = log_prob(&mu, &sigma, &raw);
        assert!((lp - lp2).abs() < 1e-9);
    }

    #[test]
    fn unconstrained_projection_is_rounding() {
        let c = cfg(true);
        let cont1 = vec![3.4, 7.6, 9.9];
        let cont2 = vec![1.2, 4.5, 2.5];
        let (g1, g2) =
            nearest_feasible(&c, &cont1, &cont2, |_, _, _| 0.0, 1e9);
        assert_eq!(g1, vec![3, 8, 10]);
        // 4.5 / 2.5 tie-break picks the first minimal (lower) candidate.
        assert_eq!(g2[0], 1);
        assert!(g2[1] == 4 || g2[1] == 5);
    }

    #[test]
    fn budget_constraint_reduces_frequencies() {
        let c = cfg(true);
        let cont1 = vec![10.0; 3];
        let cont2 = vec![5.0; 3];
        // Time model: 1s per gamma1*gamma2 unit, budget 12s -> products
        // must be <= 12.
        let (g1, g2) = nearest_feasible(
            &c,
            &cont1,
            &cont2,
            |_, a, b| (a * b) as f64,
            12.0,
        );
        for j in 0..3 {
            assert!(g1[j] * g2[j] <= 12, "({}, {})", g1[j], g2[j]);
        }
    }

    #[test]
    fn impossible_budget_picks_fastest() {
        let c = cfg(true);
        let (g1, g2) = nearest_feasible(
            &c,
            &[8.0; 3],
            &[4.0; 3],
            |_, a, b| (a * b) as f64,
            0.5, // nothing fits
        );
        assert_eq!(g1, vec![1; 3]);
        assert_eq!(g2, vec![1; 3]);
    }

    #[test]
    fn hwamei_mode_ignores_budget() {
        let c = cfg(false);
        let (g1, _) = nearest_feasible(
            &c,
            &[9.7; 3],
            &[3.0; 3],
            |_, a, b| (a * b) as f64,
            0.5,
        );
        assert_eq!(g1, vec![10; 3]);
    }

    fn acfg(bound: Option<BoundParams>) -> AsyncActionConfig {
        AsyncActionConfig {
            m: 3,
            gamma1_max: 8,
            alpha_min: 0.0,
            alpha_max: 2.0,
            bound,
        }
    }

    #[test]
    fn async_decode_saturates_at_the_extremes() {
        let c = acfg(None);
        // Raw +inf-ish saturates every coordinate at its upper bound,
        // -inf-ish at the lower (the bound.rs / config box).
        let hi = decode_async(&c, &[1e9f32; 6]);
        assert_eq!(hi.0, vec![8; 3]);
        for &a in &hi.1 {
            assert!((a - 2.0).abs() < 1e-12);
        }
        let lo = decode_async(&c, &[-1e9f32; 6]);
        assert_eq!(lo.0, vec![1; 3]);
        for &a in &lo.1 {
            assert!(a.abs() < 1e-12);
        }
        // A centered raw action decodes to the mid-box.
        let mid = decode_async(&c, &[0.0f32; 6]);
        for &g in &mid.0 {
            assert!((1..=8).contains(&g));
        }
        for &a in &mid.1 {
            assert!((a - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn async_decode_respects_step_size_bound() {
        // A large step size shrinks the Eq. (29)-feasible γ1 range; the
        // decode must clamp to it even when the raw action saturates high.
        let b = BoundParams {
            gamma1_max: 8.0,
            gamma2_max: 4.0,
            m_edges: 3.0,
            n_devices: 30.0,
            eta: 0.4,
            smooth_l: 1.0,
            sigma2: 1.0,
            grad_norm2: 1.0,
        };
        let cap = max_feasible_gamma1(&b, 8, 1.0);
        assert!(cap < 8);
        let c = acfg(Some(b));
        let (g1, _) = decode_async(&c, &[1e9f32; 6]);
        assert_eq!(g1, vec![cap; 3]);
        // The floor survives even an infeasible bound.
        let mut b1 = acfg(None);
        b1.bound = Some(BoundParams { eta: 10.0, ..b });
        let (g1, _) = decode_async(&b1, &[1e9f32; 6]);
        assert_eq!(g1, vec![1; 3]);
    }

    #[test]
    fn prop_projection_always_in_bounds() {
        check(
            "action-bounds",
            50,
            |g| {
                let m = g.usize_in(1, 6);
                let cont1: Vec<f64> =
                    (0..m).map(|_| g.f64_in(-5.0, 20.0)).collect();
                let cont2: Vec<f64> =
                    (0..m).map(|_| g.f64_in(-5.0, 20.0)).collect();
                let budget = g.f64_in(0.0, 100.0);
                let nearest = g.bool();
                (m, cont1, cont2, budget, nearest)
            },
            |(m, cont1, cont2, budget, nearest)| {
                let c = ActionConfig {
                    m: *m,
                    gamma1_max: 10,
                    gamma2_max: 5,
                    nearest_solution: *nearest,
                };
                let (g1, g2) = nearest_feasible(
                    &c,
                    cont1,
                    cont2,
                    |_, a, b| (a + b) as f64,
                    *budget,
                );
                for j in 0..*m {
                    if !(1..=10).contains(&g1[j]) {
                        return Err(format!("g1[{j}]={}", g1[j]));
                    }
                    if !(1..=5).contains(&g2[j]) {
                        return Err(format!("g2[{j}]={}", g2[j]));
                    }
                }
                Ok(())
            },
        );
    }
}
