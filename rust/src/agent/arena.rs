//! Algorithm 1: Arena's training loop, plus greedy policy rollout.
//!
//! The Hwamei ablation (paper Table 2) is the same loop with the §3.6
//! enhancements off: plain discounted returns instead of GAE, naive
//! rounding instead of the nearest-feasible-solution projection.

use anyhow::Result;

use crate::hfl::{HflEngine, RoundStats, RunHistory};
use crate::runtime::Runtime;
use crate::util::rng::Rng;

use super::action::{nearest_feasible, to_continuous, ActionConfig};
use super::gae::{discounted_returns, gae_advantages, normalize};
use super::memory::{Trajectory, Transition};
use super::ppo::PpoAgent;
use super::state::StateBuilder;

#[derive(Clone, Debug)]
pub struct ArenaOptions {
    pub episodes: usize,
    /// §3.6 enhancements (both true = Arena, both false = Hwamei).
    pub use_gae: bool,
    pub nearest_solution: bool,
    pub verbose: bool,
}

impl ArenaOptions {
    pub fn arena(episodes: usize) -> Self {
        ArenaOptions {
            episodes,
            use_gae: true,
            nearest_solution: true,
            verbose: false,
        }
    }

    pub fn hwamei(episodes: usize) -> Self {
        ArenaOptions {
            episodes,
            use_gae: false,
            nearest_solution: false,
            verbose: false,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EpisodeLog {
    pub episode: usize,
    pub reward: f64,
    pub final_accuracy: f64,
    /// Average per-device energy over the episode, mAh.
    pub avg_energy: f64,
    pub rounds: usize,
    pub policy_loss: f64,
    pub value_loss: f64,
    pub entropy: f64,
}

/// Paper Eq. (11): r(k) = Υ^{A(k)} − Υ^{A(k-1)} − ε·E(k),
/// E(k) in average-per-device mAh.
pub fn reward(
    upsilon: f64,
    epsilon: f64,
    acc_now: f64,
    acc_prev: f64,
    avg_energy: f64,
) -> f64 {
    upsilon.powf(acc_now) - upsilon.powf(acc_prev) - epsilon * avg_energy
}

/// Train the PPO agent over `opts.episodes` episodes (Algorithm 1).
/// Returns the trained agent, per-episode logs, and the state builder
/// (holding the fitted PCA) for later greedy rollouts.
pub fn train_arena(
    engine: &mut HflEngine,
    opts: &ArenaOptions,
) -> Result<(PpoAgent, StateBuilder, Vec<EpisodeLog>)> {
    let mut agent_rt = Runtime::load(&engine.cfg.artifacts_dir, &[])?;
    let mut agent =
        PpoAgent::new_variant(&agent_rt, engine.cfg.agent.npca)?;
    let (fwd_art, upd_art) = agent.artifact_names();
    agent_rt.compile(&fwd_art)?;
    agent_rt.compile(&upd_art)?;
    let m = engine.edges();
    let cfg = engine.cfg.clone();
    let mut sb = StateBuilder::new(m, cfg.agent.npca, cfg.hfl.threshold_time);
    let acfg = ActionConfig {
        m,
        gamma1_max: cfg.hfl.gamma1_max,
        gamma2_max: cfg.hfl.gamma2_max,
        nearest_solution: opts.nearest_solution,
    };
    let mut rng = Rng::new(cfg.seed ^ 0xa6e47);
    let mut logs = Vec::with_capacity(opts.episodes);
    let n_dev = cfg.topology.devices as f64;

    for ep in 0..opts.episodes {
        engine.reset();
        // Line 3: first cloud aggregation at the configured frequencies.
        let mut last = engine.run_round(
            &vec![cfg.hfl.gamma1; m],
            &vec![cfg.hfl.gamma2; m],
            None,
        )?;
        // Line 4: fit the PCA module once, on the first episode's models.
        if !sb.pca_ready() {
            sb.fit_pca(engine);
        }
        let mut traj = Trajectory::default();
        let mut ep_energy = last.energy;
        // Lines 7-17: interact until the time budget runs out.
        while engine.remaining_time() > 0.0 && traj.len() < agent.batch() {
            let state = sb.build(engine, &last)?;
            let (raw, logp, value) = agent.act(&agent_rt, &state, &mut rng)?;
            let cont1: Vec<f64> = (0..m)
                .map(|j| to_continuous(raw[j], acfg.gamma1_max))
                .collect();
            let cont2: Vec<f64> = (0..m)
                .map(|j| to_continuous(raw[m + j], acfg.gamma2_max))
                .collect();
            let budget = engine.remaining_time();
            let (g1, g2) = nearest_feasible(
                &acfg,
                &cont1,
                &cont2,
                |j, a, b| engine.predict_edge_time(j, a, b),
                budget,
            );
            let stats = engine.run_round(&g1, &g2, None)?;
            let r = reward(
                cfg.agent.upsilon,
                cfg.agent.epsilon,
                stats.accuracy,
                last.accuracy,
                stats.energy / n_dev,
            );
            traj.push(Transition {
                state,
                raw_action: raw,
                log_prob: logp,
                value,
                reward: r,
            });
            ep_energy += stats.energy;
            last = stats;
        }
        // Lines 19: update the agent from the episode's trajectory.
        let rewards = traj.rewards();
        let values = traj.values();
        let (mut adv, ret) = if opts.use_gae {
            gae_advantages(&rewards, &values, cfg.agent.xi, cfg.agent.lambda)
        } else {
            let ret = discounted_returns(&rewards, cfg.agent.xi);
            let adv: Vec<f64> =
                ret.iter().zip(&values).map(|(r, v)| r - v).collect();
            (adv, ret)
        };
        normalize(&mut adv);
        let batch = traj.to_batch(
            &adv,
            &ret,
            agent.batch(),
            agent.state_len(),
            agent.act_len(),
        );
        let mut losses = super::ppo::UpdateLosses {
            policy: 0.0,
            value: 0.0,
            entropy: 0.0,
        };
        if !traj.is_empty() {
            for _ in 0..cfg.agent.update_epochs {
                losses = agent.update(&agent_rt, &batch)?;
            }
        }
        let log = EpisodeLog {
            episode: ep,
            reward: rewards.iter().sum(),
            final_accuracy: last.accuracy,
            avg_energy: ep_energy / n_dev,
            rounds: traj.len() + 1,
            policy_loss: losses.policy,
            value_loss: losses.value,
            entropy: losses.entropy,
        };
        if opts.verbose {
            println!(
                "episode {:>4}: reward {:>8.3}  acc {:.3}  energy/dev {:>7.1} mAh  rounds {}",
                log.episode,
                log.reward,
                log.final_accuracy,
                log.avg_energy,
                log.rounds
            );
        }
        logs.push(log);
    }
    Ok((agent, sb, logs))
}

/// Greedy (mean-action) rollout of a trained policy; returns the round
/// history for time-to-accuracy / threshold-time figures.
pub fn run_arena_policy(
    engine: &mut HflEngine,
    agent: &PpoAgent,
    sb: &StateBuilder,
    nearest_solution: bool,
) -> Result<RunHistory> {
    let mut agent_rt = Runtime::load(&engine.cfg.artifacts_dir, &[])?;
    let (fwd_art, _) = agent.artifact_names();
    agent_rt.compile(&fwd_art)?;
    let cfg = engine.cfg.clone();
    let m = engine.edges();
    let acfg = ActionConfig {
        m,
        gamma1_max: cfg.hfl.gamma1_max,
        gamma2_max: cfg.hfl.gamma2_max,
        nearest_solution,
    };
    engine.reset();
    let mut hist = RunHistory::default();
    let mut last: RoundStats = engine.run_round(
        &vec![cfg.hfl.gamma1; m],
        &vec![cfg.hfl.gamma2; m],
        None,
    )?;
    hist.push(last.clone());
    while engine.remaining_time() > 0.0 {
        let state = sb.build(engine, &last)?;
        let (mu, _) = agent.act_mean(&agent_rt, &state)?;
        let cont1: Vec<f64> = (0..m)
            .map(|j| to_continuous(mu[j], acfg.gamma1_max))
            .collect();
        let cont2: Vec<f64> = (0..m)
            .map(|j| to_continuous(mu[m + j], acfg.gamma2_max))
            .collect();
        let budget = engine.remaining_time();
        let (g1, g2) = nearest_feasible(
            &acfg,
            &cont1,
            &cont2,
            |j, a, b| engine.predict_edge_time(j, a, b),
            budget,
        );
        last = engine.run_round(&g1, &g2, None)?;
        hist.push(last.clone());
    }
    Ok(hist)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reward_tracks_accuracy_and_energy() {
        // Accuracy gain pays, energy costs.
        let up = reward(64.0, 0.002, 0.72, 0.70, 10.0);
        let flat = reward(64.0, 0.002, 0.70, 0.70, 10.0);
        assert!(up > flat);
        assert!(flat < 0.0); // pure energy cost
        let expensive = reward(64.0, 0.002, 0.72, 0.70, 500.0);
        assert!(up > expensive);
    }

    #[test]
    fn reward_amplifies_late_gains() {
        // Υ^A growth: the same +0.02 accuracy is worth more at 0.9 than 0.3
        // (paper: "capture the small model improvement near the end").
        let early = reward(64.0, 0.0, 0.32, 0.30, 0.0);
        let late = reward(64.0, 0.0, 0.92, 0.90, 0.0);
        assert!(late > 2.0 * early, "late {late} early {early}");
    }

    #[test]
    fn options_presets_differ() {
        let a = ArenaOptions::arena(10);
        let h = ArenaOptions::hwamei(10);
        assert!(a.use_gae && a.nearest_solution);
        assert!(!h.use_gae && !h.nearest_solution);
    }
}
