//! Algorithm 1: Arena's training loop, plus greedy policy rollout.
//!
//! The loop is generic over a [`ControlledEngine`]: the barrier
//! [`HflEngine`] (the paper's setting — the action decodes to per-edge
//! (γ1, γ2) frequencies under the §3.6 nearest-feasible projection) and
//! the event-driven [`AsyncHflEngine`] (the ROADMAP's staleness-adaptive
//! γ — the same 2M-wide action decodes to per-edge local-epoch counts
//! γ1_j plus staleness exponents α_j, re-armed at every cloud decision
//! point through `AsyncHflEngine::set_control`). The event engine's
//! episodes run over the extended control state (`agent::state` ctrl
//! layout) and the matching `_ctrl` PPO artifacts.
//!
//! The Hwamei ablation (paper Table 2) is the same loop with the §3.6
//! enhancements off: plain discounted returns instead of GAE, naive
//! rounding instead of the nearest-feasible-solution projection.

use anyhow::{Context, Result};

use crate::hfl::{AsyncHflEngine, HflEngine, RoundStats, RunHistory};
use crate::runtime::Runtime;
use crate::util::rng::Rng;

use super::action::{
    decode_async, nearest_feasible, to_continuous, ActionConfig,
    AsyncActionConfig,
};
use super::bound::BoundParams;
use super::gae::{discounted_returns, gae_advantages, normalize};
use super::memory::{Trajectory, Transition};
use super::ppo::PpoAgent;
use super::state::{StateBuilder, StateScales};

/// What Algorithm 1 needs from an engine: episode bootstrap, one decision
/// interval per action, and access to the barrier core for state
/// construction (PCA scores, remaining time, config).
pub trait ControlledEngine {
    /// The barrier core this engine is built on.
    fn base(&self) -> &HflEngine;

    /// Start a fresh episode and execute the bootstrap interval
    /// (Algorithm 1 line 3) at the configured default knobs.
    fn begin_episode(&mut self) -> Result<RoundStats>;

    /// Decode `raw` (2M coordinates) and execute one decision interval;
    /// `None` once the run's time budget is exhausted.
    fn step_decided(
        &mut self,
        raw: &[f32],
        nearest: bool,
    ) -> Result<Option<RoundStats>>;

    /// Whether the DRL state carries the per-edge control columns (the
    /// agent then runs the `_ctrl` artifact variant).
    fn ctrl_state(&self) -> bool;
}

impl ControlledEngine for HflEngine {
    fn base(&self) -> &HflEngine {
        self
    }

    fn begin_episode(&mut self) -> Result<RoundStats> {
        self.reset();
        let m = self.edges();
        let g1 = vec![self.cfg.hfl.gamma1; m];
        let g2 = vec![self.cfg.hfl.gamma2; m];
        self.run_round(&g1, &g2, None)
    }

    fn step_decided(
        &mut self,
        raw: &[f32],
        nearest: bool,
    ) -> Result<Option<RoundStats>> {
        if self.remaining_time() <= 0.0 {
            return Ok(None);
        }
        let m = self.edges();
        let acfg = ActionConfig {
            m,
            gamma1_max: self.cfg.hfl.gamma1_max,
            gamma2_max: self.cfg.hfl.gamma2_max,
            nearest_solution: nearest,
        };
        let cont1: Vec<f64> = (0..m)
            .map(|j| to_continuous(raw[j], acfg.gamma1_max))
            .collect();
        let cont2: Vec<f64> = (0..m)
            .map(|j| to_continuous(raw[m + j], acfg.gamma2_max))
            .collect();
        let budget = self.remaining_time();
        let (g1, g2) = nearest_feasible(
            &acfg,
            &cont1,
            &cont2,
            |j, a, b| self.predict_edge_time(j, a, b),
            budget,
        );
        self.run_round(&g1, &g2, None).map(Some)
    }

    fn ctrl_state(&self) -> bool {
        false
    }
}

impl ControlledEngine for AsyncHflEngine {
    fn base(&self) -> &HflEngine {
        &self.eng
    }

    fn begin_episode(&mut self) -> Result<RoundStats> {
        let m = self.edges();
        let g1 = vec![self.eng.cfg.hfl.gamma1; m];
        self.begin_run(&g1)?;
        self.run_window()?.context(
            "time budget shorter than one cloud window: no bootstrap round",
        )
    }

    fn step_decided(
        &mut self,
        raw: &[f32],
        nearest: bool,
    ) -> Result<Option<RoundStats>> {
        let cfg = &self.eng.cfg;
        let acfg = AsyncActionConfig {
            m: self.edges(),
            gamma1_max: cfg.hfl.gamma1_max,
            alpha_min: cfg.sync.alpha_min,
            alpha_max: cfg.sync.alpha_max,
            // Arena gates γ1_j through Eq. 29 (same diagnostic constants
            // as the Fig. 7 bound report); the Hwamei ablation decodes
            // naively, mirroring its skipped projection on the barrier
            // engine.
            bound: if nearest {
                Some(BoundParams::diagnostic(cfg))
            } else {
                None
            },
        };
        let (g1, alpha) = decode_async(&acfg, raw);
        // Re-arm the per-edge aggregation periods and staleness exponents
        // at the decision point; in-flight work is untouched.
        self.set_control(&g1, &alpha)?;
        self.run_window()
    }

    fn ctrl_state(&self) -> bool {
        true
    }
}

#[derive(Clone, Debug)]
pub struct ArenaOptions {
    pub episodes: usize,
    /// §3.6 enhancements (both true = Arena, both false = Hwamei).
    pub use_gae: bool,
    pub nearest_solution: bool,
    pub verbose: bool,
}

impl ArenaOptions {
    pub fn arena(episodes: usize) -> Self {
        ArenaOptions {
            episodes,
            use_gae: true,
            nearest_solution: true,
            verbose: false,
        }
    }

    pub fn hwamei(episodes: usize) -> Self {
        ArenaOptions {
            episodes,
            use_gae: false,
            nearest_solution: false,
            verbose: false,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EpisodeLog {
    pub episode: usize,
    pub reward: f64,
    pub final_accuracy: f64,
    /// Average per-device energy over the episode, mAh.
    pub avg_energy: f64,
    pub rounds: usize,
    pub policy_loss: f64,
    pub value_loss: f64,
    pub entropy: f64,
}

/// Paper Eq. (11): r(k) = Υ^{A(k)} − Υ^{A(k-1)} − ε·E(k),
/// E(k) in average-per-device mAh.
pub fn reward(
    upsilon: f64,
    epsilon: f64,
    acc_now: f64,
    acc_prev: f64,
    avg_energy: f64,
) -> f64 {
    upsilon.powf(acc_now) - upsilon.powf(acc_prev) - epsilon * avg_energy
}

/// The (fresh) agent and state builder matching `engine`'s layout: the
/// `_ctrl` variant over the extended control state for the event engine,
/// the plain n_PCA variant for the barrier engine. Scales derive from the
/// run's own link/energy configuration. Shared by the training loop and
/// the harness's cache-restore paths so restored policies always see the
/// normalization they were trained under.
pub(crate) fn agent_for<E: ControlledEngine>(
    engine: &E,
    rt: &Runtime,
) -> Result<(PpoAgent, StateBuilder)> {
    let base = engine.base();
    let cfg = &base.cfg;
    let agent = if engine.ctrl_state() {
        anyhow::ensure!(
            cfg.agent.npca == rt.manifest.config.npca,
            "the _ctrl agent variant is only built at the manifest default \
             n_PCA ({}); config asks for {}",
            rt.manifest.config.npca,
            cfg.agent.npca
        );
        PpoAgent::new_ctrl_variant(rt)?
    } else {
        PpoAgent::new_variant(rt, cfg.agent.npca)?
    };
    let scales = StateScales::derive(
        cfg,
        &base.net,
        rt.manifest.config.nb,
        base.p,
    );
    let sb = StateBuilder::new(base.edges(), cfg.agent.npca, scales)
        .with_ctrl(engine.ctrl_state());
    Ok((agent, sb))
}

/// Train the PPO agent over `opts.episodes` episodes (Algorithm 1) on any
/// [`ControlledEngine`]. Returns the trained agent, per-episode logs, and
/// the state builder (holding the fitted PCA) for later greedy rollouts.
pub fn train_arena_on<E: ControlledEngine>(
    engine: &mut E,
    opts: &ArenaOptions,
) -> Result<(PpoAgent, StateBuilder, Vec<EpisodeLog>)> {
    let cfg = engine.base().cfg.clone();
    let mut agent_rt = Runtime::load(&cfg.artifacts_dir, &[])?;
    let (mut agent, mut sb) = agent_for(engine, &agent_rt)?;
    let (fwd_art, upd_art) = agent.artifact_names();
    agent_rt.compile(&fwd_art)?;
    agent_rt.compile(&upd_art)?;
    let mut rng = Rng::new(cfg.seed ^ 0xa6e47);
    let mut logs = Vec::with_capacity(opts.episodes);
    let n_dev = cfg.topology.devices as f64;

    for ep in 0..opts.episodes {
        // Line 3: bootstrap interval at the configured frequencies.
        let mut last = engine.begin_episode()?;
        // Line 4: fit the PCA module once, on the first episode's models.
        if !sb.pca_ready() {
            sb.fit_pca(engine.base());
        }
        let mut traj = Trajectory::default();
        let mut ep_energy = last.energy;
        // Lines 7-17: interact until the time budget runs out.
        while engine.base().remaining_time() > 0.0
            && traj.len() < agent.batch()
        {
            let state = sb.build(engine.base(), &last)?;
            let (raw, logp, value) = agent.act(&agent_rt, &state, &mut rng)?;
            let Some(stats) =
                engine.step_decided(&raw, opts.nearest_solution)?
            else {
                break;
            };
            let r = reward(
                cfg.agent.upsilon,
                cfg.agent.epsilon,
                stats.accuracy,
                last.accuracy,
                stats.energy / n_dev,
            );
            traj.push(Transition {
                state,
                raw_action: raw,
                log_prob: logp,
                value,
                reward: r,
            });
            ep_energy += stats.energy;
            last = stats;
        }
        // Line 19: update the agent from the episode's trajectory.
        let rewards = traj.rewards();
        let values = traj.values();
        let (mut adv, ret) = if opts.use_gae {
            gae_advantages(&rewards, &values, cfg.agent.xi, cfg.agent.lambda)
        } else {
            let ret = discounted_returns(&rewards, cfg.agent.xi);
            let adv: Vec<f64> =
                ret.iter().zip(&values).map(|(r, v)| r - v).collect();
            (adv, ret)
        };
        normalize(&mut adv);
        let batch = traj.to_batch(
            &adv,
            &ret,
            agent.batch(),
            agent.state_len(),
            agent.act_len(),
        );
        let mut losses = super::ppo::UpdateLosses {
            policy: 0.0,
            value: 0.0,
            entropy: 0.0,
        };
        if !traj.is_empty() {
            for _ in 0..cfg.agent.update_epochs {
                losses = agent.update(&agent_rt, &batch)?;
            }
        }
        let log = EpisodeLog {
            episode: ep,
            reward: rewards.iter().sum(),
            final_accuracy: last.accuracy,
            avg_energy: ep_energy / n_dev,
            rounds: traj.len() + 1,
            policy_loss: losses.policy,
            value_loss: losses.value,
            entropy: losses.entropy,
        };
        if opts.verbose {
            println!(
                "episode {:>4}: reward {:>8.3}  acc {:.3}  energy/dev {:>7.1} mAh  rounds {}",
                log.episode,
                log.reward,
                log.final_accuracy,
                log.avg_energy,
                log.rounds
            );
        }
        logs.push(log);
    }
    Ok((agent, sb, logs))
}

/// Train on the barrier engine (the paper's Algorithm 1 setting).
pub fn train_arena(
    engine: &mut HflEngine,
    opts: &ArenaOptions,
) -> Result<(PpoAgent, StateBuilder, Vec<EpisodeLog>)> {
    train_arena_on(engine, opts)
}

/// Greedy (mean-action) rollout of a trained policy on any
/// [`ControlledEngine`]; returns the round history for time-to-accuracy /
/// threshold-time figures.
pub fn run_policy_on<E: ControlledEngine>(
    engine: &mut E,
    agent: &PpoAgent,
    sb: &StateBuilder,
    nearest_solution: bool,
) -> Result<RunHistory> {
    let mut agent_rt = Runtime::load(&engine.base().cfg.artifacts_dir, &[])?;
    let (fwd_art, _) = agent.artifact_names();
    agent_rt.compile(&fwd_art)?;
    let mut hist = RunHistory::default();
    let mut last = engine.begin_episode()?;
    hist.push(last.clone());
    while engine.base().remaining_time() > 0.0 {
        let state = sb.build(engine.base(), &last)?;
        let (mu, _) = agent.act_mean(&agent_rt, &state)?;
        let Some(stats) = engine.step_decided(&mu, nearest_solution)? else {
            break;
        };
        hist.push(stats.clone());
        last = stats;
    }
    Ok(hist)
}

/// Greedy rollout on the barrier engine.
pub fn run_arena_policy(
    engine: &mut HflEngine,
    agent: &PpoAgent,
    sb: &StateBuilder,
    nearest_solution: bool,
) -> Result<RunHistory> {
    run_policy_on(engine, agent, sb, nearest_solution)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reward_tracks_accuracy_and_energy() {
        // Accuracy gain pays, energy costs.
        let up = reward(64.0, 0.002, 0.72, 0.70, 10.0);
        let flat = reward(64.0, 0.002, 0.70, 0.70, 10.0);
        assert!(up > flat);
        assert!(flat < 0.0); // pure energy cost
        let expensive = reward(64.0, 0.002, 0.72, 0.70, 500.0);
        assert!(up > expensive);
    }

    #[test]
    fn reward_amplifies_late_gains() {
        // Υ^A growth: the same +0.02 accuracy is worth more at 0.9 than 0.3
        // (paper: "capture the small model improvement near the end").
        let early = reward(64.0, 0.0, 0.32, 0.30, 0.0);
        let late = reward(64.0, 0.0, 0.92, 0.90, 0.0);
        assert!(late > 2.0 * early, "late {late} early {early}");
    }

    #[test]
    fn options_presets_differ() {
        let a = ArenaOptions::arena(10);
        let h = ArenaOptions::hwamei(10);
        assert!(a.use_gae && a.nearest_solution);
        assert!(!h.use_gae && !h.nearest_solution);
    }

    #[test]
    fn diagnostic_bound_tracks_topology() {
        let mut cfg = crate::config::ExperimentConfig::mnist();
        cfg.hfl.gamma1_max = 7;
        cfg.topology.edges = 4;
        let b = BoundParams::diagnostic(&cfg);
        assert!((b.gamma1_max - 7.0).abs() < 1e-12);
        assert!((b.m_edges - 4.0).abs() < 1e-12);
        // The diagnostic step size keeps the whole default box feasible.
        assert_eq!(
            crate::agent::bound::max_feasible_gamma1(&b, 7, 1.0),
            7
        );
    }
}
