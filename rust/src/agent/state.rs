//! DRL state construction (paper §3.2, Fig. 6).
//!
//! s(k) is an (M+1) x (n_pca + 3) matrix:
//!   row 0:      [ PCA(cloud model)  |  k, T_re(k), A_test(k-1) ]
//!   row j=1..M: [ PCA(edge_j model) |  T_j^SGD,  T_j^ec,  E_j  ]
//! The PCA loading vectors are fit once after the first cloud aggregation
//! (on the cloud, Gram trick — see pca/) and reused; the projection itself
//! runs through the pca_project Pallas artifact.
//!
//! `T_j^ec` is fed from *observed* transfer completions: since the
//! transfer layer (`sim::link`) landed, `EdgeStats::{t_up, t_down}` carry
//! the durations of edge j's last completed uplink/downlink transfers —
//! contention and jitter included — instead of a freshly resampled
//! round-trip, so the agent sees the communication times the run actually
//! experienced.
//!
//! Under churn-driven re-clustering (`hfl::membership`) the *composition*
//! of edge j changes mid-run, but the state stays well-formed: M is
//! fixed, and every per-edge feature is recomputed against the current
//! membership — row j's PCA score projects edge j's live model, and
//! `t_sgd_slowest`/`t_ec`/`E_j` come from the next round's stats, which
//! accumulate over the migrated member sets. The agent simply observes
//! edge j getting faster/slower as its membership shifts.

use anyhow::Result;

use crate::hfl::{HflEngine, RoundStats};
use crate::pca::PcaModel;

/// Normalization scales so every state entry is O(1) for the CNN trunk.
#[derive(Clone, Debug)]
pub struct StateScales {
    pub round: f64,
    pub time: f64,
    pub sgd_time: f64,
    pub comm_time: f64,
    pub energy: f64,
    pub pca: f64,
}

impl Default for StateScales {
    fn default() -> Self {
        StateScales {
            round: 10.0,
            time: 3000.0,
            sgd_time: 200.0,
            comm_time: 60.0,
            energy: 50.0,
            pca: 10.0,
        }
    }
}

pub struct StateBuilder {
    pub npca: usize,
    pub m: usize,
    pub scales: StateScales,
    pca: Option<PcaModel>,
}

impl StateBuilder {
    pub fn new(m: usize, npca: usize, threshold_time: f64) -> Self {
        let scales = StateScales {
            time: threshold_time,
            ..Default::default()
        };
        StateBuilder {
            npca,
            m,
            scales,
            pca: None,
        }
    }

    pub fn rows(&self) -> usize {
        self.m + 1
    }

    pub fn cols(&self) -> usize {
        self.npca + 3
    }

    pub fn pca_ready(&self) -> bool {
        self.pca.is_some()
    }

    /// Fit the PCA loadings from the engine's current [cloud; edges] models
    /// (paper: after the first cloud aggregation).
    pub fn fit_pca(&mut self, engine: &HflEngine) {
        let stack = engine.model_stack();
        self.pca = Some(PcaModel::fit(&stack, self.npca));
    }

    /// Build the flattened state matrix for round k.
    pub fn build(
        &self,
        engine: &HflEngine,
        last: &RoundStats,
    ) -> Result<Vec<f32>> {
        let pca = self
            .pca
            .as_ref()
            .expect("fit_pca must run after the first cloud aggregation");
        let scores = engine.pca_scores(pca)?;
        let rows = self.rows();
        let cols = self.cols();
        let mut s = vec![0.0f32; rows * cols];
        let sc = &self.scales;
        // Row 0: cloud PCA + global parameters (Eq. 9).
        for (c, &v) in scores[0].iter().take(self.npca).enumerate() {
            s[c] = v / sc.pca as f32;
        }
        s[self.npca] = last.k as f32 / sc.round as f32;
        s[self.npca + 1] =
            (engine.remaining_time() / sc.time) as f32;
        s[self.npca + 2] = last.accuracy as f32;
        // Rows 1..=M: edge PCA + h_j (Eq. 7).
        for j in 0..self.m {
            let base = (j + 1) * cols;
            for (c, &v) in scores[j + 1].iter().take(self.npca).enumerate() {
                s[base + c] = v / sc.pca as f32;
            }
            let e = &last.per_edge[j];
            s[base + self.npca] = (e.t_sgd_slowest / sc.sgd_time) as f32;
            // t_ec is the observed round trip of the edge's last landed
            // transfers (see EdgeStats), not a resampled draw.
            s[base + self.npca + 1] = (e.t_ec / sc.comm_time) as f32;
            s[base + self.npca + 2] = (e.energy / sc.energy) as f32;
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_default_sane() {
        let s = StateScales::default();
        assert!(s.time > 0.0 && s.energy > 0.0);
    }

    #[test]
    fn dims() {
        let b = StateBuilder::new(5, 6, 3000.0);
        assert_eq!(b.rows(), 6);
        assert_eq!(b.cols(), 9);
        assert!(!b.pca_ready());
    }
}
