//! DRL state construction (paper §3.2, Fig. 6).
//!
//! s(k) is an (M+1) x C matrix. In the paper's barrier setting
//! C = n_pca + 3:
//!   row 0:      [ PCA(cloud model)  |  k, T_re(k), A_test(k-1) ]
//!   row j=1..M: [ PCA(edge_j model) |  T_j^SGD,  T_j^ec,  E_j  ]
//! When the builder drives the event-driven engine (`ctrl` layout,
//! C = n_pca + 8) every row gains five control columns, sourced from the
//! [`crate::hfl::EdgeStats`] control + lifecycle observables the async
//! engine records at each cloud decision point:
//!   row 0:      [ ... | mean staleness, mean in-flight, mean quorum fill,
//!                       mean abandon rate, mean availability ]
//!   row j=1..M: [ ... | s_j, u_j, q_j, b_j, v_j ]
//! where s_j is the observed staleness of edge j's last landed upload (in
//! cloud windows), u_j the uploads still in flight on its uplink, q_j
//! its semi-sync quorum fill, b_j the window's abandonment rate
//! (over-selected stragglers + fault-voided work over all dispatched
//! work) and v_j its membership's diurnal availability. These are what
//! the per-edge (γ1_j, α_j) policy reacts to: a persistently stale edge
//! wants lighter local work and a harsher discount, a saturated uplink
//! wants a longer aggregation period, and an edge burning energy on
//! abandoned stragglers in its availability trough wants its pace
//! steered down.
//!
//! The PCA loading vectors are fit once after the first cloud aggregation
//! (on the cloud, Gram trick — see pca/) and reused; the projection itself
//! runs through the pca_project Pallas artifact.
//!
//! `T_j^ec` is fed from *observed* transfer completions: since the
//! transfer layer (`sim::link`) landed, `EdgeStats::{t_up, t_down}` carry
//! the durations of edge j's last completed uplink/downlink transfers —
//! contention and jitter included — instead of a freshly resampled
//! round-trip, so the agent sees the communication times the run actually
//! experienced.
//!
//! Normalization scales are derived from the run's own configuration
//! ([`StateScales::derive`]): the communication scale from the configured
//! link bandwidths and model size, the energy scale from the power band
//! and per-round epoch budget — so state entries stay O(1) across
//! topologies instead of assuming one calibration.
//!
//! Under churn-driven re-clustering (`hfl::membership`) the *composition*
//! of edge j changes mid-run, but the state stays well-formed: M is
//! fixed, and every per-edge feature is recomputed against the current
//! membership — row j's PCA score projects edge j's live model, and
//! `t_sgd_slowest`/`t_ec`/`E_j` come from the next round's stats, which
//! accumulate over the migrated member sets. The agent simply observes
//! edge j getting faster/slower as its membership shifts.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::hfl::{HflEngine, RoundStats};
use crate::pca::PcaModel;
use crate::sim::{EnergyModel, NetworkModel, Region};

/// Normalization scales so every state entry is O(1) for the CNN trunk.
#[derive(Clone, Debug)]
pub struct StateScales {
    pub round: f64,
    pub time: f64,
    pub sgd_time: f64,
    pub comm_time: f64,
    pub energy: f64,
    pub pca: f64,
    /// Cloud windows of upload staleness treated as O(1) (ctrl layout).
    pub staleness: f64,
    /// Concurrent uplink transfers treated as O(1) (ctrl layout).
    pub in_flight: f64,
}

impl Default for StateScales {
    fn default() -> Self {
        StateScales {
            round: 10.0,
            time: 3000.0,
            sgd_time: 200.0,
            comm_time: 60.0,
            energy: 50.0,
            pca: 10.0,
            staleness: 4.0,
            in_flight: 4.0,
        }
    }
}

impl StateScales {
    /// Derive the scales from a run's configuration instead of the fixed
    /// defaults: the communication scale is the worst-region expected
    /// round trip under the configured `link.*` bandwidth scales and model
    /// size, the SGD scale the slowest plausible per-dispatch compute
    /// (γ̃1 local epochs of `nb` batches at 2x interference slowdown), and
    /// the energy scale one edge's round energy at mid-band power. `nb`
    /// and `p` come from the artifact manifest (batches per epoch, flat
    /// parameter count).
    pub fn derive(
        cfg: &ExperimentConfig,
        net: &NetworkModel,
        nb: usize,
        p: usize,
    ) -> StateScales {
        let pbytes = crate::sim::network::model_bytes(p);
        let comm = [Region::Cn, Region::Us]
            .iter()
            .map(|&r| {
                let up = cfg.link.up_bandwidth_scale;
                let down = cfg.link.down_bandwidth_scale;
                net.one_way_mean(r, pbytes, up)
                    + net.one_way_mean(r, pbytes, down)
            })
            .fold(0.0, f64::max);
        let sgd =
            cfg.sim.sgd_base_time * 2.0 * (nb * cfg.hfl.gamma1_max) as f64;
        let energy_model =
            EnergyModel::new(cfg.sim.power_idle, cfg.sim.power_max);
        let p_mid = 0.5 * (cfg.sim.power_idle + cfg.sim.power_max);
        let t_round = cfg.sim.sgd_base_time
            * (nb * cfg.hfl.gamma1 * cfg.hfl.gamma2) as f64;
        let per_device = energy_model.to_mah(p_mid, t_round);
        let energy = per_device * cfg.devices_per_edge().max(1) as f64;
        StateScales {
            round: 10.0,
            time: cfg.hfl.threshold_time,
            sgd_time: sgd.max(1e-9),
            comm_time: comm.max(1e-9),
            energy: energy.max(1e-9),
            pca: 10.0,
            staleness: 4.0,
            // An edge rarely keeps more uploads in flight than it has
            // members (one per report), so that is the O(1) yardstick.
            in_flight: cfg.devices_per_edge().max(1) as f64,
        }
    }
}

pub struct StateBuilder {
    pub npca: usize,
    pub m: usize,
    pub scales: StateScales,
    /// Extended layout carrying the per-edge control (staleness) columns.
    pub ctrl: bool,
    pca: Option<PcaModel>,
}

impl StateBuilder {
    /// `scales` should come from [`StateScales::derive`] on any real run
    /// (tests may pass `StateScales::default()`): requiring them at
    /// construction keeps the topology-independent fallback off every
    /// reachable training/rollout path.
    pub fn new(m: usize, npca: usize, scales: StateScales) -> Self {
        StateBuilder {
            npca,
            m,
            scales,
            ctrl: false,
            pca: None,
        }
    }

    /// Switch to the extended (n_pca + 8 column) control layout; the
    /// matching `_ctrl` PPO artifacts must be built for it.
    pub fn with_ctrl(mut self, ctrl: bool) -> Self {
        self.ctrl = ctrl;
        self
    }

    pub fn rows(&self) -> usize {
        self.m + 1
    }

    pub fn cols(&self) -> usize {
        self.npca + if self.ctrl { 8 } else { 3 }
    }

    pub fn pca_ready(&self) -> bool {
        self.pca.is_some()
    }

    /// Fit the PCA loadings from the engine's current [cloud; edges] models
    /// (paper: after the first cloud aggregation).
    pub fn fit_pca(&mut self, engine: &HflEngine) {
        let stack = engine.model_stack();
        self.pca = Some(PcaModel::fit(&stack, self.npca));
    }

    /// Build the flattened state matrix for round k.
    pub fn build(
        &self,
        engine: &HflEngine,
        last: &RoundStats,
    ) -> Result<Vec<f32>> {
        let pca = self
            .pca
            .as_ref()
            .expect("fit_pca must run after the first cloud aggregation");
        let scores = engine.pca_scores(pca)?;
        let rows = self.rows();
        let cols = self.cols();
        let mut s = vec![0.0f32; rows * cols];
        let sc = &self.scales;
        // Row 0: cloud PCA + global parameters (Eq. 9).
        for (c, &v) in scores[0].iter().take(self.npca).enumerate() {
            s[c] = v / sc.pca as f32;
        }
        s[self.npca] = last.k as f32 / sc.round as f32;
        s[self.npca + 1] = (engine.remaining_time() / sc.time) as f32;
        s[self.npca + 2] = last.accuracy as f32;
        // Rows 1..=M: edge PCA + h_j (Eq. 7).
        for j in 0..self.m {
            let base = (j + 1) * cols;
            for (c, &v) in scores[j + 1].iter().take(self.npca).enumerate() {
                s[base + c] = v / sc.pca as f32;
            }
            let e = &last.per_edge[j];
            s[base + self.npca] = (e.t_sgd_slowest / sc.sgd_time) as f32;
            // t_ec is the observed round trip of the edge's last landed
            // transfers (see EdgeStats), not a resampled draw.
            s[base + self.npca + 1] = (e.t_ec / sc.comm_time) as f32;
            s[base + self.npca + 2] = (e.energy / sc.energy) as f32;
            if self.ctrl {
                s[base + self.npca + 3] = (e.staleness / sc.staleness) as f32;
                s[base + self.npca + 4] =
                    (e.in_flight_up as f64 / sc.in_flight) as f32;
                s[base + self.npca + 5] = e.quorum_fill as f32;
                // Lifecycle observables (already in [0, 1]): the edge's
                // abandonment rate this window and its membership's
                // diurnal availability at the decision point.
                s[base + self.npca + 6] = e.abandon_rate() as f32;
                s[base + self.npca + 7] = e.availability as f32;
            }
        }
        if self.ctrl {
            // Row 0 control columns: population means of the per-edge
            // signals (the cloud's aggregate view of how stale its inputs
            // run).
            let m = self.m.max(1) as f32;
            for off in 0..5 {
                let mut sum = 0.0f32;
                for j in 0..self.m {
                    sum += s[(j + 1) * cols + self.npca + 3 + off];
                }
                s[self.npca + 3 + off] = sum / m;
            }
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_default_sane() {
        let s = StateScales::default();
        assert!(s.time > 0.0 && s.energy > 0.0 && s.staleness > 0.0);
    }

    #[test]
    fn dims() {
        let b = StateBuilder::new(5, 6, StateScales::default());
        assert_eq!(b.rows(), 6);
        assert_eq!(b.cols(), 9);
        assert!(!b.pca_ready());
        let b = b.with_ctrl(true);
        assert_eq!(b.cols(), 14, "ctrl layout adds 5 columns");
    }

    #[test]
    fn derived_scales_track_config() {
        let cfg = ExperimentConfig::mnist();
        let net = NetworkModel::from_config(&cfg.sim);
        let s = StateScales::derive(&cfg, &net, 2, 21_840);
        assert!((s.time - cfg.hfl.threshold_time).abs() < 1e-12);
        assert!(s.comm_time > 0.0 && s.energy > 0.0 && s.sgd_time > 0.0);
        // Halving the uplink bandwidth must widen the comm scale: the
        // derived scales react to the link config (the old hard-coded
        // 60.0/50.0 did not).
        let mut slow = cfg.clone();
        slow.link.up_bandwidth_scale = 0.25;
        let s2 = StateScales::derive(&slow, &net, 2, 21_840);
        assert!(s2.comm_time > s.comm_time);
        // A heavier epoch budget must widen the energy scale.
        let mut heavy = cfg.clone();
        heavy.hfl.gamma1 *= 2;
        let s3 = StateScales::derive(&heavy, &net, 2, 21_840);
        assert!(s3.energy > s.energy);
        // The in-flight yardstick follows the edge population.
        assert!(
            (s.in_flight - cfg.devices_per_edge() as f64).abs() < 1e-12
        );
    }
}
