//! Trajectory memory (paper Algorithm 1, line 12: "push (s, a, r, s') to
//! agent memory"). Collected per episode, padded to the ppo_update
//! artifact's fixed batch length with a zero mask.

#[derive(Clone, Debug)]
pub struct Transition {
    pub state: Vec<f32>,
    pub raw_action: Vec<f32>,
    pub log_prob: f64,
    pub value: f64,
    pub reward: f64,
}

#[derive(Clone, Debug, Default)]
pub struct Trajectory {
    pub steps: Vec<Transition>,
}

impl Trajectory {
    pub fn push(&mut self, t: Transition) {
        self.steps.push(t);
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn rewards(&self) -> Vec<f64> {
        self.steps.iter().map(|t| t.reward).collect()
    }

    pub fn values(&self) -> Vec<f64> {
        self.steps.iter().map(|t| t.value).collect()
    }

    /// Flatten into the ppo_update batch layout, truncating/padding to
    /// `batch` rows. Returns (states, actions, old_logp, adv, ret, mask).
    pub fn to_batch(
        &self,
        adv: &[f64],
        ret: &[f64],
        batch: usize,
        state_len: usize,
        act_len: usize,
    ) -> PpoBatch {
        assert_eq!(adv.len(), self.len());
        assert_eq!(ret.len(), self.len());
        let n = self.len().min(batch);
        let mut states = vec![0.0f32; batch * state_len];
        let mut actions = vec![0.0f32; batch * act_len];
        let mut old_logp = vec![0.0f32; batch];
        let mut advantages = vec![0.0f32; batch];
        let mut returns = vec![0.0f32; batch];
        let mut mask = vec![0.0f32; batch];
        for (i, t) in self.steps.iter().take(n).enumerate() {
            assert_eq!(t.state.len(), state_len);
            assert_eq!(t.raw_action.len(), act_len);
            states[i * state_len..(i + 1) * state_len]
                .copy_from_slice(&t.state);
            actions[i * act_len..(i + 1) * act_len]
                .copy_from_slice(&t.raw_action);
            old_logp[i] = t.log_prob as f32;
            advantages[i] = adv[i] as f32;
            returns[i] = ret[i] as f32;
            mask[i] = 1.0;
        }
        PpoBatch {
            states,
            actions,
            old_logp,
            advantages,
            returns,
            mask,
        }
    }
}

pub struct PpoBatch {
    pub states: Vec<f32>,
    pub actions: Vec<f32>,
    pub old_logp: Vec<f32>,
    pub advantages: Vec<f32>,
    pub returns: Vec<f32>,
    pub mask: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(r: f64) -> Transition {
        Transition {
            state: vec![1.0; 6],
            raw_action: vec![0.5; 4],
            log_prob: -2.0,
            value: 0.3,
            reward: r,
        }
    }

    #[test]
    fn batch_pads_with_zero_mask() {
        let mut t = Trajectory::default();
        t.push(step(1.0));
        t.push(step(2.0));
        let adv = vec![0.1, 0.2];
        let ret = vec![1.0, 2.0];
        let b = t.to_batch(&adv, &ret, 4, 6, 4);
        assert_eq!(b.mask, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(b.states.len(), 4 * 6);
        assert_eq!(b.actions[0], 0.5);
        assert_eq!(b.returns[1], 2.0);
        assert_eq!(b.returns[2], 0.0);
    }

    #[test]
    fn batch_truncates_long_trajectories() {
        let mut t = Trajectory::default();
        for i in 0..10 {
            t.push(step(i as f64));
        }
        let adv = vec![0.0; 10];
        let ret = vec![0.0; 10];
        let b = t.to_batch(&adv, &ret, 4, 6, 4);
        assert_eq!(b.mask, vec![1.0; 4]);
    }
}
