//! Metrics core: counters, gauges and log₂-bucketed histograms behind a
//! name-keyed registry with deterministic Prometheus text exposition.
//!
//! Dependency-free by design (std only). Keys are flat Prometheus metric
//! names (`[a-zA-Z_:][a-zA-Z0-9_:]*`, by caller convention); the registry
//! stores them in `BTreeMap`s and renders them sorted, so the exposition
//! text is a pure function of the recorded values — exact-text golden
//! tests stay stable across runs and platforms.

use std::collections::BTreeMap;

/// Number of log₂ buckets in a [`Histogram`]. Bucket `i` holds values in
/// `(2^(i-1), 2^i]` (bucket 0 holds everything ≤ 1); the last bucket also
/// absorbs +inf / overflow.
pub const BUCKETS: usize = 64;

/// Log₂ bucket index for `v`: the smallest `i` with `v <= 2^i`, clamped
/// to `[0, BUCKETS-1]`. NaN and values ≤ 1 land in bucket 0.
#[inline]
pub fn bucket_index(v: f64) -> usize {
    if !(v > 1.0) {
        return 0;
    }
    if v >= 9.0e18 {
        return BUCKETS - 1;
    }
    let n = v.ceil() as u64;
    (64 - (n - 1).leading_zeros() as usize).min(BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i` (`2^i`).
#[inline]
pub fn bucket_upper(i: usize) -> f64 {
    2f64.powi(i as i32)
}

/// Fixed-size log₂-bucketed histogram: O(1) record, O(1) merge, and
/// approximate percentiles with ≤2x relative error — latency telemetry
/// without per-sample storage on the hot path.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn record(&mut self, v: f64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn bucket_count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// Approximate `p`-th percentile (`p` in `[0, 100]`): the upper bound
    /// of the bucket holding the `ceil(p/100·count)`-th smallest sample,
    /// clamped to the observed `[min, max]` range. 0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target =
            ((p / 100.0 * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_upper(i).min(self.max).max(self.min);
            }
        }
        self.max
    }

    /// Merge another histogram into this one (counts, sum and range).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }
}

/// Format an f64 for exposition: integral values print without a
/// fractional part so the golden text stays platform-independent.
pub fn fmt_value(v: f64) -> String {
    if v.is_finite() && v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Name-keyed registry of counters, gauges and histograms.
///
/// All maps are `BTreeMap`s, so [`Registry::render_prometheus`] output is
/// fully ordered: counters, then gauges, then histograms, each sorted by
/// metric name.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Increment counter `name` by 1 (created at 0 on first touch).
    pub fn inc(&mut self, name: &str) {
        self.inc_by(name, 1);
    }

    pub fn inc_by(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Record `v` into histogram `name` (created empty on first touch).
    pub fn observe(&mut self, name: &str, v: f64) {
        self.hists
            .entry(name.to_string())
            .or_insert_with(Histogram::new)
            .record(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
            && self.gauges.is_empty()
            && self.hists.is_empty()
    }

    /// Merge another registry into this one: counters add, gauges take
    /// the other's value, histograms merge.
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            self.inc_by(k, *v);
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.hists {
            self.hists
                .entry(k.clone())
                .or_insert_with(Histogram::new)
                .merge(h);
        }
    }

    /// Prometheus text exposition (format version 0.0.4). Histograms emit
    /// cumulative `_bucket{le=...}` series up to the highest non-empty
    /// bucket plus `+Inf`, then `_sum` and `_count`.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            out.push_str(&format!("# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {}\n", fmt_value(*v)));
        }
        for (name, h) in &self.hists {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let top = h
                .counts
                .iter()
                .rposition(|&c| c > 0)
                .unwrap_or(0);
            let mut cum = 0u64;
            for (i, &c) in h.counts.iter().enumerate().take(top + 1) {
                cum += c;
                // Upper bounds are exact powers of two: print integral.
                let le = 1u128 << i;
                out.push_str(&format!(
                    "{name}_bucket{{le=\"{le}\"}} {cum}\n"
                ));
            }
            out.push_str(&format!(
                "{name}_bucket{{le=\"+Inf\"}} {}\n",
                h.count
            ));
            out.push_str(&format!(
                "{name}_sum {}\n",
                fmt_value(h.sum)
            ));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_exact_powers_of_two() {
        // Bucket i holds (2^(i-1), 2^i]; boundary values land low.
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(-3.0), 0);
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(1.0), 0);
        assert_eq!(bucket_index(1.5), 1);
        assert_eq!(bucket_index(2.0), 1);
        assert_eq!(bucket_index(2.0000001), 2);
        assert_eq!(bucket_index(4.0), 2);
        assert_eq!(bucket_index(100.0), 7);
        assert_eq!(bucket_index(128.0), 7);
        assert_eq!(bucket_index(129.0), 8);
        assert_eq!(bucket_index(1e30), BUCKETS - 1);
        assert_eq!(bucket_upper(0), 1.0);
        assert_eq!(bucket_upper(10), 1024.0);
    }

    #[test]
    fn histogram_records_and_ranks() {
        let mut h = Histogram::new();
        for v in [1.0, 3.0, 3.5, 100.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 107.5).abs() < 1e-12);
        assert_eq!(h.bucket_count(0), 1);
        assert_eq!(h.bucket_count(2), 2);
        assert_eq!(h.bucket_count(7), 1);
        // p50 falls in bucket 2 → upper bound 4.
        assert_eq!(h.percentile(50.0), 4.0);
        // p99 falls in the top bucket, clamped to the observed max.
        assert_eq!(h.percentile(99.0), 100.0);
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(Histogram::new().percentile(50.0), 0.0);
    }

    #[test]
    fn histogram_merge_adds_counts_and_range() {
        let mut a = Histogram::new();
        a.record(2.0);
        a.record(10.0);
        let mut b = Histogram::new();
        b.record(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!((a.sum() - 1012.0).abs() < 1e-12);
        assert_eq!(a.percentile(100.0), 1000.0);
        assert_eq!(a.bucket_count(bucket_index(1000.0)), 1);
    }

    #[test]
    fn registry_accessors() {
        let mut r = Registry::new();
        assert!(r.is_empty());
        r.inc("a_total");
        r.inc_by("a_total", 2);
        r.set_gauge("g", 1.25);
        r.observe("h", 5.0);
        assert_eq!(r.counter("a_total"), 3);
        assert_eq!(r.counter("missing"), 0);
        assert_eq!(r.gauge("g"), Some(1.25));
        assert_eq!(r.histogram("h").unwrap().count(), 1);
        assert!(!r.is_empty());
        let mut r2 = Registry::new();
        r2.inc_by("a_total", 7);
        r2.observe("h", 6.0);
        r.merge(&r2);
        assert_eq!(r.counter("a_total"), 10);
        assert_eq!(r.histogram("h").unwrap().count(), 2);
    }

    #[test]
    fn prometheus_exposition_golden_text() {
        let mut r = Registry::new();
        r.inc_by("arena_events_total", 41);
        r.inc("arena_events_total");
        r.inc("arena_rounds_total");
        r.set_gauge("arena_accuracy", 0.5);
        r.observe("arena_lag_ns", 1.0);
        r.observe("arena_lag_ns", 3.0);
        r.observe("arena_lag_ns", 100.0);
        let want = "\
# TYPE arena_events_total counter
arena_events_total 42
# TYPE arena_rounds_total counter
arena_rounds_total 1
# TYPE arena_accuracy gauge
arena_accuracy 0.5
# TYPE arena_lag_ns histogram
arena_lag_ns_bucket{le=\"1\"} 1
arena_lag_ns_bucket{le=\"2\"} 1
arena_lag_ns_bucket{le=\"4\"} 2
arena_lag_ns_bucket{le=\"8\"} 2
arena_lag_ns_bucket{le=\"16\"} 2
arena_lag_ns_bucket{le=\"32\"} 2
arena_lag_ns_bucket{le=\"64\"} 2
arena_lag_ns_bucket{le=\"128\"} 3
arena_lag_ns_bucket{le=\"+Inf\"} 3
arena_lag_ns_sum 104
arena_lag_ns_count 3
";
        assert_eq!(r.render_prometheus(), want);
    }
}
