//! Span buffer and Chrome-trace JSON exporter.
//!
//! Spans carry *both* clocks: simulated start/end times (the timeline the
//! exported trace draws) and the wall-clock nanoseconds the host spent,
//! stashed in the event `args` for profiling. The exporter emits the
//! Chrome trace-event JSON array format — load the file at
//! `chrome://tracing` (or <https://ui.perfetto.dev>) to see device
//! training bursts, in-flight transfers and cloud windows on one track
//! per edge.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// One closed interval on a named track, stamped with sim-time endpoints
/// and the wall-clock cost of whatever produced it (0 when the work was
/// purely simulated).
#[derive(Clone, Debug)]
pub struct Span {
    /// Track name, e.g. `edge/3`, `cloud`, `harness`.
    pub track: String,
    /// Event name, e.g. `train d12`, `up e3`, `window 4`.
    pub name: String,
    /// Simulated start time, seconds.
    pub t0_sim: f64,
    /// Simulated end time, seconds.
    pub t1_sim: f64,
    /// Host wall-clock spent producing this span, nanoseconds.
    pub wall_ns: u64,
}

/// Canonical track name for shard `i` of the parallel runtime. The
/// sharded sim emits one span per shard per window on these tracks.
pub fn shard_track(i: usize) -> String {
    format!("shard/{i}")
}

/// Canonical track name for worker `i` of a `ShardPool` — spans carry
/// the worker's busy wall-ns per window in `args.wall_ns`.
pub fn worker_track(i: usize) -> String {
    format!("worker/{i}")
}

/// Append-only span store. Track ids are assigned in first-seen order,
/// which is deterministic because span emission follows the (seeded)
/// event timeline.
#[derive(Clone, Debug, Default)]
pub struct TraceBuffer {
    spans: Vec<Span>,
    track_ids: BTreeMap<String, usize>,
    track_order: Vec<String>,
}

impl TraceBuffer {
    pub fn new() -> Self {
        TraceBuffer::default()
    }

    pub fn push(&mut self, span: Span) {
        if !self.track_ids.contains_key(&span.track) {
            let id = self.track_order.len();
            self.track_ids.insert(span.track.clone(), id);
            self.track_order.push(span.track.clone());
        }
        self.spans.push(span);
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    pub fn tracks(&self) -> &[String] {
        &self.track_order
    }

    /// Chrome trace-event JSON: one `thread_name` metadata event per
    /// track, then one complete (`"ph":"X"`) event per span with `ts` /
    /// `dur` in microseconds of *simulated* time and the wall-clock cost
    /// in `args.wall_ns`.
    pub fn to_chrome_json(&self) -> String {
        let mut events = Vec::new();
        for (tid, track) in self.track_order.iter().enumerate() {
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid as f64)),
                (
                    "args",
                    Json::obj(vec![("name", Json::str(track.clone()))]),
                ),
            ]));
        }
        for s in &self.spans {
            let tid = self.track_ids[&s.track];
            events.push(Json::obj(vec![
                ("name", Json::str(s.name.clone())),
                ("ph", Json::str("X")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid as f64)),
                ("ts", Json::num(s.t0_sim * 1e6)),
                ("dur", Json::num((s.t1_sim - s.t0_sim).max(0.0) * 1e6)),
                (
                    "args",
                    Json::obj(vec![(
                        "wall_ns",
                        Json::num(s.wall_ns as f64),
                    )]),
                ),
            ]));
        }
        Json::obj(vec![("traceEvents", Json::Arr(events))]).to_string()
    }

    /// Write the Chrome-trace JSON to `path`.
    pub fn write_chrome_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(track: &str, name: &str, t0: f64, t1: f64) -> Span {
        Span {
            track: track.to_string(),
            name: name.to_string(),
            t0_sim: t0,
            t1_sim: t1,
            wall_ns: 42,
        }
    }

    #[test]
    fn tracks_dedup_in_first_seen_order() {
        let mut tb = TraceBuffer::new();
        tb.push(span("edge/1", "a", 0.0, 1.0));
        tb.push(span("cloud", "b", 1.0, 2.0));
        tb.push(span("edge/1", "c", 2.0, 3.0));
        assert_eq!(tb.len(), 3);
        assert_eq!(tb.tracks(), &["edge/1".to_string(), "cloud".into()]);
    }

    #[test]
    fn chrome_json_has_metadata_and_microsecond_ts() {
        let mut tb = TraceBuffer::new();
        tb.push(span("edge/0", "train d3", 1.5, 2.5));
        let text = tb.to_chrome_json();
        let j = Json::parse(&text).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        // Metadata event names the track.
        assert_eq!(
            events[0].path("args.name").unwrap().as_str().unwrap(),
            "edge/0"
        );
        assert_eq!(events[0].get("ph").unwrap().as_str().unwrap(), "M");
        // Span event: sim seconds scaled to microseconds.
        let e = &events[1];
        assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(e.get("ts").unwrap().as_f64().unwrap(), 1.5e6);
        assert_eq!(e.get("dur").unwrap().as_f64().unwrap(), 1e6);
        assert_eq!(
            e.path("args.wall_ns").unwrap().as_f64().unwrap(),
            42.0
        );
    }

    #[test]
    fn shard_and_worker_track_names() {
        assert_eq!(shard_track(3), "shard/3");
        assert_eq!(worker_track(0), "worker/0");
    }

    #[test]
    fn negative_duration_is_clamped() {
        let mut tb = TraceBuffer::new();
        tb.push(span("t", "x", 5.0, 4.0));
        let j = Json::parse(&tb.to_chrome_json()).unwrap();
        let e = &j.get("traceEvents").unwrap().as_arr().unwrap()[1];
        assert_eq!(e.get("dur").unwrap().as_f64().unwrap(), 0.0);
    }
}
