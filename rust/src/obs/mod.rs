//! # Observability: deterministic tracing, metrics, live telemetry
//!
//! Three std-only layers on top of `util/`:
//!
//! 1. **Core** ([`metrics`], [`trace`]) — a [`Registry`] of counters,
//!    gauges and log₂-bucketed [`Histogram`]s, plus a [`TraceBuffer`] of
//!    [`Span`]s stamped with *both* simulated time and host wall-clock.
//! 2. **Exporters** — Prometheus text exposition
//!    ([`Registry::render_prometheus`]) and Chrome-trace JSON
//!    ([`TraceBuffer::to_chrome_json`], loadable at `chrome://tracing`).
//! 3. **Server** ([`server`]) — a `TcpListener` thread serving scrapes
//!    and an NDJSON round stream while a run is in progress.
//!
//! ## Observer contract (the no-feedback rule)
//!
//! Engines expose an optional [`Observer`]; every hook has a no-op
//! default, and an engine with no observer attached pays nothing. The
//! contract that makes this observability rather than logging:
//!
//! - **Hooks read, never mutate.** An observer receives borrowed or
//!   copied facts about the run and has no channel back into engine
//!   state, RNG streams, or the event queue.
//! - **Wall-clock never feeds back.** `Instant` reads happen only when
//!   an observer is attached and flow only into observer records; no
//!   simulated timestamp, seed, or decision ever derives from them.
//!
//! Together these extend the engine's determinism guarantee family
//! (sync-equivalence, zero-churn no-op, re-arm no-op) with a fourth:
//! **observer-on == observer-off, bitwise** — asserted by the
//! `observer_attach_is_bitwise_noop` integration test — and, since the
//! [`profiler`] landed, a fifth: **profiler-on == profiler-off** on the
//! sharded parallel runtime (`tests/obs_profiler.rs`).
//!
//! ## Parallel-runtime shard metrics
//!
//! With an observer attached to a `ShardedDeviceSim` (and
//! `sim.profiler` on, the default), every window barrier folds the
//! per-shard [`ShardWindowProfile`]s into the registry **in fixed shard
//! order** — the exposition's metric-name set and every sim-derived
//! value are identical at any `sim.workers`; wall-clock values flow
//! only into observer records. The catalog:
//!
//! - counters (sim-derived): `arena_shard_windows_total`,
//!   `arena_shard_events_total`, `arena_shard_voided_total`,
//!   `arena_shard_aggregates_total`, `arena_shard_flips_total`,
//!   `arena_shard_adopt_across_total`, `arena_shard_replicate_total`;
//!   injected-fault counters `arena_fault_outage_total`,
//!   `arena_fault_partition_total`, `arena_fault_crash_total` and the
//!   roll-up `arena_fault_events_total` (also fed per-event by
//!   [`Observer::on_fault`] on the event engines)
//! - gauges (sim-derived): `arena_shard_count`,
//!   `arena_shard_live_devices`, `arena_shard_queue_depth_peak`,
//!   `arena_shard_imbalance` (max/mean per-shard events),
//!   `arena_sharded_store_live_buffers` / `_peak_bytes` /
//!   `_sharing_ratio` (+ `_total_refs`, `_adopt_across`, `_adopt_bytes`,
//!   `_replicate`, `_replicate_bytes` from
//!   [`Observer::on_sharded_store`])
//! - histograms (sim-derived): `arena_shard_events_per_window`,
//!   `arena_shard_queue_depth`
//! - wall-clock (observer records only): `arena_shard_advance_wall_ns`,
//!   `arena_shard_barrier_stall_ns`, `arena_pool_window_wall_ns`,
//!   `arena_pool_worker_busy_ns`, `arena_pool_sim_batch_wall_ns`
//!   histograms; `arena_pool_workers` / `arena_pool_occupancy` gauges;
//!   `arena_pool_sim_batches_total` / `arena_pool_sim_batch_items_total`
//!   counters
//!
//! Each barrier also emits one `"type":"shard_window"` NDJSON frame
//! (see [`shard_window_frame`]) and per-shard / per-worker trace spans
//! on the [`trace::shard_track`] / [`trace::worker_track`] tracks.
//!
//! ## Endpoints (`arena run --serve <addr>`)
//!
//! ```text
//! curl http://127.0.0.1:9898/            # live dashboard (HTML+JS)
//! curl http://127.0.0.1:9898/healthz     # -> ok
//! curl http://127.0.0.1:9898/metrics     # Prometheus text exposition
//! curl -sN http://127.0.0.1:9898/stream | head -n1  # one NDJSON frame
//! curl http://127.0.0.1:9898/trace > trace.json  # current Chrome trace
//! ```
//!
//! `/stream` frames are one JSON object per line with a
//! `"schema_version"` field (see `hfl::metrics::SCHEMA_VERSION`); new
//! subscribers receive the most recent frame first, then live frames as
//! cloud rounds close (and, on the sharded runtime, as window barriers
//! close). `GET /` serves a self-contained dashboard (embedded HTML+JS,
//! no external assets) that consumes `/stream` + `/metrics` and renders
//! round progress, per-edge staleness, shard imbalance and
//! barrier-stall sparklines live. `/trace` serves the current
//! Chrome-trace JSON; `--trace-out <path>` additionally writes the
//! final timeline to a file at the end of the run.

pub mod metrics;
pub mod profiler;
pub mod server;
pub mod trace;

pub use metrics::{Histogram, Registry};
pub use profiler::{
    shard_imbalance, PoolWindowProfile, ShardProfiler, ShardWindowProfile,
};
pub use server::{TelemetryServer, TelemetrySink};
pub use trace::{Span, TraceBuffer};

use std::sync::{Arc, Mutex, OnceLock};

use crate::hfl::metrics::RoundStats;
use crate::hfl::model_store::ShardedStoreStats;
use crate::sim::shard::WindowRow;
use crate::util::json::Json;

/// Read-only run instrumentation. Every hook defaults to a no-op so the
/// trait doubles as its own null object; engines call hooks only when an
/// observer is attached and skip all wall-clock reads otherwise.
pub trait Observer: Send {
    /// One event was popped and handled: its variant name, the simulated
    /// time it fired at, the wall-ns between dequeue and handler entry,
    /// and the handler's wall-ns cost.
    fn on_event_handled(
        &mut self,
        _variant: &'static str,
        _sim_time: f64,
        _dequeue_lag_ns: u64,
        _handler_ns: u64,
    ) {
    }

    /// A closed interval on the sim timeline (training burst, transfer,
    /// cloud window, harness phase).
    fn on_span(&mut self, _span: Span) {}

    /// A transfer completed its lifetime `[start, finish]` (sim
    /// seconds) on `edge`'s `dir` link.
    fn on_transfer(
        &mut self,
        _edge: usize,
        _dir: &'static str,
        _bytes: f64,
        _start: f64,
        _finish: f64,
    ) {
    }

    /// A cloud round / window closed.
    fn on_round(&mut self, _stats: &RoundStats) {}

    /// A re-clustering executed at sim time `at`, migrating `migrated`
    /// devices at a host cost of `wall_ns`.
    fn on_recluster(&mut self, _at: f64, _migrated: usize, _wall_ns: u64) {}

    /// An injected fault event was applied (`kind` ∈ `"outage"`,
    /// `"partition"`, `"crash"`, `"recovery"`).
    fn on_fault(&mut self, _kind: &'static str) {}

    /// Model-store occupancy snapshot at a round boundary.
    fn on_store(
        &mut self,
        _live_buffers: usize,
        _peak_bytes: usize,
        _sharing_ratio: f64,
    ) {
    }

    /// A sharded-runtime window barrier closed: the merged `row` plus
    /// the per-shard profiles (**fixed shard order**, whatever order
    /// worker threads finished in) and the pool-side occupancy view.
    fn on_shard_barrier(
        &mut self,
        _row: &WindowRow,
        _shards: &[ShardWindowProfile],
        _pool: &PoolWindowProfile,
    ) {
    }

    /// One parallel per-device simulation batch completed on the
    /// engines' shared `ShardPool` (`items` requests over `workers`).
    fn on_sim_batch(&mut self, _items: usize, _workers: usize, _wall_ns: u64) {
    }

    /// Sharded model-store observables snapshot (per-shard slab
    /// occupancy + cumulative cross-shard traffic).
    fn on_sharded_store(&mut self, _stats: &ShardedStoreStats) {}
}

/// The do-nothing observer (useful as an overhead baseline in benches).
#[derive(Default, Clone, Copy)]
pub struct NoopObserver;

impl Observer for NoopObserver {}

/// Everything a [`RunObserver`] accumulates, shared behind
/// `Arc<Mutex<_>>` so the CLI keeps a reader handle while the engine
/// owns the observer box.
#[derive(Default)]
pub struct ObsState {
    pub registry: Registry,
    pub trace: TraceBuffer,
}

/// The standard observer: folds hooks into a metrics [`Registry`] and a
/// [`TraceBuffer`], and (optionally) publishes round frames + fresh
/// exposition text to a [`TelemetrySink`].
pub struct RunObserver {
    state: Arc<Mutex<ObsState>>,
    sink: Option<TelemetrySink>,
}

impl Default for RunObserver {
    fn default() -> Self {
        RunObserver::new()
    }
}

impl RunObserver {
    pub fn new() -> Self {
        RunObserver {
            state: Arc::new(Mutex::new(ObsState::default())),
            sink: None,
        }
    }

    pub fn with_sink(sink: TelemetrySink) -> Self {
        RunObserver {
            state: Arc::new(Mutex::new(ObsState::default())),
            sink: Some(sink),
        }
    }

    /// Reader handle onto the accumulated registry + trace.
    pub fn state(&self) -> Arc<Mutex<ObsState>> {
        self.state.clone()
    }
}

impl Observer for RunObserver {
    fn on_event_handled(
        &mut self,
        variant: &'static str,
        _sim_time: f64,
        dequeue_lag_ns: u64,
        handler_ns: u64,
    ) {
        let mut st = self.state.lock().unwrap();
        st.registry.inc("arena_events_total");
        st.registry
            .inc(&format!("arena_events_{variant}_total"));
        st.registry
            .observe("arena_event_dequeue_lag_ns", dequeue_lag_ns as f64);
        st.registry.observe(
            &format!("arena_handler_wall_ns_{variant}"),
            handler_ns as f64,
        );
    }

    fn on_span(&mut self, span: Span) {
        self.state.lock().unwrap().trace.push(span);
    }

    fn on_transfer(
        &mut self,
        edge: usize,
        dir: &'static str,
        _bytes: f64,
        start: f64,
        finish: f64,
    ) {
        let mut st = self.state.lock().unwrap();
        st.registry.inc("arena_transfers_total");
        st.registry.inc(&format!("arena_transfers_{dir}_total"));
        st.registry.observe(
            "arena_transfer_lifetime_seconds",
            (finish - start).max(0.0),
        );
        st.trace.push(Span {
            track: format!("edge/{edge}"),
            name: format!("xfer {dir}"),
            t0_sim: start,
            t1_sim: finish,
            wall_ns: 0,
        });
    }

    fn on_round(&mut self, stats: &RoundStats) {
        {
            let mut st = self.state.lock().unwrap();
            st.registry.inc("arena_rounds_total");
            st.registry.set_gauge("arena_round_k", stats.k as f64);
            st.registry
                .set_gauge("arena_round_accuracy", stats.accuracy);
            st.registry
                .set_gauge("arena_round_train_loss", stats.train_loss);
            st.registry
                .set_gauge("arena_sim_time_seconds", stats.sim_now);
            st.registry
                .set_gauge("arena_round_energy_mah", stats.energy);
            st.registry.set_gauge(
                "arena_active_devices",
                stats.active_devices as f64,
            );
            st.registry.set_gauge(
                "arena_mean_staleness",
                stats.mean_staleness(),
            );
            st.registry.set_gauge(
                "arena_mean_link_util",
                stats.mean_link_util(),
            );
            st.registry.observe(
                "arena_round_time_seconds",
                stats.round_time,
            );
            st.trace.push(Span {
                track: "cloud".to_string(),
                name: format!("window {}", stats.k),
                t0_sim: stats.sim_now - stats.round_time,
                t1_sim: stats.sim_now,
                wall_ns: 0,
            });
        }
        if let Some(sink) = &self.sink {
            sink.push_frame(&round_frame(stats));
            let st = self.state.lock().unwrap();
            sink.set_metrics(st.registry.render_prometheus());
        }
    }

    fn on_recluster(&mut self, _at: f64, migrated: usize, wall_ns: u64) {
        let mut st = self.state.lock().unwrap();
        st.registry.inc("arena_reclusters_total");
        st.registry
            .inc_by("arena_migrated_devices_total", migrated as u64);
        st.registry
            .observe("arena_recluster_wall_ns", wall_ns as f64);
    }

    fn on_fault(&mut self, kind: &'static str) {
        let mut st = self.state.lock().unwrap();
        st.registry.inc("arena_fault_events_total");
        st.registry.inc(&format!("arena_fault_{kind}_total"));
    }

    fn on_store(
        &mut self,
        live_buffers: usize,
        peak_bytes: usize,
        sharing_ratio: f64,
    ) {
        let mut st = self.state.lock().unwrap();
        st.registry
            .set_gauge("arena_store_live_buffers", live_buffers as f64);
        st.registry
            .set_gauge("arena_store_peak_bytes", peak_bytes as f64);
        st.registry
            .set_gauge("arena_store_sharing_ratio", sharing_ratio);
    }

    fn on_shard_barrier(
        &mut self,
        row: &WindowRow,
        shards: &[ShardWindowProfile],
        pool: &PoolWindowProfile,
    ) {
        let imbalance = shard_imbalance(shards);
        {
            let mut st = self.state.lock().unwrap();
            st.registry.inc("arena_shard_windows_total");
            let mut events = 0u64;
            let mut voided = 0u64;
            let mut aggregates = 0u64;
            let mut flips = 0u64;
            let mut adopt = 0u64;
            let mut replicate = 0u64;
            let mut outages = 0u64;
            let mut partitions = 0u64;
            let mut crashes = 0u64;
            let mut live = 0usize;
            let mut depth_peak = 0usize;
            let mut store_live = 0usize;
            let mut store_peak = 0usize;
            let mut shared = 0usize;
            let mut handles = 0usize;
            for p in shards {
                events += p.events;
                voided += p.voided;
                aggregates += p.aggregates;
                flips += p.flips;
                adopt += p.adopt_across;
                replicate += p.replicate;
                outages += p.outages;
                partitions += p.partitions;
                crashes += p.crashes;
                live += p.live_devices;
                depth_peak = depth_peak.max(p.queue_depth_peak);
                store_live += p.store_live_buffers;
                store_peak += p.store_peak_bytes;
                shared += p.store_shared_handles;
                handles += p.store_handles;
                st.registry.observe(
                    "arena_shard_events_per_window",
                    p.events as f64,
                );
                st.registry.observe(
                    "arena_shard_queue_depth",
                    p.queue_depth_peak as f64,
                );
                st.registry.observe(
                    "arena_shard_advance_wall_ns",
                    p.advance_wall_ns as f64,
                );
                st.registry.observe(
                    "arena_shard_barrier_stall_ns",
                    p.barrier_stall_ns as f64,
                );
            }
            st.registry.inc_by("arena_shard_events_total", events);
            st.registry.inc_by("arena_shard_voided_total", voided);
            st.registry
                .inc_by("arena_shard_aggregates_total", aggregates);
            st.registry.inc_by("arena_shard_flips_total", flips);
            st.registry.inc_by("arena_shard_adopt_across_total", adopt);
            st.registry
                .inc_by("arena_shard_replicate_total", replicate);
            st.registry.inc_by("arena_fault_outage_total", outages);
            st.registry
                .inc_by("arena_fault_partition_total", partitions);
            st.registry.inc_by("arena_fault_crash_total", crashes);
            st.registry.inc_by(
                "arena_fault_events_total",
                outages + partitions + crashes,
            );
            st.registry
                .set_gauge("arena_shard_count", shards.len() as f64);
            st.registry
                .set_gauge("arena_shard_live_devices", live as f64);
            st.registry.set_gauge(
                "arena_shard_queue_depth_peak",
                depth_peak as f64,
            );
            st.registry.set_gauge("arena_shard_imbalance", imbalance);
            st.registry.set_gauge(
                "arena_sharded_store_live_buffers",
                store_live as f64,
            );
            st.registry.set_gauge(
                "arena_sharded_store_peak_bytes",
                store_peak as f64,
            );
            let ratio = if handles == 0 {
                0.0
            } else {
                shared as f64 / handles as f64
            };
            st.registry
                .set_gauge("arena_sharded_store_sharing_ratio", ratio);
            st.registry
                .set_gauge("arena_pool_workers", pool.workers as f64);
            st.registry
                .set_gauge("arena_pool_occupancy", pool.occupancy());
            st.registry.observe(
                "arena_pool_window_wall_ns",
                pool.window_wall_ns as f64,
            );
            for &busy in &pool.worker_busy_ns {
                st.registry
                    .observe("arena_pool_worker_busy_ns", busy as f64);
            }
            for p in shards {
                st.trace.push(Span {
                    track: trace::shard_track(p.shard),
                    name: format!("w{} {}ev", row.window, p.events),
                    t0_sim: pool.t0_sim,
                    t1_sim: row.sim_time,
                    wall_ns: p.advance_wall_ns,
                });
            }
            for (wk, &busy) in pool.worker_busy_ns.iter().enumerate() {
                st.trace.push(Span {
                    track: trace::worker_track(wk),
                    name: format!("window {}", row.window),
                    t0_sim: pool.t0_sim,
                    t1_sim: row.sim_time,
                    wall_ns: busy,
                });
            }
        }
        if let Some(sink) = &self.sink {
            sink.push_frame(&shard_window_frame(row, shards, pool));
            let st = self.state.lock().unwrap();
            sink.set_metrics(st.registry.render_prometheus());
            sink.set_trace(st.trace.to_chrome_json());
        }
    }

    fn on_sim_batch(&mut self, items: usize, workers: usize, wall_ns: u64) {
        let mut st = self.state.lock().unwrap();
        st.registry.inc("arena_pool_sim_batches_total");
        st.registry
            .inc_by("arena_pool_sim_batch_items_total", items as u64);
        st.registry
            .set_gauge("arena_pool_workers", workers as f64);
        st.registry
            .observe("arena_pool_sim_batch_wall_ns", wall_ns as f64);
    }

    fn on_sharded_store(&mut self, stats: &ShardedStoreStats) {
        let mut st = self.state.lock().unwrap();
        st.registry.set_gauge(
            "arena_sharded_store_live_buffers",
            stats.live_buffers as f64,
        );
        st.registry.set_gauge(
            "arena_sharded_store_peak_bytes",
            stats.peak_model_bytes as f64,
        );
        st.registry.set_gauge(
            "arena_sharded_store_total_refs",
            stats.total_refs as f64,
        );
        st.registry.set_gauge(
            "arena_sharded_store_sharing_ratio",
            stats.sharing_ratio(),
        );
        st.registry.set_gauge(
            "arena_sharded_store_adopt_across",
            stats.adopt_across as f64,
        );
        st.registry.set_gauge(
            "arena_sharded_store_adopt_bytes",
            stats.adopt_bytes as f64,
        );
        st.registry.set_gauge(
            "arena_sharded_store_replicate",
            stats.replicate as f64,
        );
        st.registry.set_gauge(
            "arena_sharded_store_replicate_bytes",
            stats.replicate_bytes as f64,
        );
    }
}

/// One `/stream` NDJSON frame for a closed round: the round's JSON
/// (which carries `schema_version`) plus a frame `type` tag, the
/// per-edge link utilizations and per-edge staleness (in cloud
/// windows) — the dashboard's staleness bars read the latter.
pub fn round_frame(stats: &RoundStats) -> String {
    let mut j = stats.to_json();
    if let Json::Obj(m) = &mut j {
        m.insert("type".to_string(), Json::str("round"));
        let up: Vec<f64> = stats
            .per_edge
            .iter()
            .map(|e| e.link_util(stats.round_time).0)
            .collect();
        let down: Vec<f64> = stats
            .per_edge
            .iter()
            .map(|e| e.link_util(stats.round_time).1)
            .collect();
        let stale: Vec<f64> =
            stats.per_edge.iter().map(|e| e.staleness).collect();
        m.insert("link_util_up".to_string(), Json::arr_f64(&up));
        m.insert("link_util_down".to_string(), Json::arr_f64(&down));
        m.insert("staleness".to_string(), Json::arr_f64(&stale));
    }
    j.to_string()
}

/// One `/stream` NDJSON frame for a sharded-runtime window barrier:
/// merged-row scalars plus per-shard arrays in **fixed shard order**.
/// The `*_ns` arrays and `occupancy`/`workers` are wall-clock observer
/// records (execution detail); everything else is sim-derived and
/// worker-count invariant.
pub fn shard_window_frame(
    row: &WindowRow,
    shards: &[ShardWindowProfile],
    pool: &PoolWindowProfile,
) -> String {
    let events: Vec<f64> =
        shards.iter().map(|p| p.events as f64).collect();
    let depth: Vec<f64> =
        shards.iter().map(|p| p.queue_depth_peak as f64).collect();
    let live: Vec<f64> =
        shards.iter().map(|p| p.live_devices as f64).collect();
    let stall: Vec<f64> =
        shards.iter().map(|p| p.barrier_stall_ns as f64).collect();
    let wall: Vec<f64> =
        shards.iter().map(|p| p.advance_wall_ns as f64).collect();
    Json::obj(vec![
        ("type", Json::str("shard_window")),
        (
            "schema_version",
            Json::num(crate::hfl::metrics::SCHEMA_VERSION as f64),
        ),
        ("window", Json::num(row.window as f64)),
        ("sim_time", Json::num(row.sim_time)),
        ("events", Json::arr_f64(&events)),
        ("queue_depth_peak", Json::arr_f64(&depth)),
        ("live", Json::arr_f64(&live)),
        ("barrier_stall_ns", Json::arr_f64(&stall)),
        ("advance_wall_ns", Json::arr_f64(&wall)),
        ("imbalance", Json::num(shard_imbalance(shards))),
        ("occupancy", Json::num(pool.occupancy())),
        ("workers", Json::num(pool.workers as f64)),
        ("n_shards", Json::num(pool.n_shards as f64)),
    ])
    .to_string()
}

/// Process-wide registry for harness phase timings (`exp::harness`
/// records per-figure wall time here so it lands in the same exposition
/// as engine metrics).
pub fn harness_registry() -> &'static Mutex<Registry> {
    static HARNESS: OnceLock<Mutex<Registry>> = OnceLock::new();
    HARNESS.get_or_init(|| Mutex::new(Registry::new()))
}

/// Sanitize an arbitrary label into a Prometheus metric-name fragment.
pub fn metric_fragment(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> RoundStats {
        use crate::hfl::metrics::EdgeStats;
        RoundStats {
            k: 3,
            accuracy: 0.75,
            test_loss: 0.5,
            train_loss: 0.6,
            round_time: 100.0,
            sim_now: 300.0,
            per_edge: vec![EdgeStats {
                up_busy: 20.0,
                down_busy: 10.0,
                ..Default::default()
            }],
            energy: 12.0,
            gamma1: vec![2],
            gamma2: vec![1],
            device_losses: vec![],
            n_reclusters: 1,
            migrated_devices: 2,
            active_devices: 9,
            edge_size_imbalance: 0.1,
            live_model_buffers: 2,
            peak_model_bytes: 1024,
            sharing_ratio: 0.9,
            fault_events: 0,
        }
    }

    #[test]
    fn run_observer_accumulates_metrics_and_spans() {
        let mut o = RunObserver::new();
        o.on_event_handled("train_done", 10.0, 50, 1000);
        o.on_event_handled("train_done", 11.0, 60, 2000);
        o.on_transfer(0, "up", 4096.0, 5.0, 9.0);
        o.on_recluster(50.0, 3, 700);
        o.on_store(2, 1024, 0.9);
        o.on_round(&stats());
        let st = o.state();
        let st = st.lock().unwrap();
        assert_eq!(st.registry.counter("arena_events_total"), 2);
        assert_eq!(
            st.registry.counter("arena_events_train_done_total"),
            2
        );
        assert_eq!(st.registry.counter("arena_transfers_up_total"), 1);
        assert_eq!(st.registry.counter("arena_reclusters_total"), 1);
        assert_eq!(
            st.registry.counter("arena_migrated_devices_total"),
            3
        );
        assert_eq!(st.registry.gauge("arena_round_accuracy"), Some(0.75));
        assert_eq!(
            st.registry.gauge("arena_store_live_buffers"),
            Some(2.0)
        );
        let lag =
            st.registry.histogram("arena_event_dequeue_lag_ns").unwrap();
        assert_eq!(lag.count(), 2);
        // Spans: one transfer + one cloud window.
        assert_eq!(st.trace.len(), 2);
        assert_eq!(st.trace.tracks(), &["edge/0".to_string(), "cloud".into()]);
    }

    #[test]
    fn round_frame_is_tagged_and_versioned() {
        let f = round_frame(&stats());
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("type").unwrap().as_str().unwrap(), "round");
        assert_eq!(
            j.get("schema_version").unwrap().as_usize().unwrap(),
            crate::hfl::metrics::SCHEMA_VERSION
        );
        assert_eq!(j.get("k").unwrap().as_usize().unwrap(), 3);
        let up = j.get("link_util_up").unwrap().as_arr().unwrap();
        assert_eq!(up[0].as_f64().unwrap(), 0.2);
        assert!(!f.contains('\n'), "frames must be single-line NDJSON");
    }

    fn profile(shard: usize, events: u64) -> ShardWindowProfile {
        ShardWindowProfile {
            shard,
            events,
            live_devices: 10,
            queue_depth_peak: 4 + shard,
            store_live_buffers: 3,
            store_peak_bytes: 256,
            store_shared_handles: 2,
            store_handles: 4,
            advance_wall_ns: 1000,
            done_at_ns: 2000,
            barrier_stall_ns: 500,
            ..Default::default()
        }
    }

    fn pool_profile() -> PoolWindowProfile {
        PoolWindowProfile {
            window: 1,
            t0_sim: 60.0,
            t1_sim: 120.0,
            workers: 2,
            n_shards: 2,
            window_wall_ns: 4000,
            worker_busy_ns: vec![1000, 1000],
        }
    }

    fn row() -> WindowRow {
        WindowRow {
            window: 1,
            sim_time: 120.0,
            ..Default::default()
        }
    }

    #[test]
    fn shard_barrier_folds_profiles_in_fixed_order() {
        let mut o = RunObserver::new();
        let shards = vec![profile(0, 6), profile(1, 2)];
        o.on_shard_barrier(&row(), &shards, &pool_profile());
        let st = o.state();
        let st = st.lock().unwrap();
        assert_eq!(st.registry.counter("arena_shard_windows_total"), 1);
        assert_eq!(st.registry.counter("arena_shard_events_total"), 8);
        assert_eq!(st.registry.gauge("arena_shard_count"), Some(2.0));
        assert_eq!(
            st.registry.gauge("arena_shard_queue_depth_peak"),
            Some(5.0)
        );
        // max=6, mean=4 -> 1.5
        assert_eq!(st.registry.gauge("arena_shard_imbalance"), Some(1.5));
        assert_eq!(
            st.registry.gauge("arena_sharded_store_sharing_ratio"),
            Some(0.5)
        );
        assert_eq!(st.registry.gauge("arena_pool_workers"), Some(2.0));
        let h =
            st.registry.histogram("arena_shard_barrier_stall_ns").unwrap();
        assert_eq!(h.count(), 2);
        // One span per shard, then one per worker, fixed order.
        assert_eq!(
            st.trace.tracks(),
            &[
                "shard/0".to_string(),
                "shard/1".into(),
                "worker/0".into(),
                "worker/1".into()
            ]
        );
    }

    #[test]
    fn fault_counters_fold_at_barriers_and_per_event() {
        let mut o = RunObserver::new();
        let shards = vec![
            ShardWindowProfile {
                outages: 1,
                partitions: 2,
                crashes: 5,
                ..profile(0, 6)
            },
            profile(1, 2),
        ];
        o.on_shard_barrier(&row(), &shards, &pool_profile());
        o.on_fault("outage");
        o.on_fault("recovery");
        let st = o.state();
        let st = st.lock().unwrap();
        assert_eq!(st.registry.counter("arena_fault_outage_total"), 2);
        assert_eq!(st.registry.counter("arena_fault_partition_total"), 2);
        assert_eq!(st.registry.counter("arena_fault_crash_total"), 5);
        assert_eq!(st.registry.counter("arena_fault_recovery_total"), 1);
        assert_eq!(st.registry.counter("arena_fault_events_total"), 10);
        // The series render (at zero too) as soon as a barrier closes —
        // the telemetry-smoke grep in CI relies on this.
        let text = st.registry.render_prometheus();
        assert!(text.contains("arena_fault_outage_total"));
        assert!(text.contains("arena_fault_events_total"));
    }

    #[test]
    fn shard_window_frame_is_single_line_and_typed() {
        let shards = vec![profile(0, 6), profile(1, 2)];
        let f = shard_window_frame(&row(), &shards, &pool_profile());
        assert!(!f.contains('\n'), "frames must be single-line NDJSON");
        let j = Json::parse(&f).unwrap();
        assert_eq!(
            j.get("type").unwrap().as_str().unwrap(),
            "shard_window"
        );
        assert_eq!(
            j.get("schema_version").unwrap().as_usize().unwrap(),
            crate::hfl::metrics::SCHEMA_VERSION
        );
        let ev = j.get("events").unwrap().as_arr().unwrap();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].as_f64().unwrap(), 6.0);
        assert_eq!(
            j.get("imbalance").unwrap().as_f64().unwrap(),
            1.5
        );
    }

    #[test]
    fn round_frame_carries_per_edge_staleness() {
        let f = round_frame(&stats());
        let j = Json::parse(&f).unwrap();
        let s = j.get("staleness").unwrap().as_arr().unwrap();
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn sharded_store_snapshot_lands_as_gauges() {
        let mut o = RunObserver::new();
        let stats = ShardedStoreStats {
            live_buffers: 4,
            total_refs: 8,
            peak_model_bytes: 2048,
            adopt_across: 3,
            adopt_bytes: 192,
            replicate: 6,
            replicate_bytes: 384,
            ..Default::default()
        };
        o.on_sharded_store(&stats);
        let st = o.state();
        let st = st.lock().unwrap();
        assert_eq!(
            st.registry.gauge("arena_sharded_store_total_refs"),
            Some(8.0)
        );
        assert_eq!(
            st.registry.gauge("arena_sharded_store_sharing_ratio"),
            Some(0.5)
        );
        assert_eq!(
            st.registry.gauge("arena_sharded_store_adopt_bytes"),
            Some(192.0)
        );
    }

    #[test]
    fn metric_fragment_sanitizes() {
        assert_eq!(metric_fragment("fig_async-headtohead"),
                   "fig_async_headtohead");
        assert_eq!(metric_fragment("table1"), "table1");
    }
}
