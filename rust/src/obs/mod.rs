//! # Observability: deterministic tracing, metrics, live telemetry
//!
//! Three std-only layers on top of `util/`:
//!
//! 1. **Core** ([`metrics`], [`trace`]) — a [`Registry`] of counters,
//!    gauges and log₂-bucketed [`Histogram`]s, plus a [`TraceBuffer`] of
//!    [`Span`]s stamped with *both* simulated time and host wall-clock.
//! 2. **Exporters** — Prometheus text exposition
//!    ([`Registry::render_prometheus`]) and Chrome-trace JSON
//!    ([`TraceBuffer::to_chrome_json`], loadable at `chrome://tracing`).
//! 3. **Server** ([`server`]) — a `TcpListener` thread serving scrapes
//!    and an NDJSON round stream while a run is in progress.
//!
//! ## Observer contract (the no-feedback rule)
//!
//! Engines expose an optional [`Observer`]; every hook has a no-op
//! default, and an engine with no observer attached pays nothing. The
//! contract that makes this observability rather than logging:
//!
//! - **Hooks read, never mutate.** An observer receives borrowed or
//!   copied facts about the run and has no channel back into engine
//!   state, RNG streams, or the event queue.
//! - **Wall-clock never feeds back.** `Instant` reads happen only when
//!   an observer is attached and flow only into observer records; no
//!   simulated timestamp, seed, or decision ever derives from them.
//!
//! Together these extend the engine's determinism guarantee family
//! (sync-equivalence, zero-churn no-op, re-arm no-op) with a fourth:
//! **observer-on == observer-off, bitwise** — asserted by the
//! `observer_attach_is_bitwise_noop` integration test.
//!
//! ## Endpoints (`arena run --serve <addr>`)
//!
//! ```text
//! curl http://127.0.0.1:9898/healthz   # -> ok
//! curl http://127.0.0.1:9898/metrics   # Prometheus text exposition
//! curl -sN http://127.0.0.1:9898/stream | head -n1   # one NDJSON frame
//! ```
//!
//! `/stream` frames are one JSON object per line with a
//! `"schema_version"` field (see `hfl::metrics::SCHEMA_VERSION`); new
//! subscribers receive the most recent frame first, then live frames as
//! cloud rounds close. `--trace-out <path>` additionally writes the
//! Chrome-trace timeline at the end of the run.

pub mod metrics;
pub mod server;
pub mod trace;

pub use metrics::{Histogram, Registry};
pub use server::{TelemetryServer, TelemetrySink};
pub use trace::{Span, TraceBuffer};

use std::sync::{Arc, Mutex, OnceLock};

use crate::hfl::metrics::RoundStats;
use crate::util::json::Json;

/// Read-only run instrumentation. Every hook defaults to a no-op so the
/// trait doubles as its own null object; engines call hooks only when an
/// observer is attached and skip all wall-clock reads otherwise.
pub trait Observer: Send {
    /// One event was popped and handled: its variant name, the simulated
    /// time it fired at, the wall-ns between dequeue and handler entry,
    /// and the handler's wall-ns cost.
    fn on_event_handled(
        &mut self,
        _variant: &'static str,
        _sim_time: f64,
        _dequeue_lag_ns: u64,
        _handler_ns: u64,
    ) {
    }

    /// A closed interval on the sim timeline (training burst, transfer,
    /// cloud window, harness phase).
    fn on_span(&mut self, _span: Span) {}

    /// A transfer completed its lifetime `[start, finish]` (sim
    /// seconds) on `edge`'s `dir` link.
    fn on_transfer(
        &mut self,
        _edge: usize,
        _dir: &'static str,
        _bytes: f64,
        _start: f64,
        _finish: f64,
    ) {
    }

    /// A cloud round / window closed.
    fn on_round(&mut self, _stats: &RoundStats) {}

    /// A re-clustering executed at sim time `at`, migrating `migrated`
    /// devices at a host cost of `wall_ns`.
    fn on_recluster(&mut self, _at: f64, _migrated: usize, _wall_ns: u64) {}

    /// Model-store occupancy snapshot at a round boundary.
    fn on_store(
        &mut self,
        _live_buffers: usize,
        _peak_bytes: usize,
        _sharing_ratio: f64,
    ) {
    }
}

/// The do-nothing observer (useful as an overhead baseline in benches).
#[derive(Default, Clone, Copy)]
pub struct NoopObserver;

impl Observer for NoopObserver {}

/// Everything a [`RunObserver`] accumulates, shared behind
/// `Arc<Mutex<_>>` so the CLI keeps a reader handle while the engine
/// owns the observer box.
#[derive(Default)]
pub struct ObsState {
    pub registry: Registry,
    pub trace: TraceBuffer,
}

/// The standard observer: folds hooks into a metrics [`Registry`] and a
/// [`TraceBuffer`], and (optionally) publishes round frames + fresh
/// exposition text to a [`TelemetrySink`].
pub struct RunObserver {
    state: Arc<Mutex<ObsState>>,
    sink: Option<TelemetrySink>,
}

impl Default for RunObserver {
    fn default() -> Self {
        RunObserver::new()
    }
}

impl RunObserver {
    pub fn new() -> Self {
        RunObserver {
            state: Arc::new(Mutex::new(ObsState::default())),
            sink: None,
        }
    }

    pub fn with_sink(sink: TelemetrySink) -> Self {
        RunObserver {
            state: Arc::new(Mutex::new(ObsState::default())),
            sink: Some(sink),
        }
    }

    /// Reader handle onto the accumulated registry + trace.
    pub fn state(&self) -> Arc<Mutex<ObsState>> {
        self.state.clone()
    }
}

impl Observer for RunObserver {
    fn on_event_handled(
        &mut self,
        variant: &'static str,
        _sim_time: f64,
        dequeue_lag_ns: u64,
        handler_ns: u64,
    ) {
        let mut st = self.state.lock().unwrap();
        st.registry.inc("arena_events_total");
        st.registry
            .inc(&format!("arena_events_{variant}_total"));
        st.registry
            .observe("arena_event_dequeue_lag_ns", dequeue_lag_ns as f64);
        st.registry.observe(
            &format!("arena_handler_wall_ns_{variant}"),
            handler_ns as f64,
        );
    }

    fn on_span(&mut self, span: Span) {
        self.state.lock().unwrap().trace.push(span);
    }

    fn on_transfer(
        &mut self,
        edge: usize,
        dir: &'static str,
        _bytes: f64,
        start: f64,
        finish: f64,
    ) {
        let mut st = self.state.lock().unwrap();
        st.registry.inc("arena_transfers_total");
        st.registry.inc(&format!("arena_transfers_{dir}_total"));
        st.registry.observe(
            "arena_transfer_lifetime_seconds",
            (finish - start).max(0.0),
        );
        st.trace.push(Span {
            track: format!("edge/{edge}"),
            name: format!("xfer {dir}"),
            t0_sim: start,
            t1_sim: finish,
            wall_ns: 0,
        });
    }

    fn on_round(&mut self, stats: &RoundStats) {
        {
            let mut st = self.state.lock().unwrap();
            st.registry.inc("arena_rounds_total");
            st.registry.set_gauge("arena_round_k", stats.k as f64);
            st.registry
                .set_gauge("arena_round_accuracy", stats.accuracy);
            st.registry
                .set_gauge("arena_round_train_loss", stats.train_loss);
            st.registry
                .set_gauge("arena_sim_time_seconds", stats.sim_now);
            st.registry
                .set_gauge("arena_round_energy_mah", stats.energy);
            st.registry.set_gauge(
                "arena_active_devices",
                stats.active_devices as f64,
            );
            st.registry.set_gauge(
                "arena_mean_staleness",
                stats.mean_staleness(),
            );
            st.registry.set_gauge(
                "arena_mean_link_util",
                stats.mean_link_util(),
            );
            st.registry.observe(
                "arena_round_time_seconds",
                stats.round_time,
            );
            st.trace.push(Span {
                track: "cloud".to_string(),
                name: format!("window {}", stats.k),
                t0_sim: stats.sim_now - stats.round_time,
                t1_sim: stats.sim_now,
                wall_ns: 0,
            });
        }
        if let Some(sink) = &self.sink {
            sink.push_frame(&round_frame(stats));
            let st = self.state.lock().unwrap();
            sink.set_metrics(st.registry.render_prometheus());
        }
    }

    fn on_recluster(&mut self, _at: f64, migrated: usize, wall_ns: u64) {
        let mut st = self.state.lock().unwrap();
        st.registry.inc("arena_reclusters_total");
        st.registry
            .inc_by("arena_migrated_devices_total", migrated as u64);
        st.registry
            .observe("arena_recluster_wall_ns", wall_ns as f64);
    }

    fn on_store(
        &mut self,
        live_buffers: usize,
        peak_bytes: usize,
        sharing_ratio: f64,
    ) {
        let mut st = self.state.lock().unwrap();
        st.registry
            .set_gauge("arena_store_live_buffers", live_buffers as f64);
        st.registry
            .set_gauge("arena_store_peak_bytes", peak_bytes as f64);
        st.registry
            .set_gauge("arena_store_sharing_ratio", sharing_ratio);
    }
}

/// One `/stream` NDJSON frame for a closed round: the round's JSON
/// (which carries `schema_version`) plus a frame `type` tag and the
/// per-edge link utilizations.
pub fn round_frame(stats: &RoundStats) -> String {
    let mut j = stats.to_json();
    if let Json::Obj(m) = &mut j {
        m.insert("type".to_string(), Json::str("round"));
        let up: Vec<f64> = stats
            .per_edge
            .iter()
            .map(|e| e.link_util(stats.round_time).0)
            .collect();
        let down: Vec<f64> = stats
            .per_edge
            .iter()
            .map(|e| e.link_util(stats.round_time).1)
            .collect();
        m.insert("link_util_up".to_string(), Json::arr_f64(&up));
        m.insert("link_util_down".to_string(), Json::arr_f64(&down));
    }
    j.to_string()
}

/// Process-wide registry for harness phase timings (`exp::harness`
/// records per-figure wall time here so it lands in the same exposition
/// as engine metrics).
pub fn harness_registry() -> &'static Mutex<Registry> {
    static HARNESS: OnceLock<Mutex<Registry>> = OnceLock::new();
    HARNESS.get_or_init(|| Mutex::new(Registry::new()))
}

/// Sanitize an arbitrary label into a Prometheus metric-name fragment.
pub fn metric_fragment(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> RoundStats {
        use crate::hfl::metrics::EdgeStats;
        RoundStats {
            k: 3,
            accuracy: 0.75,
            test_loss: 0.5,
            train_loss: 0.6,
            round_time: 100.0,
            sim_now: 300.0,
            per_edge: vec![EdgeStats {
                up_busy: 20.0,
                down_busy: 10.0,
                ..Default::default()
            }],
            energy: 12.0,
            gamma1: vec![2],
            gamma2: vec![1],
            device_losses: vec![],
            n_reclusters: 1,
            migrated_devices: 2,
            active_devices: 9,
            edge_size_imbalance: 0.1,
            live_model_buffers: 2,
            peak_model_bytes: 1024,
            sharing_ratio: 0.9,
        }
    }

    #[test]
    fn run_observer_accumulates_metrics_and_spans() {
        let mut o = RunObserver::new();
        o.on_event_handled("train_done", 10.0, 50, 1000);
        o.on_event_handled("train_done", 11.0, 60, 2000);
        o.on_transfer(0, "up", 4096.0, 5.0, 9.0);
        o.on_recluster(50.0, 3, 700);
        o.on_store(2, 1024, 0.9);
        o.on_round(&stats());
        let st = o.state();
        let st = st.lock().unwrap();
        assert_eq!(st.registry.counter("arena_events_total"), 2);
        assert_eq!(
            st.registry.counter("arena_events_train_done_total"),
            2
        );
        assert_eq!(st.registry.counter("arena_transfers_up_total"), 1);
        assert_eq!(st.registry.counter("arena_reclusters_total"), 1);
        assert_eq!(
            st.registry.counter("arena_migrated_devices_total"),
            3
        );
        assert_eq!(st.registry.gauge("arena_round_accuracy"), Some(0.75));
        assert_eq!(
            st.registry.gauge("arena_store_live_buffers"),
            Some(2.0)
        );
        let lag =
            st.registry.histogram("arena_event_dequeue_lag_ns").unwrap();
        assert_eq!(lag.count(), 2);
        // Spans: one transfer + one cloud window.
        assert_eq!(st.trace.len(), 2);
        assert_eq!(st.trace.tracks(), &["edge/0".to_string(), "cloud".into()]);
    }

    #[test]
    fn round_frame_is_tagged_and_versioned() {
        let f = round_frame(&stats());
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("type").unwrap().as_str().unwrap(), "round");
        assert_eq!(
            j.get("schema_version").unwrap().as_usize().unwrap(),
            crate::hfl::metrics::SCHEMA_VERSION
        );
        assert_eq!(j.get("k").unwrap().as_usize().unwrap(), 3);
        let up = j.get("link_util_up").unwrap().as_arr().unwrap();
        assert_eq!(up[0].as_f64().unwrap(), 0.2);
        assert!(!f.contains('\n'), "frames must be single-line NDJSON");
    }

    #[test]
    fn metric_fragment_sanitizes() {
        assert_eq!(metric_fragment("fig_async-headtohead"),
                   "fig_async_headtohead");
        assert_eq!(metric_fragment("table1"), "table1");
    }
}
