//! Per-shard profiler for the parallel runtime (`sim::shard` +
//! `util::threadpool::ShardPool`).
//!
//! Each shard owns a [`ShardProfiler`] — a plain struct of counters with
//! no locks, atomics or channels on the hot path. During a window the
//! shard samples into it (queue-depth high-water, cross-shard store
//! traffic); at the barrier the worker drains it into a
//! [`ShardWindowProfile`] that rides home with the shard's
//! `WindowReport`. The coordinator then:
//!
//! 1. computes per-shard **barrier stall** (`max(done_at) - done_at`,
//!    i.e. how long each shard's worker sat waiting for the straggler),
//! 2. attributes per-worker busy time via `ShardPool::shard_worker` into
//!    a [`PoolWindowProfile`],
//! 3. hands both to `Observer::on_shard_barrier` **in fixed shard
//!    order**, whatever order worker threads finished in.
//!
//! Determinism contract (the fifth bitwise-guarantee family member,
//! profiler-on == profiler-off): the sim-derived fields (event counts,
//! queue depths, store occupancy, traffic counters) are pure functions
//! of the seeded trajectory and therefore identical at any worker count
//! and queue backend; the wall-clock fields (`advance_wall_ns`,
//! `done_at_ns`, `barrier_stall_ns`) are read only when an observer is
//! attached and flow only into observer records — never into simulated
//! state, metric *names*, or any value a test byte-compares.

/// One shard's profile of one conservative time window. Everything
/// except the three `*_ns` fields is sim-derived and bit-identical at
/// any worker count.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardWindowProfile {
    /// Shard index (fixed by topology).
    pub shard: usize,
    /// Events handled this window.
    pub events: u64,
    /// Straggler results voided by departures this window.
    pub voided: u64,
    /// Edge aggregations this window.
    pub aggregates: u64,
    /// Mobility flips this window.
    pub flips: u64,
    /// Live devices at the barrier.
    pub live_devices: usize,
    /// Queue-depth high-water mark observed during the window.
    pub queue_depth_peak: usize,
    /// Events still queued at the barrier (future-window events).
    pub queue_len_end: usize,
    /// Live buffers in the shard's model-store slab at the barrier.
    pub store_live_buffers: usize,
    /// High-water bytes of the shard's slab (pooled scratch included).
    pub store_peak_bytes: usize,
    /// Device handles whose buffer is shared (rc > 1) at the barrier.
    pub store_shared_handles: usize,
    /// Total device handles in the shard.
    pub store_handles: usize,
    /// Cross-shard handle adoptions charged to this shard this window.
    pub adopt_across: u64,
    /// Bytes copied by those adoptions.
    pub adopt_bytes: u64,
    /// Barrier replications charged to this shard this window.
    pub replicate: u64,
    /// Bytes copied by those replications.
    pub replicate_bytes: u64,
    /// Injected edge-outage events (down only) handled this window.
    pub outages: u64,
    /// Owned edges severed by injected partitions this window.
    pub partitions: u64,
    /// Devices crashed by injected storms this window.
    pub crashes: u64,
    /// Wall time of this shard's `advance` call (observer-only).
    pub advance_wall_ns: u64,
    /// Wall time from window start to this shard's arrival at the
    /// barrier (observer-only).
    pub done_at_ns: u64,
    /// `max(done_at_ns) - done_at_ns` over the window's shards: how
    /// long this shard's result waited for the straggler
    /// (observer-only; filled by the coordinator).
    pub barrier_stall_ns: u64,
}

impl ShardWindowProfile {
    /// Fraction of device handles sharing a buffer at the barrier.
    pub fn sharing_ratio(&self) -> f64 {
        if self.store_handles == 0 {
            0.0
        } else {
            self.store_shared_handles as f64 / self.store_handles as f64
        }
    }
}

/// The pool-side view of one window: worker occupancy and wall extent.
/// All fields except `window`, `t0_sim`, `t1_sim`, `workers` and
/// `n_shards` are wall-clock (observer-only).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PoolWindowProfile {
    pub window: usize,
    /// Simulated window extent (for trace spans).
    pub t0_sim: f64,
    pub t1_sim: f64,
    pub workers: usize,
    pub n_shards: usize,
    /// Wall time from window start to the last shard's arrival.
    pub window_wall_ns: u64,
    /// Per-worker busy wall-ns this window (sum of owned shards'
    /// `advance_wall_ns`), indexed by worker.
    pub worker_busy_ns: Vec<u64>,
}

impl PoolWindowProfile {
    /// Mean fraction of the window's wall time the workers spent
    /// advancing shards (1.0 = perfectly balanced, no barrier idle).
    pub fn occupancy(&self) -> f64 {
        if self.workers == 0 || self.window_wall_ns == 0 {
            return 0.0;
        }
        let busy: u64 = self.worker_busy_ns.iter().sum();
        busy as f64 / (self.workers as f64 * self.window_wall_ns as f64)
    }
}

/// Shard-owned hot-path accumulator. Disabled (the default) every
/// sampling call is a single predictable branch; enabled it is plain
/// integer arithmetic on shard-private memory — no locks anywhere.
#[derive(Clone, Debug, Default)]
pub struct ShardProfiler {
    enabled: bool,
    queue_depth_peak: usize,
    adopt_across: u64,
    adopt_bytes: u64,
    replicate: u64,
    replicate_bytes: u64,
}

impl ShardProfiler {
    pub fn new() -> Self {
        ShardProfiler::default()
    }

    /// Toggle sampling for the coming window (set by the worker closure
    /// at window start — shards live inside worker threads).
    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record the queue length after an event was handled.
    #[inline]
    pub fn sample_queue_depth(&mut self, len: usize) {
        if self.enabled && len > self.queue_depth_peak {
            self.queue_depth_peak = len;
        }
    }

    /// Record one cross-shard adoption of `bytes` payload bytes.
    #[inline]
    pub fn count_adopt(&mut self, bytes: usize) {
        if self.enabled {
            self.adopt_across += 1;
            self.adopt_bytes += bytes as u64;
        }
    }

    /// Record one barrier replication of `bytes` payload bytes.
    #[inline]
    pub fn count_replicate(&mut self, bytes: usize) {
        if self.enabled {
            self.replicate += 1;
            self.replicate_bytes += bytes as u64;
        }
    }

    /// Drain the window's accumulators into `p` and reset for the next
    /// window.
    pub fn drain_into(&mut self, p: &mut ShardWindowProfile) {
        p.queue_depth_peak = self.queue_depth_peak;
        p.adopt_across = self.adopt_across;
        p.adopt_bytes = self.adopt_bytes;
        p.replicate = self.replicate;
        p.replicate_bytes = self.replicate_bytes;
        self.queue_depth_peak = 0;
        self.adopt_across = 0;
        self.adopt_bytes = 0;
        self.replicate = 0;
        self.replicate_bytes = 0;
    }
}

/// Deterministic shard-imbalance for one window: `max / mean` of
/// per-shard event counts (1.0 = perfectly even; 0 shards or an idle
/// window report 1.0). Sim-derived, so identical at any worker count.
pub fn shard_imbalance(shards: &[ShardWindowProfile]) -> f64 {
    if shards.is_empty() {
        return 1.0;
    }
    let total: u64 = shards.iter().map(|p| p.events).sum();
    if total == 0 {
        return 1.0;
    }
    let max = shards.iter().map(|p| p.events).max().unwrap_or(0);
    max as f64 * shards.len() as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_profiler_samples_nothing() {
        let mut pr = ShardProfiler::new();
        pr.sample_queue_depth(100);
        pr.count_adopt(64);
        pr.count_replicate(64);
        let mut p = ShardWindowProfile::default();
        pr.drain_into(&mut p);
        assert_eq!(p.queue_depth_peak, 0);
        assert_eq!(p.adopt_across, 0);
        assert_eq!(p.replicate, 0);
    }

    #[test]
    fn drain_resets_for_the_next_window() {
        let mut pr = ShardProfiler::new();
        pr.set_enabled(true);
        pr.sample_queue_depth(7);
        pr.sample_queue_depth(3);
        pr.count_adopt(16);
        pr.count_adopt(16);
        pr.count_replicate(8);
        let mut p = ShardWindowProfile::default();
        pr.drain_into(&mut p);
        assert_eq!(p.queue_depth_peak, 7);
        assert_eq!(p.adopt_across, 2);
        assert_eq!(p.adopt_bytes, 32);
        assert_eq!(p.replicate, 1);
        assert_eq!(p.replicate_bytes, 8);
        let mut p2 = ShardWindowProfile::default();
        pr.drain_into(&mut p2);
        assert_eq!(p2.queue_depth_peak, 0);
        assert_eq!(p2.adopt_across, 0);
    }

    #[test]
    fn sharing_ratio_and_imbalance() {
        let p = ShardWindowProfile {
            store_shared_handles: 3,
            store_handles: 4,
            ..Default::default()
        };
        assert!((p.sharing_ratio() - 0.75).abs() < 1e-12);
        assert_eq!(ShardWindowProfile::default().sharing_ratio(), 0.0);

        let mk = |events| ShardWindowProfile {
            events,
            ..Default::default()
        };
        assert_eq!(shard_imbalance(&[]), 1.0);
        assert_eq!(shard_imbalance(&[mk(0), mk(0)]), 1.0);
        assert_eq!(shard_imbalance(&[mk(5), mk(5)]), 1.0);
        // max=6, mean=4 -> 1.5
        let got = shard_imbalance(&[mk(6), mk(2)]);
        assert!((got - 1.5).abs() < 1e-12);
    }

    #[test]
    fn occupancy_is_busy_over_workers_times_wall() {
        let p = PoolWindowProfile {
            window: 0,
            t0_sim: 0.0,
            t1_sim: 60.0,
            workers: 2,
            n_shards: 4,
            window_wall_ns: 1000,
            worker_busy_ns: vec![1000, 500],
        };
        assert!((p.occupancy() - 0.75).abs() < 1e-12);
        assert_eq!(PoolWindowProfile::default().occupancy(), 0.0);
    }
}
