//! Live telemetry server: a std-only HTTP endpoint over `TcpListener`.
//!
//! Architecture follows the worker/channel executor shape (SNIPPETS.md):
//! producer threads push strings over an `mpsc` channel, a pump thread
//! drains it, and shared state sits behind `Arc<Mutex<_>>`. Here the
//! producers are the engines (via [`TelemetrySink`]), the pump fans NDJSON
//! frames out to every connected `/stream` subscriber, and an accept
//! thread answers `/healthz` and `/metrics` scrapes.
//!
//! Endpoints:
//! - `GET /` — the live dashboard: one self-contained embedded HTML+JS
//!   page (no external assets) that subscribes to `/stream`, polls
//!   `/metrics`, and renders round progress, per-edge staleness, shard
//!   imbalance and barrier-stall sparklines.
//! - `GET /healthz` — `200 ok` liveness probe.
//! - `GET /metrics` — Prometheus text exposition (whatever the sink last
//!   published via [`TelemetrySink::set_metrics`]).
//! - `GET /stream` — NDJSON frames, one JSON object per line, pushed as
//!   cloud rounds close (and, on the sharded runtime, as window barriers
//!   close). New subscribers first receive the most recent frame (if
//!   any) so a late scrape still sees data.
//! - `GET /trace` — the current Chrome-trace JSON (whatever the sink
//!   last published via [`TelemetrySink::set_trace`]; an empty-but-valid
//!   `{"traceEvents":[]}` before the first publish).
//!
//! The server never touches the simulation: it only reads what the
//! observer published. Frames with no subscriber are dropped, not
//! buffered — telemetry must not grow unbounded state.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// The dashboard page served at `GET /` — a single self-contained
/// HTML+JS file embedded at compile time (no external assets, no deps).
pub const DASHBOARD_HTML: &str = include_str!("dashboard.html");

/// Producer-side handle: cheap to clone, safe to hold inside an observer.
/// All operations are fire-and-forget — a dead or absent server never
/// blocks or fails the simulation.
#[derive(Clone)]
pub struct TelemetrySink {
    frames: Sender<String>,
    metrics: Arc<Mutex<String>>,
    trace: Arc<Mutex<String>>,
}

impl TelemetrySink {
    /// Publish one NDJSON frame (without trailing newline).
    pub fn push_frame(&self, line: &str) {
        let _ = self.frames.send(line.to_string());
    }

    /// Replace the text served at `/metrics`.
    pub fn set_metrics(&self, text: String) {
        if let Ok(mut m) = self.metrics.lock() {
            *m = text;
        }
    }

    /// Replace the Chrome-trace JSON served at `/trace`.
    pub fn set_trace(&self, text: String) {
        if let Ok(mut t) = self.trace.lock() {
            *t = text;
        }
    }
}

pub struct TelemetryServer {
    addr: SocketAddr,
    metrics: Arc<Mutex<String>>,
    trace: Arc<Mutex<String>>,
    frames_tx: Sender<String>,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    pump_handle: Option<JoinHandle<()>>,
}

impl TelemetryServer {
    /// Bind `addr` (e.g. `127.0.0.1:9898`; port 0 picks a free port) and
    /// start the accept + pump threads.
    pub fn bind(addr: &str) -> std::io::Result<TelemetryServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let metrics = Arc::new(Mutex::new(String::new()));
        let trace = Arc::new(Mutex::new(String::new()));
        let subscribers: Arc<Mutex<Vec<TcpStream>>> =
            Arc::new(Mutex::new(Vec::new()));
        let last_frame = Arc::new(Mutex::new(String::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = channel::<String>();

        let accept_handle = {
            let metrics = metrics.clone();
            let trace = trace.clone();
            let subscribers = subscribers.clone();
            let last_frame = last_frame.clone();
            let stop = stop.clone();
            // Sanctioned spawn: the accept loop blocks on the socket, so
            // it cannot ride the simulation thread pools.
            #[allow(clippy::disallowed_methods)]
            thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => handle_conn(
                            stream,
                            &metrics,
                            &trace,
                            &subscribers,
                            &last_frame,
                        ),
                        Err(_) => {
                            thread::sleep(Duration::from_millis(20));
                        }
                    }
                }
            })
        };

        let pump_handle = {
            let subscribers = subscribers.clone();
            let stop = stop.clone();
            // Sanctioned spawn: ditto — the pump blocks on the channel.
            #[allow(clippy::disallowed_methods)]
            thread::spawn(move || loop {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(line) => {
                        if let Ok(mut lf) = last_frame.lock() {
                            *lf = line.clone();
                        }
                        if let Ok(mut subs) = subscribers.lock() {
                            subs.retain_mut(|s| {
                                write_frame(s, &line).is_ok()
                            });
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => return,
                }
            })
        };

        Ok(TelemetryServer {
            addr: local,
            metrics,
            trace,
            frames_tx: tx,
            stop,
            accept_handle: Some(accept_handle),
            pump_handle: Some(pump_handle),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A producer handle for observers / the CLI.
    pub fn sink(&self) -> TelemetrySink {
        TelemetrySink {
            frames: self.frames_tx.clone(),
            metrics: self.metrics.clone(),
            trace: self.trace.clone(),
        }
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.pump_handle.take() {
            let _ = h.join();
        }
    }

    /// Stop the threads and release the port.
    pub fn stop(mut self) {
        self.shutdown();
    }
}

impl Drop for TelemetryServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn write_frame(s: &mut TcpStream, line: &str) -> std::io::Result<()> {
    s.write_all(line.as_bytes())?;
    s.write_all(b"\n")?;
    s.flush()
}

fn respond(
    mut s: TcpStream,
    status: &str,
    ctype: &str,
    body: &str,
) {
    let _ = write!(
        s,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = s.flush();
}

/// Read the request line + headers (bounded) and route the path.
fn handle_conn(
    stream: TcpStream,
    metrics: &Arc<Mutex<String>>,
    trace: &Arc<Mutex<String>>,
    subscribers: &Arc<Mutex<Vec<TcpStream>>>,
    last_frame: &Arc<Mutex<String>>,
) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut request = String::new();
    if reader.read_line(&mut request).is_err() {
        return;
    }
    let path = request.split_whitespace().nth(1).unwrap_or("/");
    // Drain headers so the peer isn't mid-write when we respond.
    for _ in 0..64 {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) if line == "\r\n" || line == "\n" => break,
            Ok(_) => {}
            Err(_) => break,
        }
    }
    match path {
        "/" | "/index.html" => respond(
            stream,
            "200 OK",
            "text/html; charset=utf-8",
            DASHBOARD_HTML,
        ),
        "/healthz" => respond(stream, "200 OK", "text/plain", "ok\n"),
        "/trace" => {
            let body = trace
                .lock()
                .map(|t| t.clone())
                .unwrap_or_default();
            let body = if body.is_empty() {
                "{\"traceEvents\":[]}".to_string()
            } else {
                body
            };
            respond(stream, "200 OK", "application/json", &body);
        }
        "/metrics" => {
            let body = metrics
                .lock()
                .map(|m| m.clone())
                .unwrap_or_default();
            respond(
                stream,
                "200 OK",
                "text/plain; version=0.0.4",
                &body,
            );
        }
        "/stream" => {
            let mut stream = stream;
            let header = "HTTP/1.1 200 OK\r\n\
                          Content-Type: application/x-ndjson\r\n\
                          Connection: close\r\n\r\n";
            if stream.write_all(header.as_bytes()).is_err() {
                return;
            }
            // Replay the latest frame so late subscribers see data.
            if let Ok(lf) = last_frame.lock() {
                if !lf.is_empty()
                    && write_frame(&mut stream, &lf).is_err()
                {
                    return;
                }
            }
            if let Ok(mut subs) = subscribers.lock() {
                subs.push(stream);
            }
        }
        _ => respond(stream, "404 Not Found", "text/plain", "not found\n"),
    }
}

/// Blocking helper for tests and smoke probes: one HTTP GET against the
/// server, returning the raw response (headers + body). `max_bytes`
/// bounds the read so `/stream` probes return after one frame-sized
/// chunk instead of blocking forever.
pub fn http_get(
    addr: &SocketAddr,
    path: &str,
    max_bytes: usize,
) -> std::io::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(5)))?;
    write!(s, "GET {path} HTTP/1.1\r\nHost: arena\r\n\r\n")?;
    s.flush()?;
    let mut buf = vec![0u8; max_bytes];
    let mut n = 0;
    while n < max_bytes {
        match s.read(&mut buf[n..]) {
            Ok(0) => break,
            Ok(k) => {
                n += k;
                // Headers + at least one body line is enough for a
                // stream probe.
                let text = String::from_utf8_lossy(&buf[..n]);
                if let Some(split) = text.find("\r\n\r\n") {
                    if text[split + 4..].contains('\n') {
                        break;
                    }
                }
            }
            Err(e) => {
                if n > 0 {
                    break;
                }
                return Err(e);
            }
        }
    }
    Ok(String::from_utf8_lossy(&buf[..n]).into_owned())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthz_and_metrics_roundtrip() {
        let srv = TelemetryServer::bind("127.0.0.1:0").unwrap();
        let addr = srv.local_addr();
        let sink = srv.sink();
        sink.set_metrics("# TYPE a counter\na 1\n".to_string());
        let h = http_get(&addr, "/healthz", 4096).unwrap();
        assert!(h.starts_with("HTTP/1.1 200"), "{h}");
        assert!(h.contains("ok"));
        let m = http_get(&addr, "/metrics", 4096).unwrap();
        assert!(m.contains("# TYPE a counter"), "{m}");
        assert!(m.contains("\na 1"));
        let nf = http_get(&addr, "/nope", 4096).unwrap();
        assert!(nf.starts_with("HTTP/1.1 404"), "{nf}");
        srv.stop();
    }

    #[test]
    fn stream_replays_last_frame_to_late_subscriber() {
        let srv = TelemetryServer::bind("127.0.0.1:0").unwrap();
        let addr = srv.local_addr();
        let sink = srv.sink();
        sink.push_frame("{\"type\":\"round\",\"k\":1}");
        // Wait for the pump to latch the frame.
        for _ in 0..100 {
            let r = http_get(&addr, "/stream", 8192).unwrap_or_default();
            if r.contains("{\"type\":\"round\",\"k\":1}") {
                srv.stop();
                return;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        panic!("stream subscriber never received the latched frame");
    }

    #[test]
    fn stream_receives_live_frames() {
        let srv = TelemetryServer::bind("127.0.0.1:0").unwrap();
        let addr = srv.local_addr();
        let sink = srv.sink();
        // Subscribe first, then push: the frame must be fanned out.
        let handle = {
            let addr = addr;
            // Sanctioned spawn: blocking test probe, not simulation work.
            #[allow(clippy::disallowed_methods)]
            std::thread::spawn(move || http_get(&addr, "/stream", 8192))
        };
        // Give the subscriber time to register, then emit frames until
        // the probe returns.
        for _ in 0..100 {
            sink.push_frame("{\"k\":2}");
            std::thread::sleep(Duration::from_millis(20));
            if handle.is_finished() {
                break;
            }
        }
        let got = handle.join().unwrap().unwrap();
        assert!(got.contains("{\"k\":2}"), "{got}");
        srv.stop();
    }
}
