//! Tiny pure-rust MLP with manual backprop.
//!
//! Used for the Favor baseline's DQN Q-network (the baseline's compute is
//! deliberately not part of the paper's AOT hot path) and as an in-crate
//! sanity mirror of the L2 dense math.

use crate::util::rng::Rng;

/// Fully-connected network with ReLU hidden layers and linear output.
#[derive(Clone, Debug)]
pub struct Mlp {
    /// Layer weight matrices, row-major [in, out].
    ws: Vec<Vec<f32>>,
    bs: Vec<Vec<f32>>,
    dims: Vec<usize>,
}

impl Mlp {
    pub fn new(dims: &[usize], rng: &mut Rng) -> Self {
        assert!(dims.len() >= 2);
        let mut ws = Vec::new();
        let mut bs = Vec::new();
        for win in dims.windows(2) {
            let (i, o) = (win[0], win[1]);
            let std = (2.0 / i as f64).sqrt();
            ws.push(
                (0..i * o)
                    .map(|_| (rng.normal() * std) as f32)
                    .collect(),
            );
            bs.push(vec![0.0; o]);
        }
        Mlp {
            ws,
            bs,
            dims: dims.to_vec(),
        }
    }

    pub fn output_dim(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// Forward pass; returns activations per layer (input included).
    fn forward_full(&self, x: &[f32]) -> Vec<Vec<f32>> {
        assert_eq!(x.len(), self.dims[0]);
        let mut acts = vec![x.to_vec()];
        for (l, (w, b)) in self.ws.iter().zip(&self.bs).enumerate() {
            let (i, o) = (self.dims[l], self.dims[l + 1]);
            let prev = &acts[l];
            let mut out = b.clone();
            for r in 0..i {
                let a = prev[r];
                if a == 0.0 {
                    continue;
                }
                let row = &w[r * o..(r + 1) * o];
                for c in 0..o {
                    out[c] += a * row[c];
                }
            }
            if l + 1 < self.ws.len() {
                for v in out.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            acts.push(out);
        }
        acts
    }

    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        self.forward_full(x).pop().unwrap()
    }

    /// One SGD step on 0.5·||y_pred - y_target||² (only `mask`ed outputs
    /// contribute, as DQN updates a single action's Q). Returns the loss.
    pub fn train_step(
        &mut self,
        x: &[f32],
        target: &[f32],
        mask: &[f32],
        lr: f32,
    ) -> f32 {
        let acts = self.forward_full(x);
        let out = acts.last().unwrap();
        let o_dim = self.output_dim();
        assert_eq!(target.len(), o_dim);
        assert_eq!(mask.len(), o_dim);
        let mut delta: Vec<f32> = (0..o_dim)
            .map(|c| (out[c] - target[c]) * mask[c])
            .collect();
        let loss: f32 = delta.iter().map(|d| 0.5 * d * d).sum();
        // Backprop through layers.
        for l in (0..self.ws.len()).rev() {
            let (i, o) = (self.dims[l], self.dims[l + 1]);
            let prev = &acts[l];
            // Grad wrt prev activations (before applying relu grad).
            let mut dprev = vec![0.0f32; i];
            {
                let w = &self.ws[l];
                for r in 0..i {
                    let row = &w[r * o..(r + 1) * o];
                    let mut acc = 0.0;
                    for c in 0..o {
                        acc += row[c] * delta[c];
                    }
                    dprev[r] = acc;
                }
            }
            // Parameter update.
            let w = &mut self.ws[l];
            for r in 0..i {
                let a = prev[r];
                if a != 0.0 {
                    let row = &mut w[r * o..(r + 1) * o];
                    for c in 0..o {
                        row[c] -= lr * a * delta[c];
                    }
                }
            }
            let b = &mut self.bs[l];
            for c in 0..o {
                b[c] -= lr * delta[c];
            }
            // ReLU grad for the next (earlier) layer.
            if l > 0 {
                for r in 0..i {
                    if acts[l][r] <= 0.0 {
                        dprev[r] = 0.0;
                    }
                }
            }
            delta = dprev;
        }
        loss
    }

    /// Copy parameters from another network (DQN target sync).
    pub fn copy_from(&mut self, other: &Mlp) {
        assert_eq!(self.dims, other.dims);
        self.ws.clone_from(&other.ws);
        self.bs.clone_from(&other.bs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let net = Mlp::new(&[4, 8, 3], &mut rng);
        assert_eq!(net.forward(&[0.1, -0.2, 0.3, 0.4]).len(), 3);
    }

    #[test]
    fn learns_a_linear_map() {
        let mut rng = Rng::new(2);
        let mut net = Mlp::new(&[2, 16, 1], &mut rng);
        let f = |x: f64, y: f64| (2.0 * x - y) as f32;
        let mask = [1.0];
        let mut last = f32::INFINITY;
        for it in 0..4000 {
            let x = rng.range(-1.0, 1.0);
            let y = rng.range(-1.0, 1.0);
            last = net.train_step(
                &[x as f32, y as f32],
                &[f(x, y)],
                &mask,
                0.02,
            );
            let _ = it;
        }
        assert!(last < 0.02, "final loss {last}");
        let pred = net.forward(&[0.5, 0.5])[0];
        assert!((pred - 0.5).abs() < 0.25, "pred {pred}");
    }

    #[test]
    fn masked_outputs_do_not_update() {
        let mut rng = Rng::new(3);
        let mut net = Mlp::new(&[2, 4, 2], &mut rng);
        let before = net.forward(&[0.3, 0.7]);
        // Train only output 0; output 1's prediction on the same input
        // can shift through shared hidden weights, but the loss must only
        // count output 0.
        let loss = net.train_step(&[0.3, 0.7], &[before[0], 999.0],
                                  &[1.0, 0.0], 0.1);
        assert_eq!(loss, 0.0); // target == prediction on the masked dim
    }

    #[test]
    fn copy_from_syncs() {
        let mut rng = Rng::new(4);
        let a = Mlp::new(&[3, 5, 2], &mut rng);
        let mut b = Mlp::new(&[3, 5, 2], &mut rng);
        b.copy_from(&a);
        let x = [0.1, 0.2, 0.3];
        assert_eq!(a.forward(&x), b.forward(&x));
    }
}
