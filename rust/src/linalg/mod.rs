//! Small dense linear algebra: just enough for the Gram-trick PCA fit
//! (R x R symmetric eigenproblem with R = M+1 ≈ 6) and the clustering
//! distance math. Deliberately simple — all heavy lifting at scale P runs
//! through the Pallas artifacts.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// self * other.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Gram matrix self * self^T (rows x rows).
    pub fn gram(&self) -> Mat {
        let mut g = Mat::zeros(self.rows, self.rows);
        for i in 0..self.rows {
            for j in i..self.rows {
                let mut acc = 0.0;
                let (ri, rj) = (self.row(i), self.row(j));
                for k in 0..self.cols {
                    acc += ri[k] * rj[k];
                }
                g[(i, j)] = acc;
                g[(j, i)] = acc;
            }
        }
        g
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Eigen-decomposition of a symmetric matrix by the cyclic Jacobi method.
/// Returns (eigenvalues desc, eigenvectors as columns, in matching order).
pub fn jacobi_eigen(a: &Mat, max_sweeps: usize) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "jacobi needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::identity(n);
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-15 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum()
                    / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut pairs: Vec<(f64, usize)> =
        (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let vals: Vec<f64> = pairs.iter().map(|&(val, _)| val).collect();
    let mut vecs = Mat::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for r in 0..n {
            vecs[(r, new_col)] = v[(r, old_col)];
        }
    }
    (vals, vecs)
}

/// Squared Euclidean distance between two points.
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Mat::identity(2);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn jacobi_on_known_matrix() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Mat::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, vecs) = jacobi_eigen(&a, 50);
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 1.0).abs() < 1e-10);
        // eigenvector of 3 is (1,1)/sqrt(2) up to sign
        let ratio = vecs[(0, 0)] / vecs[(1, 0)];
        assert!((ratio - 1.0).abs() < 1e-8);
    }

    #[test]
    fn prop_jacobi_reconstructs_symmetric_matrices() {
        check(
            "jacobi-reconstruction",
            30,
            |g| {
                let n = g.usize_in(2, 8);
                let mut rng = Rng::new(g.rng.next_u64());
                let mut a = Mat::zeros(n, n);
                for i in 0..n {
                    for j in i..n {
                        let x = rng.range(-3.0, 3.0);
                        a[(i, j)] = x;
                        a[(j, i)] = x;
                    }
                }
                a
            },
            |a| {
                let n = a.rows;
                let (vals, vecs) = jacobi_eigen(a, 100);
                // Check A v_k = lambda_k v_k for each column.
                for k in 0..n {
                    for i in 0..n {
                        let mut av = 0.0;
                        for j in 0..n {
                            av += a[(i, j)] * vecs[(j, k)];
                        }
                        let want = vals[k] * vecs[(i, k)];
                        if (av - want).abs() > 1e-7 {
                            return Err(format!(
                                "Av != lambda v at ({i},{k}): {av} vs {want}"
                            ));
                        }
                    }
                }
                // Orthonormal columns.
                for k1 in 0..n {
                    for k2 in 0..n {
                        let mut dot = 0.0;
                        for i in 0..n {
                            dot += vecs[(i, k1)] * vecs[(i, k2)];
                        }
                        let want = if k1 == k2 { 1.0 } else { 0.0 };
                        if (dot - want).abs() > 1e-8 {
                            return Err("eigvecs not orthonormal".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gram_is_symmetric_psd_diagonal() {
        let a = Mat::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![-1.0, 0.5, 2.0],
        ]);
        let g = a.gram();
        assert_eq!(g.rows, 2);
        assert!((g[(0, 1)] - g[(1, 0)]).abs() < 1e-12);
        assert!(g[(0, 0)] >= 0.0 && g[(1, 1)] >= 0.0);
    }
}
