//! Online membership: churn-driven re-clustering with live topology
//! migration (paper §3.1 — "if new devices join, the profiling module can
//! also periodically re-cluster").
//!
//! The startup topology (`hfl::topology::build_topology`) clusters the
//! *whole* population once. Under churn (`sim::mobility`) the active set
//! drifts away from that clustering: edges shrink unevenly, capability
//! mixes degrade, and the straggler-removal property of the profiling
//! module erodes. This module makes re-clustering a first-class, online
//! operation:
//!
//! * [`MembershipTracker`] accumulates drift — joins + leaves since the
//!   last clustering (fed by [`crate::sim::FlipStats`], no per-event
//!   re-scan of the active vector) and the worst per-region live
//!   edge-size imbalance ([`region_imbalance`]) — and decides when
//!   `cluster.recluster_threshold` is crossed, rate-limited by
//!   `cluster.recluster_min_interval`.
//! * [`plan_recluster`] re-clusters the **live** population with the same
//!   region-constrained balanced k-means the profiling module uses at
//!   startup, then parks departed devices on their region's emptiest
//!   edges so no edge can exceed its startup share (`topology.nmax`
//!   safety) when they rejoin. Pure function of its inputs + RNG stream:
//!   deterministic under a fixed seed, unit/property-testable and
//!   benchable without AOT artifacts.
//!
//! The engines drive the subsystem differently but share the core
//! (`HflEngine::recluster_core`):
//!
//! * `HflEngine` (and the event engine's synchronous mode, bit-for-bit
//!   identically) checks between cloud rounds, right after the mobility
//!   step; migrated devices warm-start from their new edge's current
//!   model, delivered as downlink transfers through `sim::link` whose
//!   straggler landing advances the simulated clock.
//! * `AsyncHflEngine` schedules an [`crate::sim::Event::Recluster`] when
//!   a `MobilityFlip` pushes drift past the threshold; migration is live:
//!   in-flight training of migrated devices is voided (the stale-result
//!   protocol), pending quorum reports are purged and semi-sync quorums
//!   re-derived against the new membership, and each destination edge's
//!   model rides a real in-flight downlink — the migrated devices resume
//!   training only when it lands.
//!
//! With `cluster.recluster_threshold <= 0` (default) or zero churn the
//! subsystem is inert and runs are bit-for-bit identical to the
//! pre-subsystem behavior ([`MembershipTracker::should_recluster`] hard
//! short-circuits on zero observed flips).
//!
//! In the sharded engine loop (`hfl::engine_shard`) `Recluster` is a
//! ctrl-queue barrier: all shards sweep to the barrier time, the plan
//! runs serially on the merged live set, and migrations move device
//! state between shards through the explicit `migrate_out` /
//! `migrate_in` handoff (same-shard moves stay local), with quorum
//! re-derivation and warm-start downlinks replayed in fixed shard
//! order — so a re-clustering run stays bitwise identical at any
//! `sim.workers`.

use crate::cluster::profiling::{cluster_by_region, zscore};
use crate::config::ClusterConfig;
use crate::sim::{FlipStats, Region};
use crate::util::rng::Rng;

/// A device move produced by a re-clustering.
pub type Migration = (usize, usize, usize); // (device, old edge, new edge)

/// Full re-assignment of the population after one re-clustering.
#[derive(Clone, Debug, PartialEq)]
pub struct ReclusterPlan {
    /// Edge id per device (whole population: live devices from the fresh
    /// clustering, departed devices parked on their region's emptiest
    /// edges).
    pub assignment: Vec<usize>,
    /// Live devices whose edge changed.
    pub migrated: Vec<Migration>,
    /// Within-cluster MSE of the live clustering (normalized features).
    pub mse: f64,
    /// Live devices that were clustered.
    pub live: usize,
}

/// What one executed re-clustering did (surfaced by the engines for tests
/// and logging).
#[derive(Clone, Debug)]
pub struct ReclusterOutcome {
    /// Simulated time the re-clustering ran.
    pub at: f64,
    pub migrated: Vec<Migration>,
    pub live: usize,
    pub mse: f64,
    /// Straggler duration of the warm-start downlinks (barrier path; the
    /// event engine's migration downlinks are in-flight transfers
    /// instead).
    pub migration_downlink_time: f64,
}

/// Live imbalance the balancer can actually act on: the worst per-region
/// [`edge_imbalance`]. Re-clustering balances *within* regions (devices
/// cannot cross), so structural cross-region skew — regions with unequal
/// devices-per-edge shares — must not register as drift or every flip
/// past `min_interval` would re-trigger a re-cluster that cannot fix it.
pub fn region_imbalance(
    live_per_edge: &[usize],
    edge_regions: &[Region],
) -> f64 {
    assert_eq!(live_per_edge.len(), edge_regions.len());
    [Region::Cn, Region::Us]
        .iter()
        .map(|&region| {
            let counts: Vec<usize> = live_per_edge
                .iter()
                .zip(edge_regions)
                .filter(|&(_, &r)| r == region)
                .map(|(&c, _)| c)
                .collect();
            edge_imbalance(&counts)
        })
        .fold(0.0, f64::max)
}

/// Relative live edge-size imbalance: `(max - min) / mean` of the live
/// member counts (0 for an empty or perfectly even population).
pub fn edge_imbalance(live_per_edge: &[usize]) -> f64 {
    if live_per_edge.is_empty() {
        return 0.0;
    }
    let total: usize = live_per_edge.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mean = total as f64 / live_per_edge.len() as f64;
    let max = *live_per_edge.iter().max().unwrap() as f64;
    let min = *live_per_edge.iter().min().unwrap() as f64;
    (max - min) / mean
}

/// Region-constrained balanced re-clustering of the live population.
///
/// `live` lists the active devices and `features[i]` is `live[i]`'s
/// freshly profiled characteristic (`V_i`, see `cluster::profiling`).
/// `current` is the full current device→edge assignment; departed devices
/// keep region but are re-parked for balance. Returns `None` when any
/// region has fewer live devices than edges (clustering is deferred until
/// the population recovers).
/// Whether the live population can be re-clustered at all: balanced
/// k-means needs at least one point per cluster in every region. Cheap
/// (no profiling, no allocation) — the engines gate on this *before*
/// paying the re-profiling pass, since a failed attempt would otherwise
/// still mutate every live device's CPU state.
pub fn plan_is_feasible(
    live: &[usize],
    device_regions: &[Region],
    edge_regions: &[Region],
) -> bool {
    [Region::Cn, Region::Us].iter().all(|&region| {
        let k = edge_regions.iter().filter(|&&r| r == region).count();
        let l = live
            .iter()
            .filter(|&&d| device_regions[d] == region)
            .count();
        k == 0 || l >= k
    })
}

pub fn plan_recluster(
    live: &[usize],
    features: &[Vec<f64>],
    device_regions: &[Region],
    edge_regions: &[Region],
    current: &[usize],
    rng: &mut Rng,
) -> Option<ReclusterPlan> {
    let n = current.len();
    assert_eq!(live.len(), features.len(), "one feature row per live device");
    let mut is_live = vec![false; n];
    for &d in live {
        is_live[d] = true;
    }
    if !plan_is_feasible(live, device_regions, edge_regions) {
        return None;
    }

    // The exact clustering recipe of the startup profiling module,
    // applied to the live rows only (shared core — see
    // `cluster::profiling::cluster_by_region`).
    let norm = zscore(features);
    let live_regions: Vec<Region> =
        live.iter().map(|&d| device_regions[d]).collect();
    let (live_assign, total_mse) =
        cluster_by_region(&norm, &live_regions, edge_regions, rng);
    let mut assignment = current.to_vec();
    for (i, &d) in live.iter().enumerate() {
        assignment[d] = live_assign[i];
    }
    for &region in &[Region::Cn, Region::Us] {
        let edges: Vec<usize> = (0..edge_regions.len())
            .filter(|&j| edge_regions[j] == region)
            .collect();
        if edges.is_empty() {
            continue;
        }
        // Park departed devices on the region's emptiest edges (by total
        // size, ties to the lowest edge id) so a rejoin wave cannot push
        // any edge past its startup share.
        let mut sizes: Vec<usize> = edges
            .iter()
            .map(|&e| {
                live.iter().filter(|&&d| assignment[d] == e).count()
            })
            .collect();
        for d in 0..n {
            if is_live[d] || device_regions[d] != region {
                continue;
            }
            let slot = sizes
                .iter()
                .enumerate()
                .min_by_key(|&(i, &s)| (s, i))
                .map(|(i, _)| i)
                .expect("region has edges");
            assignment[d] = edges[slot];
            sizes[slot] += 1;
        }
        // Repair: in tight populations balanced k-means can leave a
        // cluster empty (min size is l - (k-1)·⌈l/k⌉, which can reach 0)
        // and a region may have no departed devices to park there. Every
        // edge must keep at least one member (topology invariant), so
        // pull one device over from the fullest edge — preferring a
        // departed device, whose move is invisible until it rejoins.
        loop {
            let Some(empty) = sizes.iter().position(|&s| s == 0) else {
                break;
            };
            let donor = sizes
                .iter()
                .enumerate()
                .max_by_key(|&(i, &s)| (s, std::cmp::Reverse(i)))
                .map(|(i, _)| i)
                .expect("region has edges");
            debug_assert!(
                sizes[donor] > 1,
                "region population must cover its edges"
            );
            let donor_edge = edges[donor];
            let pick = (0..n)
                .rev()
                .filter(|&d| assignment[d] == donor_edge)
                .min_by_key(|&d| is_live[d])
                .expect("donor edge is non-empty");
            assignment[pick] = edges[empty];
            sizes[donor] -= 1;
            sizes[empty] += 1;
        }
    }

    let migrated: Vec<Migration> = live
        .iter()
        .filter(|&&d| assignment[d] != current[d])
        .map(|&d| (d, current[d], assignment[d]))
        .collect();
    Some(ReclusterPlan {
        assignment,
        migrated,
        mse: if live.is_empty() {
            0.0
        } else {
            total_mse / live.len() as f64
        },
        live: live.len(),
    })
}

/// Tracks active-set drift and owns the re-clustering policy + RNG stream.
///
/// Drift is `max(churn fraction, live edge-size imbalance)` where the
/// churn fraction is (joins + leaves since the last clustering) / n and
/// the imbalance is the worst *per-region* spread ([`region_imbalance`] —
/// what a region-constrained re-cluster can actually repair). With zero
/// observed flips the tracker never triggers regardless of the imbalance
/// term — the hard guarantee that zero-churn runs are bit-for-bit
/// unchanged.
#[derive(Clone, Debug)]
pub struct MembershipTracker {
    /// Drift fraction that triggers a re-cluster (`<= 0` disables).
    pub threshold: f64,
    /// Minimum simulated seconds between re-clusterings.
    pub min_interval: f64,
    /// Dedicated RNG stream for re-profiling/clustering, independent of
    /// the engine's main stream (enabling the subsystem must not perturb
    /// training/communication draws until it actually fires).
    pub(crate) rng: Rng,
    drift: FlipStats,
    last_recluster_t: f64,
    /// Re-clusterings executed over the run.
    pub n_reclusters: usize,
    /// Devices migrated over the run.
    pub migrated_total: usize,
    round_reclusters: usize,
    round_migrated: usize,
}

impl MembershipTracker {
    pub fn from_config(cluster: &ClusterConfig, seed: u64) -> Self {
        MembershipTracker {
            threshold: cluster.recluster_threshold,
            min_interval: cluster.recluster_min_interval,
            rng: Rng::new(seed ^ 0x4ec1),
            drift: FlipStats::default(),
            last_recluster_t: 0.0,
            n_reclusters: 0,
            migrated_total: 0,
            round_reclusters: 0,
            round_migrated: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.threshold > 0.0
    }

    /// Feed one mobility step's join/leave counts into the drift.
    pub fn observe(&mut self, flips: FlipStats) {
        self.drift.merge(flips);
    }

    /// Joins + leaves accumulated since the last re-clustering.
    pub fn drift_flips(&self) -> FlipStats {
        self.drift
    }

    /// Current drift measure against a population of `n`. `imbalance` is
    /// the live edge-size imbalance the balancer can act on — the
    /// engines feed [`region_imbalance`] (`HflEngine::membership_imbalance`).
    pub fn drift(&self, n: usize, imbalance: f64) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let churn = self.drift.total() as f64 / n as f64;
        churn.max(imbalance)
    }

    /// O(1) pre-gate for [`should_recluster`](Self::should_recluster):
    /// whether a drift check is worth computing at all. Hard-gated on at
    /// least one observed flip since the last clustering, so a churn-free
    /// (or disabled) run never pays the O(n) live-imbalance scan — and
    /// can never trigger (the bit-for-bit no-op guarantee).
    pub fn wants_check(&self, now: f64) -> bool {
        self.enabled()
            && self.drift.total() > 0
            && now - self.last_recluster_t >= self.min_interval
    }

    /// Whether a re-clustering should run now. Callers gate on
    /// [`wants_check`](Self::wants_check) first and only then compute
    /// `imbalance` (an O(n) membership scan).
    pub fn should_recluster(
        &self,
        now: f64,
        n: usize,
        imbalance: f64,
    ) -> bool {
        self.wants_check(now) && self.drift(n, imbalance) >= self.threshold
    }

    /// Commit an executed re-clustering: reset the drift accumulator and
    /// bump the run/round counters.
    pub fn record_recluster(&mut self, now: f64, migrated: usize) {
        self.drift = FlipStats::default();
        self.last_recluster_t = now;
        self.n_reclusters += 1;
        self.migrated_total += migrated;
        self.round_reclusters += 1;
        self.round_migrated += migrated;
    }

    /// Drain the per-round (re-clusterings, migrated devices) counters —
    /// the engines call this once per emitted `RoundStats`.
    pub fn take_round_stats(&mut self) -> (usize, usize) {
        (
            std::mem::take(&mut self.round_reclusters),
            std::mem::take(&mut self.round_migrated),
        )
    }

    /// Fresh-run reset (keeps the policy knobs, restarts drift/counters;
    /// the RNG stream continues — determinism is per engine construction,
    /// matching the mobility model which is not reset either).
    pub fn reset(&mut self) {
        self.drift = FlipStats::default();
        self.last_recluster_t = 0.0;
        self.n_reclusters = 0;
        self.migrated_total = 0;
        self.round_reclusters = 0;
        self.round_migrated = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    fn tracker(threshold: f64, min_interval: f64) -> MembershipTracker {
        MembershipTracker::from_config(
            &ClusterConfig {
                recluster_threshold: threshold,
                recluster_min_interval: min_interval,
            },
            42,
        )
    }

    #[test]
    fn imbalance_of_even_and_uneven_populations() {
        assert_eq!(edge_imbalance(&[]), 0.0);
        assert_eq!(edge_imbalance(&[0, 0, 0]), 0.0);
        assert_eq!(edge_imbalance(&[4, 4, 4]), 0.0);
        // mean 3, max-min 2 -> 2/3.
        assert!((edge_imbalance(&[4, 3, 2]) - 2.0 / 3.0).abs() < 1e-12);
        // One dead edge is maximal pressure.
        assert!(edge_imbalance(&[6, 0]) > 1.9);
    }

    #[test]
    fn region_imbalance_ignores_structural_cross_region_skew() {
        use Region::{Cn, Us};
        let regions = [Cn, Cn, Us, Us];
        // Each region internally even, but CN edges carry 6 and US 3:
        // re-clustering cannot fix that, so it must not read as drift.
        assert_eq!(region_imbalance(&[6, 6, 3, 3], &regions), 0.0);
        // Within-region skew does count — and the worst region wins.
        let v = region_imbalance(&[6, 6, 5, 1], &regions);
        assert!((v - 4.0 / 3.0).abs() < 1e-12, "us (5-1)/3 = {v}");
        let v = region_imbalance(&[8, 4, 3, 3], &regions);
        assert!((v - 4.0 / 6.0).abs() < 1e-12, "cn (8-4)/6 = {v}");
    }

    #[test]
    fn zero_churn_never_triggers() {
        // Even with an absurdly low threshold, long horizon, and a wildly
        // imbalanced live layout: no observed flip -> no re-cluster.
        let t = tracker(1e-9, 0.0);
        assert!(!t.wants_check(1e9), "zero flips must not even check");
        assert!(!t.should_recluster(1e9, 10, 3.0));
        assert_eq!(t.drift_flips().total(), 0);
    }

    #[test]
    fn threshold_and_min_interval_gate_triggers() {
        let mut t = tracker(0.2, 100.0);
        assert!(t.enabled());
        t.observe(FlipStats { joins: 1, leaves: 0 });
        // Drift exists and the interval passed: a check is warranted...
        assert!(t.wants_check(150.0));
        assert!(!t.wants_check(50.0), "inside min_interval");
        // ...but 1 flip / 10 devices = 0.1 < 0.2 with balanced edges
        // stays below the threshold.
        assert!(!t.should_recluster(150.0, 10, 0.0));
        t.observe(FlipStats { joins: 0, leaves: 1 });
        // 0.2 >= 0.2 but min_interval not yet passed.
        assert!(!t.should_recluster(50.0, 10, 0.0));
        assert!(t.should_recluster(150.0, 10, 0.0));
        // Imbalance alone (with nonzero churn) can also trip it.
        let mut t2 = tracker(0.5, 0.0);
        t2.observe(FlipStats { joins: 0, leaves: 1 });
        assert!(!t2.should_recluster(1.0, 100, 0.0));
        assert!(t2.should_recluster(1.0, 100, 1.0));
        // Committing resets the drift and starts the interval clock.
        t.record_recluster(150.0, 3);
        assert_eq!(t.n_reclusters, 1);
        assert_eq!(t.migrated_total, 3);
        assert!(!t.should_recluster(500.0, 10, 1.6));
        assert_eq!(t.take_round_stats(), (1, 3));
        assert_eq!(t.take_round_stats(), (0, 0), "round counters drain");
    }

    #[test]
    fn disabled_tracker_ignores_everything() {
        let mut t = tracker(0.0, 0.0);
        assert!(!t.enabled());
        t.observe(FlipStats { joins: 50, leaves: 50 });
        assert!(!t.should_recluster(1e6, 10, 2.0));
    }

    #[test]
    fn feasibility_requires_live_cover_per_region() {
        let device_regions =
            [Region::Cn, Region::Cn, Region::Cn, Region::Us, Region::Us];
        let edge_regions = [Region::Cn, Region::Cn, Region::Us];
        assert!(plan_is_feasible(
            &[0, 1, 3],
            &device_regions,
            &edge_regions
        ));
        // Only one live CN device for two CN edges.
        assert!(!plan_is_feasible(
            &[0, 3, 4],
            &device_regions,
            &edge_regions
        ));
    }

    // ---- plan_recluster properties -----------------------------------

    struct Pop {
        device_regions: Vec<Region>,
        edge_regions: Vec<Region>,
        current: Vec<usize>,
        live: Vec<usize>,
        features: Vec<Vec<f64>>,
        seed: u64,
    }

    /// Random region-valid population with a feasible live set (each
    /// region keeps at least as many live devices as it has edges).
    fn gen_pop(g: &mut Gen) -> Pop {
        let m_cn = g.usize_in(1, 3);
        let m_us = g.usize_in(1, 3);
        let mut edge_regions = vec![Region::Cn; m_cn];
        edge_regions.extend(vec![Region::Us; m_us]);
        let n_cn = m_cn + g.size(12);
        let n_us = m_us + g.size(12);
        let mut device_regions = vec![Region::Cn; n_cn];
        device_regions.extend(vec![Region::Us; n_us]);
        let n = n_cn + n_us;
        // Current assignment: round-robin within each region (any
        // region-respecting map works).
        let current: Vec<usize> = (0..n)
            .map(|d| {
                if device_regions[d] == Region::Cn {
                    d % m_cn
                } else {
                    m_cn + (d % m_us)
                }
            })
            .collect();
        // Live mask: drop devices at random but keep each region feasible.
        let mut live = Vec::new();
        let mut live_cn = 0;
        let mut live_us = 0;
        for d in 0..n {
            if g.bool() || g.bool() {
                live.push(d);
                match device_regions[d] {
                    Region::Cn => live_cn += 1,
                    Region::Us => live_us += 1,
                }
            }
        }
        for d in 0..n {
            let region = device_regions[d];
            let (cnt, need) = match region {
                Region::Cn => (&mut live_cn, m_cn),
                Region::Us => (&mut live_us, m_us),
            };
            if *cnt < need && !live.contains(&d) {
                live.push(d);
                *cnt += 1;
            }
        }
        live.sort_unstable();
        let features: Vec<Vec<f64>> =
            (0..live.len()).map(|_| g.vec_f64(5, 0.0, 10.0)).collect();
        let seed = g.rng.next_u64();
        Pop {
            device_regions,
            edge_regions,
            current,
            live,
            features,
            seed,
        }
    }

    #[test]
    fn plan_preserves_population_regions_and_balance() {
        check("recluster-plan-invariants", 60, gen_pop, |p| {
            let mut rng = Rng::new(p.seed);
            let plan = plan_recluster(
                &p.live,
                &p.features,
                &p.device_regions,
                &p.edge_regions,
                &p.current,
                &mut rng,
            )
            .ok_or("feasible population must produce a plan")?;
            let n = p.current.len();
            let m = p.edge_regions.len();
            if plan.assignment.len() != n {
                return Err("assignment must cover the population".into());
            }
            if plan.live != p.live.len() {
                return Err(format!(
                    "live count changed: {} != {}",
                    plan.live,
                    p.live.len()
                ));
            }
            // Region constraints: every device (live or parked) stays on
            // an edge of its own region.
            for d in 0..n {
                let e = plan.assignment[d];
                if e >= m {
                    return Err(format!("device {d} on bogus edge {e}"));
                }
                if p.edge_regions[e] != p.device_regions[d] {
                    return Err(format!("device {d} crossed regions"));
                }
            }
            // nmax safety: no edge exceeds its region's fair share.
            for &region in &[Region::Cn, Region::Us] {
                let k = p
                    .edge_regions
                    .iter()
                    .filter(|&&r| r == region)
                    .count();
                let n_r = p
                    .device_regions
                    .iter()
                    .filter(|&&r| r == region)
                    .count();
                let cap = n_r.div_ceil(k);
                for j in 0..m {
                    if p.edge_regions[j] != region {
                        continue;
                    }
                    let total = (0..n)
                        .filter(|&d| plan.assignment[d] == j)
                        .count();
                    if total > cap {
                        return Err(format!(
                            "edge {j} holds {total} > cap {cap}"
                        ));
                    }
                }
            }
            // Topology invariant: no edge ends empty (each region holds
            // at least as many devices as edges by construction).
            for j in 0..m {
                if (0..n).all(|d| plan.assignment[d] != j) {
                    return Err(format!("edge {j} ended empty"));
                }
            }
            // Migration list is exactly the live diff.
            for &(d, old, new) in &plan.migrated {
                if p.current[d] != old || plan.assignment[d] != new {
                    return Err("migration entry inconsistent".into());
                }
                if !p.live.contains(&d) {
                    return Err("departed device listed as migrated".into());
                }
            }
            let diff = p
                .live
                .iter()
                .filter(|&&d| plan.assignment[d] != p.current[d])
                .count();
            if diff != plan.migrated.len() {
                return Err("migration list incomplete".into());
            }
            Ok(())
        });
    }

    #[test]
    fn plan_is_deterministic_under_a_fixed_seed() {
        check("recluster-plan-determinism", 30, gen_pop, |p| {
            let run = || {
                let mut rng = Rng::new(p.seed);
                plan_recluster(
                    &p.live,
                    &p.features,
                    &p.device_regions,
                    &p.edge_regions,
                    &p.current,
                    &mut rng,
                )
            };
            if run() != run() {
                return Err("same seed produced different plans".into());
            }
            Ok(())
        });
    }

    #[test]
    fn infeasible_region_defers_reclustering() {
        // 2 CN edges but only 1 live CN device: plan must decline.
        let device_regions = vec![Region::Cn, Region::Cn, Region::Us];
        let edge_regions = vec![Region::Cn, Region::Cn, Region::Us];
        let current = vec![0, 1, 2];
        let live = vec![0, 2];
        let features = vec![vec![1.0; 5], vec![2.0; 5]];
        let mut rng = Rng::new(1);
        assert!(plan_recluster(
            &live,
            &features,
            &device_regions,
            &edge_regions,
            &current,
            &mut rng,
        )
        .is_none());
    }

    #[test]
    fn plan_groups_similar_live_devices() {
        // One region, two edges, live devices in two clear speed bands:
        // each band should dominate one edge.
        let n = 12;
        let device_regions = vec![Region::Cn; n];
        let edge_regions = vec![Region::Cn, Region::Cn];
        let current: Vec<usize> = (0..n).map(|d| d % 2).collect();
        let live: Vec<usize> = (0..n).collect();
        let features: Vec<Vec<f64>> = (0..n)
            .map(|d| {
                let base = if d < 6 { 1.0 } else { 9.0 };
                vec![base, base * 2.0, base, base, base]
            })
            .collect();
        let mut rng = Rng::new(7);
        let plan = plan_recluster(
            &live,
            &features,
            &device_regions,
            &edge_regions,
            &current,
            &mut rng,
        )
        .unwrap();
        // Majority of each band must share an edge, and the two bands'
        // majority edges must differ (perfect splits depend on seeding
        // internals; the grouping property is what matters).
        let majority = |devs: std::ops::Range<usize>| -> (usize, usize) {
            let mut counts = [0usize; 2];
            for d in devs {
                counts[plan.assignment[d]] += 1;
            }
            if counts[0] >= counts[1] {
                (0, counts[0])
            } else {
                (1, counts[1])
            }
        };
        let (slow_edge, slow_n) = majority(0..6);
        let (fast_edge, fast_n) = majority(6..n);
        assert!(
            slow_n >= 5 && fast_n >= 5 && slow_edge != fast_edge,
            "bands not grouped: {:?}",
            plan.assignment
        );
    }
}
