//! Copy-on-write versioned model store — the shared ownership layer
//! behind every model buffer in the system.
//!
//! Both engines used to carry `device_w: Vec<Vec<f32>>`: a full flat model
//! clone per device, re-memcpy'd by every broadcast, warm-start and
//! recluster migration, so memory and copy traffic scaled as O(N·p) even
//! though most devices' models are *identical* to their edge's between
//! training bursts. Production FL systems hand devices shared, versioned
//! model state by reference instead (arXiv:1902.01046); this module is
//! that layer.
//!
//! # The store
//!
//! [`ModelStore`] is a reference-counted slab of `p`-length `f32` buffers
//! with a free-list pool: released buffers keep their allocation and are
//! reused by the next checkout, so a steady-state run allocates a bounded
//! working set no matter how many devices cycle through training.
//! [`ModelRef`] is the handle — a buffer id plus a **version tag**. The
//! tag is the staleness bookkeeping that used to live in parallel
//! counters (`edge_version` / `device_version` / `landed_version`): a
//! line's version advances at that line's aggregations, and staleness is
//! a version delta read straight off the handles.
//!
//! # Ownership rules
//!
//! * Every live model buffer is owned by the store; everything else holds
//!   [`ModelRef`] handles. Each held handle owns exactly one reference.
//! * Handles are **explicit**: they are not `Clone` and have no `Drop`.
//!   Duplicating one is [`ModelStore::share`] (rc bump); disposing of one
//!   is [`ModelStore::release`] (buffer returns to the pool at rc 0).
//!   The engines' rc discipline is checked by the property tests below.
//! * **Re-pointing is O(1)**: broadcast, edge→device sync, warm-start and
//!   migration delivery move handles ([`ModelStore::repoint`] /
//!   [`ModelStore::adopt`]), never bytes.
//! * **Materialization is copy-on-write**: a writer calls
//!   [`ModelStore::make_mut`] (or [`ModelStore::mix_into`]); if the
//!   buffer is shared, the handle is re-pointed to a pooled copy first,
//!   so sharers never observe the write. A checkout of a shared buffer
//!   therefore *always* copies — the no-mutable-aliasing invariant.
//!
//! Everything is deterministic and RNG-free: slab ids depend only on the
//! call sequence, and no observable value ever depends on an id.
//!
//! # The sharded engine keeps the store serial
//!
//! The sharded `AsyncHflEngine` loop (`hfl::engine_shard`) never hands
//! a [`ModelRef`] to a worker thread: shards simulate timing/energy and
//! emit ordered action logs, and every store effect (train adopt,
//! aggregation mix, payload share/release, migration repoint) is
//! applied during the serial barrier replay, in fixed shard order.
//! Slab-id and free-list order therefore remain a pure function of the
//! trajectory — the same at any `sim.workers` — without the store
//! needing any synchronization. (`ShardedModelStore` below serves the
//! synthetic `sim::shard` harness, which does put slabs on threads.)

/// Handle to one model buffer in a [`ModelStore`]: slab id + version tag.
///
/// Deliberately neither `Clone` nor `Copy` — every duplication must go
/// through [`ModelStore::share`] so the reference count stays truthful.
/// The version tag rides the handle (not the buffer): re-points can keep
/// or take versions depending on what the move means (see the engine
/// call sites).
#[derive(Debug)]
pub struct ModelRef {
    id: usize,
    version: u64,
}

impl ModelRef {
    /// The handle's version tag (per-line monotone; staleness = delta).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Slab id (diagnostics only — never meaningful across stores).
    pub fn id(&self) -> usize {
        self.id
    }

    /// Whether two handles address the same underlying buffer.
    pub fn shares_buffer_with(&self, other: &ModelRef) -> bool {
        self.id == other.id
    }

    /// Advance the version tag by one (an aggregation on this line).
    /// Monotone on purpose: there is no way to move a tag backwards.
    pub fn bump_version(&mut self) {
        self.version += 1;
    }
}

struct Slot {
    w: Vec<f32>,
    rc: usize,
}

/// Reference-counted, pooled slab of flat model buffers (see module doc).
pub struct ModelStore {
    /// Flat model parameter count — every buffer is exactly this long.
    p: usize,
    slots: Vec<Slot>,
    /// Slot ids with rc 0; their buffers keep their allocation (the pool).
    free: Vec<usize>,
    live: usize,
    peak_live: usize,
}

impl ModelStore {
    pub fn new(p: usize) -> Self {
        ModelStore {
            p,
            slots: Vec::new(),
            free: Vec::new(),
            live: 0,
            peak_live: 0,
        }
    }

    pub fn p(&self) -> usize {
        self.p
    }

    fn alloc_slot(&mut self, w: Vec<f32>) -> usize {
        debug_assert_eq!(w.len(), self.p);
        let id = if let Some(id) = self.free.pop() {
            // Adopt the incoming buffer; the pooled allocation is dropped
            // (net allocation churn identical to the pre-store engines).
            self.slots[id].w = w;
            self.slots[id].rc = 1;
            id
        } else {
            self.slots.push(Slot { w, rc: 1 });
            self.slots.len() - 1
        };
        self.live += 1;
        if self.live > self.peak_live {
            self.peak_live = self.live;
        }
        id
    }

    /// Copy slot `src` into a pooled buffer (reusing a free allocation
    /// when one exists — the CoW fast path) and return the new live id.
    fn alloc_copy_of(&mut self, src: usize) -> usize {
        if let Some(id) = self.free.pop() {
            let mut w = std::mem::take(&mut self.slots[id].w);
            w.copy_from_slice(&self.slots[src].w);
            self.slots[id].w = w;
            self.slots[id].rc = 1;
            self.live += 1;
            if self.live > self.peak_live {
                self.peak_live = self.live;
            }
            id
        } else {
            let w = self.slots[src].w.clone();
            self.alloc_slot(w)
        }
    }

    /// Move a caller-owned buffer into the store as a fresh line head.
    pub fn insert(&mut self, w: Vec<f32>, version: u64) -> ModelRef {
        assert_eq!(w.len(), self.p, "model buffer has the wrong size");
        let id = self.alloc_slot(w);
        ModelRef { id, version }
    }

    /// Duplicate a handle: O(1), rc bump, same id and version.
    pub fn share(&mut self, r: &ModelRef) -> ModelRef {
        self.slots[r.id].rc += 1;
        ModelRef { id: r.id, version: r.version }
    }

    /// Dispose of a handle; the buffer returns to the pool at rc 0.
    pub fn release(&mut self, r: ModelRef) {
        let slot = &mut self.slots[r.id];
        assert!(slot.rc > 0, "release of a dead handle (slot {})", r.id);
        slot.rc -= 1;
        if slot.rc == 0 {
            self.free.push(r.id);
            self.live -= 1;
        }
    }

    /// Read access. Handles of one store never dangle: buffers only leave
    /// the slab by pooling, which live handles (rc > 0) prevent.
    pub fn slice(&self, r: &ModelRef) -> &[f32] {
        &self.slots[r.id].w
    }

    /// Re-point `dst` at `src`'s buffer (rc bump + release of the old
    /// buffer), taking `src`'s version tag. O(1) — this is a broadcast /
    /// edge→device sync / warm-start, per receiver.
    pub fn repoint(&mut self, dst: &mut ModelRef, src: &ModelRef) {
        self.slots[src.id].rc += 1;
        let old = std::mem::replace(
            dst,
            ModelRef { id: src.id, version: src.version },
        );
        self.release(old);
    }

    /// [`ModelStore::repoint`], but `dst` keeps its own version tag —
    /// the move changes which buffer a line holds without counting as an
    /// aggregation on that line (e.g. an edge adopting a cloud broadcast).
    pub fn repoint_keep_version(
        &mut self,
        dst: &mut ModelRef,
        src: &ModelRef,
    ) {
        self.slots[src.id].rc += 1;
        let v = dst.version;
        let old = std::mem::replace(dst, ModelRef { id: src.id, version: v });
        self.release(old);
    }

    /// Replace `dst` with the owned handle `src` (no net rc change on
    /// `src`'s buffer; `dst`'s old buffer is released).
    pub fn adopt(&mut self, dst: &mut ModelRef, src: ModelRef) {
        let old = std::mem::replace(dst, src);
        self.release(old);
    }

    /// [`ModelStore::adopt`], but `dst` keeps its own version tag (e.g.
    /// an edge adopting a landed downlink payload: the edge's
    /// aggregation count did not advance).
    pub fn adopt_keep_version(&mut self, dst: &mut ModelRef, src: ModelRef) {
        let v = dst.version;
        let ModelRef { id, .. } = src;
        let old = std::mem::replace(dst, ModelRef { id, version: v });
        self.release(old);
    }

    /// Make `r`'s buffer exclusively owned: shared buffers are copied
    /// into a pooled scratch buffer first (CoW — sharers keep the old
    /// values), unique buffers are handed out as-is.
    fn ensure_unique(&mut self, r: &mut ModelRef) {
        if self.slots[r.id].rc == 1 {
            return;
        }
        let id = self.alloc_copy_of(r.id);
        self.slots[r.id].rc -= 1;
        // The donor stays live by construction: rc was >= 2.
        debug_assert!(self.slots[r.id].rc > 0);
        r.id = id;
    }

    /// Mutable checkout (CoW materialization on first write — see
    /// [`ModelStore::ensure_unique`]).
    pub fn make_mut(&mut self, r: &mut ModelRef) -> &mut [f32] {
        self.ensure_unique(r);
        &mut self.slots[r.id].w
    }

    /// In-place convex blend `dst = (1-beta)·dst + beta·src` through the
    /// CoW checkout — the FedAsync per-report edge update
    /// (`hfl::aggregate::mix_into`) against store-held operands.
    pub fn mix_into(
        &mut self,
        dst: &mut ModelRef,
        src: &ModelRef,
        beta: f32,
    ) {
        self.ensure_unique(dst);
        // Two live handles on one slot imply rc >= 2, which CoW just
        // split, so the ids are distinct and the split borrow is safe.
        debug_assert_ne!(dst.id, src.id, "mix_into on aliased handles");
        let (lo, hi, dst_is_lo) = if dst.id < src.id {
            (dst.id, src.id, true)
        } else {
            (src.id, dst.id, false)
        };
        let (a, b) = self.slots.split_at_mut(hi);
        let (d, s) = if dst_is_lo {
            (&mut a[lo].w, &b[0].w)
        } else {
            (&mut b[0].w, &a[lo].w)
        };
        super::aggregate::mix_into(d, s, beta);
    }

    // ---- observables ---------------------------------------------------

    /// Distinct buffers currently referenced by at least one handle.
    pub fn live_buffers(&self) -> usize {
        self.live
    }

    /// High-water mark of [`ModelStore::live_buffers`] over the store's
    /// lifetime.
    pub fn peak_live_buffers(&self) -> usize {
        self.peak_live
    }

    /// Slab size: every buffer ever needed simultaneously, live or pooled
    /// (monotone — the store never frees allocations).
    pub fn allocated_buffers(&self) -> usize {
        self.slots.len()
    }

    /// High-water memory footprint in bytes: the whole slab, pooled
    /// buffers included (they keep their allocations for reuse).
    pub fn peak_model_bytes(&self) -> usize {
        self.slots.len() * self.p * 4
    }

    /// References held on `r`'s buffer.
    pub fn refcount(&self, r: &ModelRef) -> usize {
        self.slots[r.id].rc
    }

    /// Whether `r`'s buffer is shared with at least one other handle.
    pub fn is_shared(&self, r: &ModelRef) -> bool {
        self.slots[r.id].rc > 1
    }

    /// Total references across all live buffers (= handles outstanding).
    pub fn total_refs(&self) -> usize {
        self.slots.iter().map(|s| s.rc).sum()
    }

    /// Structural self-check (tests): free list and refcounts agree, no
    /// slot is leaked (rc 0 outside the pool), buffer sizes hold.
    pub fn assert_consistent(&self) {
        let free: std::collections::HashSet<usize> =
            self.free.iter().copied().collect();
        assert_eq!(free.len(), self.free.len(), "free-list duplicates");
        let mut live = 0;
        for (id, s) in self.slots.iter().enumerate() {
            assert_eq!(s.w.len(), self.p, "slot {id} wrong size");
            if free.contains(&id) {
                assert_eq!(s.rc, 0, "pooled slot {id} still referenced");
            } else if s.rc > 0 {
                live += 1;
            } else {
                panic!("slot {id} leaked: rc 0 but not pooled");
            }
        }
        assert_eq!(live, self.live, "live-buffer counter drifted");
        assert!(self.peak_live >= self.live);
    }
}

/// Handle into a [`ShardedModelStore`]: which shard's slab, plus the
/// ordinary [`ModelRef`] within it. Like `ModelRef`, deliberately
/// neither `Clone` nor `Copy`.
#[derive(Debug)]
pub struct ShardedModelRef {
    shard: usize,
    r: ModelRef,
}

impl ShardedModelRef {
    pub fn shard(&self) -> usize {
        self.shard
    }

    pub fn version(&self) -> u64 {
        self.r.version()
    }

    pub fn bump_version(&mut self) {
        self.r.bump_version()
    }

    /// Same buffer ⇔ same shard *and* same slab id (ids are only
    /// meaningful within one shard's slab).
    pub fn shares_buffer_with(&self, other: &ShardedModelRef) -> bool {
        self.shard == other.shard && self.r.shares_buffer_with(&other.r)
    }
}

/// Device-sharded model store: one independent [`ModelStore`] slab per
/// shard of the sharded execution layer (`sim::shard`).
///
/// Within a shard everything is the ordinary CoW store — O(1) re-points,
/// rc'd sharing, pooled buffers — and a worker thread that owns a shard
/// touches only its own slab (grab disjoint `&mut ModelStore`s via
/// [`ShardedModelStore::shards_mut`] + `util::threadpool::par_for_each`;
/// the slabs are plain data, so they are `Send`). **No buffer is ever
/// shared across slabs**: cross-shard movement happens only at
/// conservative barriers, by copying bytes once per receiving shard —
/// [`ShardedModelStore::adopt_across`] for a single handle (e.g. a
/// migration landing on another shard) and
/// [`ShardedModelStore::replicate_at_barrier`] for the cloud broadcast
/// (one copy per shard, then every device re-points shard-locally —
/// O(shards) copies instead of O(devices)).
pub struct ShardedModelStore {
    shards: Vec<ModelStore>,
    // Cumulative cross-shard traffic (deterministic: pure function of
    // the call sequence). Surfaced via `stats()` for the observer.
    adopt_across: u64,
    adopt_bytes: u64,
    replicate: u64,
    replicate_bytes: u64,
}

impl ShardedModelStore {
    pub fn new(p: usize, n_shards: usize) -> Self {
        assert!(n_shards > 0, "need at least one shard");
        ShardedModelStore {
            shards: (0..n_shards).map(|_| ModelStore::new(p)).collect(),
            adopt_across: 0,
            adopt_bytes: 0,
            replicate: 0,
            replicate_bytes: 0,
        }
    }

    /// Rewrap per-shard slabs recovered from a worker pool. Traffic
    /// counters restart at zero (the slabs carry no traffic history).
    pub fn from_shards(shards: Vec<ModelStore>) -> Self {
        assert!(!shards.is_empty());
        assert!(
            shards.windows(2).all(|w| w[0].p() == w[1].p()),
            "shard slabs disagree on p"
        );
        ShardedModelStore {
            shards,
            adopt_across: 0,
            adopt_bytes: 0,
            replicate: 0,
            replicate_bytes: 0,
        }
    }

    /// Split into owned per-shard slabs (to move into a `ShardPool`).
    pub fn into_shards(self) -> Vec<ModelStore> {
        self.shards
    }

    pub fn p(&self) -> usize {
        self.shards[0].p()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The canonical device→shard map (fixed by topology, never by
    /// worker count).
    pub fn shard_of(&self, device: usize) -> usize {
        device % self.shards.len()
    }

    pub fn shard(&self, s: usize) -> &ModelStore {
        &self.shards[s]
    }

    /// Disjoint mutable slabs — feed to `par_for_each` so each worker
    /// mutates only its own shard's region.
    pub fn shards_mut(&mut self) -> &mut [ModelStore] {
        &mut self.shards
    }

    pub fn insert(
        &mut self,
        shard: usize,
        w: Vec<f32>,
        version: u64,
    ) -> ShardedModelRef {
        ShardedModelRef {
            shard,
            r: self.shards[shard].insert(w, version),
        }
    }

    pub fn share(&mut self, r: &ShardedModelRef) -> ShardedModelRef {
        ShardedModelRef {
            shard: r.shard,
            r: self.shards[r.shard].share(&r.r),
        }
    }

    pub fn release(&mut self, r: ShardedModelRef) {
        self.shards[r.shard].release(r.r);
    }

    pub fn slice(&self, r: &ShardedModelRef) -> &[f32] {
        self.shards[r.shard].slice(&r.r)
    }

    pub fn make_mut(&mut self, r: &mut ShardedModelRef) -> &mut [f32] {
        self.shards[r.shard].make_mut(&mut r.r)
    }

    /// Shard-local re-point (both handles must live in one slab —
    /// cross-shard sharing does not exist by construction).
    pub fn repoint(
        &mut self,
        dst: &mut ShardedModelRef,
        src: &ShardedModelRef,
    ) {
        assert_eq!(
            dst.shard, src.shard,
            "repoint across shards: use adopt_across at a barrier"
        );
        self.shards[dst.shard].repoint(&mut dst.r, &src.r);
    }

    /// Barrier-time handle adoption. Same shard: an O(1) adopt. Across
    /// shards: `src`'s bytes are copied once into `dst`'s slab (taking
    /// `src`'s version) and `src` is released in its own slab — the only
    /// way bytes ever cross a shard boundary.
    pub fn adopt_across(
        &mut self,
        dst: &mut ShardedModelRef,
        src: ShardedModelRef,
    ) {
        if dst.shard == src.shard {
            self.shards[dst.shard].adopt(&mut dst.r, src.r);
            return;
        }
        let v = src.version();
        let w = self.shards[src.shard].slice(&src.r).to_vec();
        self.adopt_across += 1;
        self.adopt_bytes += (w.len() * std::mem::size_of::<f32>()) as u64;
        self.shards[src.shard].release(src.r);
        let fresh = self.shards[dst.shard].insert(w, v);
        self.shards[dst.shard].adopt(&mut dst.r, fresh);
    }

    /// Replicate a barrier payload (e.g. the cloud model) into every
    /// shard: the source shard shares the existing buffer, every other
    /// shard gets one copy. Returns one handle per shard, in shard
    /// order; devices then re-point shard-locally (O(1) each).
    pub fn replicate_at_barrier(
        &mut self,
        src: &ShardedModelRef,
    ) -> Vec<ShardedModelRef> {
        let w = self.shards[src.shard].slice(&src.r).to_vec();
        let v = src.version();
        let copies = (self.shards.len() - 1) as u64;
        self.replicate += copies;
        self.replicate_bytes +=
            copies * (w.len() * std::mem::size_of::<f32>()) as u64;
        (0..self.shards.len())
            .map(|s| {
                if s == src.shard {
                    self.share(src)
                } else {
                    ShardedModelRef {
                        shard: s,
                        r: self.shards[s].insert(w.clone(), v),
                    }
                }
            })
            .collect()
    }

    // ---- observables (sums of the per-shard slabs) --------------------

    pub fn live_buffers(&self) -> usize {
        self.shards.iter().map(|s| s.live_buffers()).sum()
    }

    pub fn allocated_buffers(&self) -> usize {
        self.shards.iter().map(|s| s.allocated_buffers()).sum()
    }

    pub fn peak_model_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.peak_model_bytes()).sum()
    }

    pub fn total_refs(&self) -> usize {
        self.shards.iter().map(|s| s.total_refs()).sum()
    }

    pub fn assert_consistent(&self) {
        for s in &self.shards {
            s.assert_consistent();
        }
    }

    /// Deterministic observables snapshot: per-shard slab occupancy,
    /// totals, and the cumulative cross-shard traffic counters — what
    /// `Observer::on_sharded_store` folds into the registry and the
    /// `/stream` frames.
    pub fn stats(&self) -> ShardedStoreStats {
        let per_shard: Vec<ShardSlabStats> = self
            .shards
            .iter()
            .map(|s| ShardSlabStats {
                live_buffers: s.live_buffers(),
                peak_model_bytes: s.peak_model_bytes(),
                total_refs: s.total_refs(),
            })
            .collect();
        ShardedStoreStats {
            live_buffers: per_shard.iter().map(|s| s.live_buffers).sum(),
            peak_model_bytes: per_shard
                .iter()
                .map(|s| s.peak_model_bytes)
                .sum(),
            total_refs: per_shard.iter().map(|s| s.total_refs).sum(),
            adopt_across: self.adopt_across,
            adopt_bytes: self.adopt_bytes,
            replicate: self.replicate,
            replicate_bytes: self.replicate_bytes,
            per_shard,
        }
    }
}

/// One shard slab's occupancy inside a [`ShardedStoreStats`] snapshot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardSlabStats {
    pub live_buffers: usize,
    pub peak_model_bytes: usize,
    pub total_refs: usize,
}

/// Snapshot of a [`ShardedModelStore`]'s observables (see
/// [`ShardedModelStore::stats`]). All fields are deterministic — pure
/// functions of the store's call sequence, never of worker timing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardedStoreStats {
    /// Per-shard slab occupancy, in shard order.
    pub per_shard: Vec<ShardSlabStats>,
    pub live_buffers: usize,
    pub peak_model_bytes: usize,
    pub total_refs: usize,
    /// Cross-shard adoptions since construction (same-shard adopts are
    /// O(1) re-points and not counted).
    pub adopt_across: u64,
    /// Bytes copied across shard boundaries by those adoptions.
    pub adopt_bytes: u64,
    /// Copies made by `replicate_at_barrier` (the source shard's O(1)
    /// share is not counted).
    pub replicate: u64,
    pub replicate_bytes: u64,
}

impl ShardedStoreStats {
    /// Fraction of outstanding handles that share a buffer with another
    /// handle (0 when no handles exist).
    pub fn sharing_ratio(&self) -> f64 {
        if self.total_refs == 0 {
            0.0
        } else {
            (self.total_refs - self.live_buffers) as f64
                / self.total_refs as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    fn store_with(p: usize) -> ModelStore {
        ModelStore::new(p)
    }

    #[test]
    fn insert_share_release_roundtrip() {
        let mut st = store_with(4);
        assert_eq!(st.p(), 4);
        let a = st.insert(vec![1.0; 4], 0);
        assert_eq!(st.live_buffers(), 1);
        assert_eq!(st.refcount(&a), 1);
        assert!(!st.is_shared(&a));
        let b = st.share(&a);
        assert!(a.shares_buffer_with(&b));
        assert_eq!(st.refcount(&a), 2);
        assert!(st.is_shared(&a));
        st.release(b);
        assert_eq!(st.refcount(&a), 1);
        st.release(a);
        assert_eq!(st.live_buffers(), 0);
        assert_eq!(st.allocated_buffers(), 1, "pooled, not freed");
        st.assert_consistent();
    }

    #[test]
    fn pool_reuses_released_buffers() {
        let mut st = store_with(8);
        let a = st.insert(vec![1.0; 8], 0);
        let id_a = a.id();
        st.release(a);
        // The next insert adopts the pooled slot id — no slab growth.
        let b = st.insert(vec![2.0; 8], 0);
        assert_eq!(b.id(), id_a);
        assert_eq!(st.allocated_buffers(), 1);
        assert_eq!(st.slice(&b), &[2.0; 8]);
        st.release(b);
        st.assert_consistent();
    }

    #[test]
    fn make_mut_on_unique_is_in_place() {
        let mut st = store_with(4);
        let mut a = st.insert(vec![1.0; 4], 0);
        let id = a.id();
        st.make_mut(&mut a)[0] = 9.0;
        assert_eq!(a.id(), id, "unique checkout must not copy");
        assert_eq!(st.slice(&a)[0], 9.0);
        assert_eq!(st.allocated_buffers(), 1);
        st.release(a);
    }

    #[test]
    fn make_mut_on_shared_copies_and_preserves_sharers() {
        let mut st = store_with(4);
        let a = st.insert(vec![1.0; 4], 0);
        let mut b = st.share(&a);
        st.make_mut(&mut b)[0] = 9.0;
        assert!(!a.shares_buffer_with(&b), "CoW must split the buffer");
        assert_eq!(st.slice(&a), &[1.0; 4], "sharer saw the write");
        assert_eq!(st.slice(&b)[0], 9.0);
        assert_eq!(st.refcount(&a), 1);
        assert_eq!(st.refcount(&b), 1);
        st.release(a);
        st.release(b);
        st.assert_consistent();
    }

    #[test]
    fn repoint_moves_references_not_bytes() {
        let mut st = store_with(4);
        let cloud = st.insert(vec![7.0; 4], 3);
        let mut dev = st.insert(vec![0.0; 4], 1);
        st.repoint(&mut dev, &cloud);
        assert!(dev.shares_buffer_with(&cloud));
        assert_eq!(dev.version(), 3, "repoint takes the source version");
        assert_eq!(st.live_buffers(), 1, "old device buffer pooled");
        let mut dev2 = st.insert(vec![0.0; 4], 5);
        st.repoint_keep_version(&mut dev2, &cloud);
        assert!(dev2.shares_buffer_with(&cloud));
        assert_eq!(dev2.version(), 5, "keep_version keeps the tag");
        st.release(cloud);
        st.release(dev);
        st.release(dev2);
        assert_eq!(st.live_buffers(), 0);
        st.assert_consistent();
    }

    #[test]
    fn adopt_transfers_ownership() {
        let mut st = store_with(2);
        let mut line = st.insert(vec![1.0; 2], 4);
        let incoming = st.insert(vec![2.0; 2], 9);
        st.adopt_keep_version(&mut line, incoming);
        assert_eq!(st.slice(&line), &[2.0; 2]);
        assert_eq!(line.version(), 4, "adopt_keep_version keeps the tag");
        assert_eq!(st.live_buffers(), 1);
        let incoming = st.insert(vec![3.0; 2], 11);
        st.adopt(&mut line, incoming);
        assert_eq!(line.version(), 11, "adopt takes the payload tag");
        assert_eq!(st.slice(&line), &[3.0; 2]);
        st.release(line);
        st.assert_consistent();
    }

    #[test]
    fn mix_into_matches_reference_and_cows() {
        let mut st = store_with(4);
        let edge0 = st.insert(vec![0.0; 4], 0);
        let mut edge = st.share(&edge0);
        let dev = st.insert(vec![2.0; 4], 0);
        st.mix_into(&mut edge, &dev, 0.25);
        assert_eq!(st.slice(&edge), &[0.5; 4]);
        assert_eq!(st.slice(&edge0), &[0.0; 4], "sharer saw the mix");
        // Unique now: the second mix stays in place.
        let id = edge.id();
        st.mix_into(&mut edge, &dev, 1.0);
        assert_eq!(edge.id(), id);
        assert_eq!(st.slice(&edge), &[2.0; 4]);
        st.release(edge0);
        st.release(edge);
        st.release(dev);
        st.assert_consistent();
    }

    #[test]
    fn end_of_run_live_buffers_is_cloud_plus_edges() {
        // The engine-shaped lifecycle: after a cloud round's broadcast
        // every device handle shares its line's buffer, so exactly
        // 1 cloud + M edge buffers stay live no matter how many devices
        // trained during the round.
        let (m, n, p) = (4usize, 64usize, 16usize);
        let mut st = store_with(p);
        let cloud = st.insert(vec![0.0; p], 0);
        let mut edges: Vec<ModelRef> =
            (0..m).map(|_| st.share(&cloud)).collect();
        let mut devs: Vec<ModelRef> =
            (0..n).map(|_| st.share(&cloud)).collect();
        assert_eq!(st.live_buffers(), 1);
        for round in 1..=3u64 {
            // Devices train: checkout materializes private buffers. In
            // round 1 the edges still share the cloud buffer; afterwards
            // they hold their own aggregates.
            for d in devs.iter_mut() {
                st.make_mut(d)[0] = round as f32;
            }
            let expected = if round == 1 { 1 + n } else { 1 + m + n };
            assert_eq!(st.live_buffers(), expected);
            // Edge aggregation: new edge buffer, members re-point to it.
            for (j, e) in edges.iter_mut().enumerate() {
                let v = e.version() + 1;
                let agg = st.insert(vec![j as f32; p], v);
                st.adopt(e, agg);
            }
            for (d, dev) in devs.iter_mut().enumerate() {
                st.repoint(dev, &edges[d % m]);
            }
            assert_eq!(
                st.live_buffers(),
                1 + m,
                "after edge sync only cloud + M edge buffers are live"
            );
            st.assert_consistent();
        }
        for d in devs.drain(..) {
            st.release(d);
        }
        for e in edges.drain(..) {
            st.release(e);
        }
        st.release(cloud);
        assert_eq!(st.live_buffers(), 0);
        // The high-water mark saw the training burst even though the
        // idle state collapses back to 1 + m.
        assert!(st.peak_live_buffers() >= 1 + n);
        assert_eq!(st.peak_model_bytes(), st.allocated_buffers() * p * 4);
        st.assert_consistent();
    }

    // ---- property tests (store invariants) ---------------------------

    /// A random engine-shaped op sequence over cloud/edge/device lines.
    struct OpSeq {
        m: usize,
        n: usize,
        ops: Vec<Op>,
    }

    #[derive(Clone, Copy)]
    enum Op {
        /// Cloud aggregation + broadcast: everything re-points to a new
        /// cloud buffer.
        Broadcast,
        /// Edge j aggregates: new edge buffer, its devices re-point.
        EdgeAgg(usize),
        /// Device d trains: CoW checkout + write.
        Train(usize),
        /// FedAsync mix of device d into edge j.
        Mix(usize, usize),
        /// Device d warm-starts from edge j (migration / rejoin).
        Migrate(usize, usize),
        /// Snapshot edge j as an in-flight payload (upload); released at
        /// the end of the run like a landed/dropped transfer.
        Upload(usize),
    }

    fn gen_ops(g: &mut Gen) -> OpSeq {
        let m = g.usize_in(1, 4);
        let n = m + g.size(24);
        let len = g.size(60);
        let ops = (0..len)
            .map(|_| match g.usize_in(0, 5) {
                0 => Op::Broadcast,
                1 => Op::EdgeAgg(g.usize_in(0, m - 1)),
                2 => Op::Train(g.usize_in(0, n - 1)),
                3 => Op::Mix(g.usize_in(0, n - 1), g.usize_in(0, m - 1)),
                4 => Op::Migrate(g.usize_in(0, n - 1), g.usize_in(0, m - 1)),
                _ => Op::Upload(g.usize_in(0, m - 1)),
            })
            .collect();
        OpSeq { m, n, ops }
    }

    #[test]
    fn refcounts_never_leak() {
        check("store-refcounts-never-leak", 60, gen_ops, |seq| {
            let p = 8;
            let mut st = ModelStore::new(p);
            let mut cloud = st.insert(vec![0.0; p], 0);
            let mut edges: Vec<ModelRef> =
                (0..seq.m).map(|_| st.share(&cloud)).collect();
            let mut devs: Vec<ModelRef> =
                (0..seq.n).map(|_| st.share(&cloud)).collect();
            let mut payloads: Vec<ModelRef> = Vec::new();
            let mut dev_edge: Vec<usize> =
                (0..seq.n).map(|d| d % seq.m).collect();
            for &op in &seq.ops {
                match op {
                    Op::Broadcast => {
                        let v = cloud.version() + 1;
                        let fresh = st.insert(vec![v as f32; p], v);
                        st.adopt(&mut cloud, fresh);
                        for e in edges.iter_mut() {
                            st.repoint_keep_version(e, &cloud);
                        }
                        for d in devs.iter_mut() {
                            st.repoint_keep_version(d, &cloud);
                        }
                    }
                    Op::EdgeAgg(j) => {
                        let v = edges[j].version() + 1;
                        let agg = st.insert(vec![v as f32; p], v);
                        st.adopt(&mut edges[j], agg);
                        for d in 0..seq.n {
                            if dev_edge[d] == j {
                                st.repoint(&mut devs[d], &edges[j]);
                            }
                        }
                    }
                    Op::Train(d) => {
                        st.make_mut(&mut devs[d])[0] += 1.0;
                    }
                    Op::Mix(d, j) => {
                        if !devs[d].shares_buffer_with(&edges[j]) {
                            st.mix_into(&mut edges[j], &devs[d], 0.5);
                        }
                        edges[j].bump_version();
                    }
                    Op::Migrate(d, j) => {
                        st.repoint(&mut devs[d], &edges[j]);
                        dev_edge[d] = j;
                    }
                    Op::Upload(j) => {
                        payloads.push(st.share(&edges[j]));
                    }
                }
                // Invariant: live buffers == distinct ids among held
                // handles; total refs == handles outstanding.
                let mut ids: Vec<usize> = payloads
                    .iter()
                    .chain(edges.iter())
                    .chain(devs.iter())
                    .chain(std::iter::once(&cloud))
                    .map(|r| r.id())
                    .collect();
                let handles = ids.len();
                ids.sort_unstable();
                ids.dedup();
                if ids.len() != st.live_buffers() {
                    return Err(format!(
                        "live {} != distinct held ids {}",
                        st.live_buffers(),
                        ids.len()
                    ));
                }
                if st.total_refs() != handles {
                    return Err(format!(
                        "total refs {} != handles {}",
                        st.total_refs(),
                        handles
                    ));
                }
                st.assert_consistent();
            }
            // End of run: transfers land/drop, devices re-point to their
            // edges — exactly 1 cloud + M edge buffers may stay live.
            for r in payloads.drain(..) {
                st.release(r);
            }
            for d in 0..seq.n {
                st.repoint(&mut devs[d], &edges[dev_edge[d]]);
            }
            if st.live_buffers() > 1 + seq.m {
                return Err(format!(
                    "end-of-run live buffers {} > 1 cloud + {} edges",
                    st.live_buffers(),
                    seq.m
                ));
            }
            for d in devs.drain(..) {
                st.release(d);
            }
            for e in edges.drain(..) {
                st.release(e);
            }
            st.release(cloud);
            if st.live_buffers() != 0 {
                return Err("handles released but buffers live".into());
            }
            st.assert_consistent();
            Ok(())
        });
    }

    #[test]
    fn checkout_of_shared_buffer_always_copies() {
        check("store-cow-no-mutable-aliasing", 60, gen_ops, |seq| {
            let p = 8;
            let mut st = ModelStore::new(p);
            let base = st.insert(vec![1.0; p], 0);
            let mut handles: Vec<ModelRef> =
                (0..seq.n).map(|_| st.share(&base)).collect();
            for (i, &op) in seq.ops.iter().enumerate() {
                let d = match op {
                    Op::Train(d) | Op::Mix(d, _) | Op::Migrate(d, _) => d,
                    _ => continue,
                };
                let before = st.slice(&base).to_vec();
                let shared = st.is_shared(&handles[d]);
                let old_id = handles[d].id();
                st.make_mut(&mut handles[d])[i % p] = i as f32;
                if shared && handles[d].id() == old_id {
                    return Err(format!(
                        "checkout of shared buffer {old_id} wrote in place"
                    ));
                }
                if st.slice(&base) != before.as_slice() {
                    return Err("a sharer observed the write".into());
                }
            }
            for h in handles.drain(..) {
                st.release(h);
            }
            st.release(base);
            st.assert_consistent();
            Ok(())
        });
    }

    #[test]
    fn version_tags_strictly_increase_per_edge() {
        check("store-versions-increase-per-edge", 60, gen_ops, |seq| {
            let p = 4;
            let mut st = ModelStore::new(p);
            let mut cloud = st.insert(vec![0.0; p], 0);
            let mut edges: Vec<ModelRef> =
                (0..seq.m).map(|_| st.share(&cloud)).collect();
            let mut last: Vec<u64> =
                edges.iter().map(|e| e.version()).collect();
            for &op in &seq.ops {
                match op {
                    // An aggregation on edge j must strictly advance it.
                    Op::EdgeAgg(j) | Op::Mix(_, j) => {
                        let v = edges[j].version() + 1;
                        let agg = st.insert(vec![0.0; p], v);
                        st.adopt(&mut edges[j], agg);
                        if edges[j].version() <= last[j] {
                            return Err(format!(
                                "edge {j} version did not increase"
                            ));
                        }
                        last[j] = edges[j].version();
                    }
                    // A broadcast adoption moves the buffer but must
                    // never move a version tag backwards.
                    Op::Broadcast => {
                        cloud.bump_version();
                        for (j, e) in edges.iter_mut().enumerate() {
                            st.repoint_keep_version(e, &cloud);
                            if e.version() < last[j] {
                                return Err(format!(
                                    "edge {j} version went backwards"
                                ));
                            }
                        }
                    }
                    _ => {}
                }
            }
            for e in edges.drain(..) {
                st.release(e);
            }
            st.release(cloud);
            st.assert_consistent();
            Ok(())
        });
    }

    // ---- sharded store ------------------------------------------------

    #[test]
    fn sharded_single_shard_behaves_like_plain_store() {
        let mut st = ShardedModelStore::new(4, 1);
        assert_eq!(st.p(), 4);
        assert_eq!(st.shard_of(17), 0);
        let a = st.insert(0, vec![1.0; 4], 0);
        let mut b = st.share(&a);
        assert!(a.shares_buffer_with(&b));
        st.make_mut(&mut b)[0] = 9.0;
        assert!(!a.shares_buffer_with(&b), "CoW must split");
        assert_eq!(st.slice(&a), &[1.0; 4]);
        assert_eq!(st.live_buffers(), 2);
        st.release(a);
        st.release(b);
        assert_eq!(st.live_buffers(), 0);
        st.assert_consistent();
    }

    #[test]
    fn adopt_across_copies_bytes_between_slabs() {
        let mut st = ShardedModelStore::new(2, 3);
        let mut dev = st.insert(1, vec![0.0; 2], 0);
        let payload = st.insert(2, vec![7.0; 2], 5);
        st.adopt_across(&mut dev, payload);
        assert_eq!(dev.shard(), 1, "handle stays in its shard");
        assert_eq!(st.slice(&dev), &[7.0; 2]);
        assert_eq!(dev.version(), 5, "adoption takes the payload tag");
        assert_eq!(st.shard(2).live_buffers(), 0, "source released");
        assert_eq!(st.live_buffers(), 1);
        // Same-shard adoption is the O(1) path.
        let local = st.insert(1, vec![3.0; 2], 9);
        st.adopt_across(&mut dev, local);
        assert_eq!(st.slice(&dev), &[3.0; 2]);
        assert_eq!(dev.version(), 9);
        st.release(dev);
        st.assert_consistent();
    }

    #[test]
    fn replicate_at_barrier_copies_once_per_shard() {
        let (s_n, p) = (4usize, 8usize);
        let mut st = ShardedModelStore::new(p, s_n);
        let cloud = st.insert(0, vec![2.5; p], 3);
        let heads = st.replicate_at_barrier(&cloud);
        assert_eq!(heads.len(), s_n);
        assert!(heads[0].shares_buffer_with(&cloud), "src shard shares");
        for (s, h) in heads.iter().enumerate() {
            assert_eq!(h.shard(), s);
            assert_eq!(st.slice(h), &[2.5; p]);
            assert_eq!(h.version(), 3);
        }
        // One buffer in the source shard, one copy in each other shard.
        assert_eq!(st.live_buffers(), s_n);
        // Devices re-point shard-locally: no further copies.
        let mut devs: Vec<ShardedModelRef> = (0..32)
            .map(|d| {
                let s = st.shard_of(d);
                let mut h = st.insert(s, vec![0.0; p], 0);
                st.repoint(&mut h, &heads[s]);
                h
            })
            .collect();
        assert_eq!(st.live_buffers(), s_n);
        for d in devs.drain(..) {
            st.release(d);
        }
        for h in heads {
            st.release(h);
        }
        st.release(cloud);
        assert_eq!(st.live_buffers(), 0);
        st.assert_consistent();
    }

    #[test]
    fn traffic_counters_track_cross_shard_bytes() {
        let (s_n, p) = (3usize, 4usize);
        let mut st = ShardedModelStore::new(p, s_n);
        assert_eq!(st.stats(), ShardedStoreStats::default());
        let cloud = st.insert(0, vec![1.0; p], 1);
        let heads = st.replicate_at_barrier(&cloud);
        let mut dev = st.insert(1, vec![0.0; p], 0);
        let payload = st.insert(2, vec![9.0; p], 7);
        st.adopt_across(&mut dev, payload);
        // Same-shard adoption is O(1) and must not count as traffic.
        let local = st.insert(1, vec![3.0; p], 8);
        st.adopt_across(&mut dev, local);
        let s = st.stats();
        assert_eq!(s.per_shard.len(), s_n);
        assert_eq!(s.adopt_across, 1);
        assert_eq!(s.adopt_bytes, (p * 4) as u64);
        assert_eq!(s.replicate, (s_n - 1) as u64);
        assert_eq!(s.replicate_bytes, ((s_n - 1) * p * 4) as u64);
        assert_eq!(
            s.live_buffers,
            st.live_buffers(),
            "snapshot totals must match the ambient observables"
        );
        assert_eq!(s.total_refs, st.total_refs());
        // cloud + its source-shard share are the only shared handles.
        let shared = (s.total_refs - s.live_buffers) as f64;
        assert_eq!(s.sharing_ratio(), shared / s.total_refs as f64);
        st.release(dev);
        for h in heads {
            st.release(h);
        }
        st.release(cloud);
        st.assert_consistent();
    }

    #[test]
    fn sharded_store_splits_and_reassembles() {
        let mut st = ShardedModelStore::new(4, 3);
        let a = st.insert(2, vec![1.5; 4], 1);
        let shards = st.into_shards();
        assert_eq!(shards.len(), 3);
        let mut st = ShardedModelStore::from_shards(shards);
        assert_eq!(st.slice(&a), &[1.5; 4]);
        assert_eq!(st.n_shards(), 3);
        st.release(a);
        st.assert_consistent();
    }

    #[test]
    fn sharded_refcounts_never_leak() {
        // The engine-shaped op mix replayed against a sharded store:
        // edges/devices live in their canonical shards, broadcasts go
        // through replicate_at_barrier, cross-shard syncs through
        // adopt_across. Per-slab invariants must hold throughout.
        check("sharded-store-refcounts-never-leak", 40, gen_ops, |seq| {
            let p = 8;
            let s_n = 1 + seq.m.min(3);
            let mut st = ShardedModelStore::new(p, s_n);
            let mut cloud = st.insert(0, vec![0.0; p], 0);
            let mut edges: Vec<ShardedModelRef> = (0..seq.m)
                .map(|j| st.insert(j % s_n, vec![0.0; p], 0))
                .collect();
            let mut devs: Vec<ShardedModelRef> = (0..seq.n)
                .map(|d| {
                    let s = st.shard_of(d);
                    st.insert(s, vec![0.0; p], 0)
                })
                .collect();
            for &op in &seq.ops {
                match op {
                    Op::Broadcast => {
                        cloud.bump_version();
                        let heads = st.replicate_at_barrier(&cloud);
                        for e in edges.iter_mut() {
                            let src = st.share(&heads[e.shard()]);
                            st.adopt_across(e, src);
                        }
                        for d in devs.iter_mut() {
                            let src = st.share(&heads[d.shard()]);
                            st.adopt_across(d, src);
                        }
                        for h in heads {
                            st.release(h);
                        }
                    }
                    Op::EdgeAgg(j) => {
                        let v = edges[j].version() + 1;
                        let s = edges[j].shard();
                        let agg = st.insert(s, vec![v as f32; p], v);
                        st.adopt_across(&mut edges[j], agg);
                    }
                    Op::Train(d) => {
                        st.make_mut(&mut devs[d])[0] += 1.0;
                    }
                    Op::Mix(d, j) | Op::Migrate(d, j) => {
                        // Cross-shard sync: one copy lands in d's slab.
                        let src = st.share(&edges[j]);
                        st.adopt_across(&mut devs[d], src);
                    }
                    Op::Upload(j) => {
                        // Snapshot rides to the cloud shard (shard 0).
                        let src = st.share(&edges[j]);
                        let mut payload =
                            st.insert(0, vec![0.0; p], 0);
                        st.adopt_across(&mut payload, src);
                        st.release(payload);
                    }
                }
                let handles = 1 + edges.len() + devs.len();
                if st.total_refs() != handles {
                    return Err(format!(
                        "total refs {} != handles {}",
                        st.total_refs(),
                        handles
                    ));
                }
                st.assert_consistent();
            }
            for d in devs.drain(..) {
                st.release(d);
            }
            for e in edges.drain(..) {
                st.release(e);
            }
            st.release(cloud);
            if st.live_buffers() != 0 {
                return Err("handles released but buffers live".into());
            }
            st.assert_consistent();
            Ok(())
        });
    }
}
