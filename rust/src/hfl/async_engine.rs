//! Event-driven HFL engine: one executor, three synchronization modes,
//! and a first-class transfer layer.
//!
//! Where [`HflEngine::run_round`] can only express lock-step rounds (every
//! edge advances through barrier-synchronized sub-rounds), this engine is
//! driven by the deterministic discrete-event queue of [`crate::sim::event`]
//! and supports the synchronization families the paper's scheme decides
//! *between*:
//!
//! * **`SyncMode::Synchronous`** — the classic HFL schedule, re-expressed
//!   as events: every device's `DeviceTrainDone` is scheduled, each edge's
//!   `EdgeAggregate` fires when its last member reports, and the
//!   communication tail routes through the shared link layer
//!   (`HflEngine::sync_comm_phase`): the round closes when the straggler's
//!   upload lands. Reproduces `HflEngine::run_round` **bit-for-bit** under
//!   the same seed (same RNG streams consumed in the same order; equality
//!   is enforced by an integration test), proving the event core models
//!   the barrier semantics exactly.
//! * **`SyncMode::SemiSync`** — K-quorum edge aggregation: an edge
//!   aggregates as soon as `quorum` of its members have reported (reported
//!   devices idle until the quorum closes, then restart from the new edge
//!   model), while the cloud aggregates on a fixed timer. Stragglers can
//!   no longer stall their whole edge.
//! * **`SyncMode::Async`** — fully asynchronous, staleness-discounted
//!   aggregation after arXiv:2107.11415 / FedAsync: every device report
//!   immediately blends into the edge model with weight
//!   `data_share · 1/(1+s)^α` where `s` counts edge-model versions the
//!   update is stale by; the cloud timer aggregates edge models weighted by
//!   data size and per-edge freshness.
//!
//! # Communication is in-flight, not a lump
//!
//! Edge↔cloud communication is no longer sampled as a lump at the cloud
//! timer. In the timer-driven modes, an edge that aggregates schedules an
//! **in-flight upload** of the fresh edge model on its uplink
//! ([`crate::sim::link::LinkManager`]) and keeps training — upload time
//! overlaps the next local round (pace steering à la arXiv:1902.01046).
//! The cloud timer aggregates whatever uploads have *landed* by the tick
//! (latest version per edge, discounted by per-edge freshness in `Async`
//! mode), and the cloud→edge broadcast is a set of **downlink transfers**:
//! an edge only adopts the new global model when its broadcast lands, and
//! devices pick it up at their next edge aggregation. Overlapping
//! transfers on one link fair-share its bandwidth when `link.contention`
//! is on, and every landing is a `TransferDone` event, so the whole
//! timeline stays deterministic from the experiment seed (stale
//! re-predictions are dropped by the link layer's bit-exact timestamp
//! match).
//!
//! # Membership migrates live
//!
//! When churn drifts the active set past `cluster.recluster_threshold`,
//! a `MobilityFlip` schedules an [`Event::Recluster`] and the membership
//! subsystem (`hfl::membership`) re-profiles and re-clusters the live
//! population *without stopping the run*: migrated devices' in-flight
//! training is voided (the stale-result protocol), their pending quorum
//! reports are purged and semi-sync quorums re-derived against the new
//! membership, and each destination edge's current model rides a real
//! in-flight downlink — a migrated device resumes training only when its
//! warm-start model lands. Synchronous mode re-clusters between cloud
//! rounds through the same `HflEngine` path as the barrier engine
//! (bit-for-bit equal).
//!
//! In the timer-driven modes one `RoundStats` is emitted per cloud
//! aggregation window: `round_time` is the window length, `gamma2` reports
//! the *observed* per-edge aggregation counts of the window, `T_j^ec` is
//! the *observed* duration of the edge's last landed transfers, and the
//! per-edge `compute_busy`/`up_busy`/`down_busy`/`comm_overlap` fields
//! split the window into compute vs in-flight communication time.
//!
//! # Model state is shared, versioned, copy-on-write
//!
//! Every model buffer lives in the engine's [`crate::hfl::ModelStore`];
//! `edge_w`/`device_w`/the landed view/in-flight payloads are all
//! version-tagged `ModelRef` handles. Broadcast landings, edge→device
//! sync, rejoin resets and migration warm-starts are O(1) handle
//! re-points; upload/downlink/migration payloads are rc-held snapshots
//! kept intact by copy-on-write while in flight. The version tags *are*
//! the staleness bookkeeping: the FedAsync device discount is the delta
//! between the edge handle and the version the device trained from, the
//! cloud's out-of-order landing guards compare payload tags, and
//! `EdgeStats::staleness` is the delta between the cloud handle's
//! version (windows) and the window of the edge's last landed upload.
//!
//! # Learned per-edge control
//!
//! The timer-driven modes expose the knobs the DRL agent drives
//! (`agent::arena`, `sync.learned`): [`AsyncHflEngine::begin_run`] /
//! [`AsyncHflEngine::run_window`] step the run one cloud window at a
//! time, and [`AsyncHflEngine::set_control`] swaps the per-edge
//! local-epoch counts γ1_j (the edge-aggregation period — future
//! dispatches pick it up) and the per-edge staleness exponents α_j
//! (future discount computations pick them up) at the cloud-aggregation
//! decision point. Nothing in flight is touched — no queued event,
//! transfer, or pending training is re-timed — so re-arming with the
//! values already in force is bitwise invisible, and every run stays a
//! pure function of the experiment seed. The cloud decision point also
//! stamps each edge's control observables into `EdgeStats`
//! (`staleness`/`in_flight_up`/`quorum_fill`) — the rows the extended DRL
//! state is built from.

use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Result};

use crate::config::{ExperimentConfig, SyncConfig, SyncModeCfg};
use crate::runtime::pool::TrainJob;
use crate::sim::{Direction, Event, EventQueue};

use super::aggregate::staleness_discount;
use super::engine::HflEngine;
use super::lifecycle::{
    overselect_count, select_dispatch, storm_hits, FaultPlan,
};
use super::metrics::{RoundAccumulator, RoundStats, RunHistory};
use super::model_store::ModelRef;

/// Synchronization policy the event loop executes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SyncMode {
    Synchronous,
    SemiSync {
        /// Device reports that close an edge round (0 = all active members).
        quorum: usize,
        /// Cloud aggregation period, simulated seconds.
        cloud_interval: f64,
    },
    Async {
        /// Staleness discount exponent α of `1/(1+s)^α` — the *immutable
        /// config default* only. The running engine discounts with its
        /// per-edge `alpha` vector (seeded from this value, re-armed by
        /// `set_control`); never read this field on a live run.
        staleness_alpha: f64,
        cloud_interval: f64,
    },
}

impl SyncMode {
    pub fn from_config(sync: &SyncConfig) -> Self {
        match sync.mode {
            SyncModeCfg::Synchronous => SyncMode::Synchronous,
            SyncModeCfg::SemiSync => SyncMode::SemiSync {
                quorum: sync.quorum,
                cloud_interval: sync.cloud_interval,
            },
            SyncModeCfg::Async => SyncMode::Async {
                staleness_alpha: sync.staleness_alpha,
                cloud_interval: sync.cloud_interval,
            },
        }
    }

    fn cloud_interval(&self) -> f64 {
        match self {
            SyncMode::Synchronous => f64::INFINITY,
            SyncMode::SemiSync { cloud_interval, .. }
            | SyncMode::Async { cloud_interval, .. } => *cloud_interval,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SyncMode::Synchronous => "synchronous",
            SyncMode::SemiSync { .. } => "semi-sync",
            SyncMode::Async { .. } => "async",
        }
    }
}

/// Effective K-quorum against `live` members: clamps to the live count
/// (never below 1), with `quorum == 0` meaning "all live members".
pub(crate) fn effective_quorum(quorum: usize, live: usize) -> usize {
    let live = live.max(1);
    if quorum == 0 {
        live
    } else {
        quorum.min(live)
    }
}

/// True when `reported` outstanding reports satisfy the K-quorum against
/// the edge's `live` membership. The quorum clamps to the live count, so a
/// departure that shrinks an edge below K cannot leave its round unclosable
/// (the semi-sync liveness fix; re-checked on every `MobilityFlip`).
pub(crate) fn quorum_satisfied(
    reported: usize,
    quorum: usize,
    live: usize,
) -> bool {
    reported >= effective_quorum(quorum, live)
}

/// Stable per-variant label for observer hooks and metric names.
fn event_variant(ev: &Event) -> &'static str {
    match ev {
        Event::DeviceTrainDone { .. } => "train_done",
        Event::EdgeAggregate { .. } => "edge_aggregate",
        Event::CloudAggregate => "cloud_aggregate",
        Event::MobilityFlip => "mobility_flip",
        Event::Recluster => "recluster",
        Event::TransferDone { .. } => "transfer_done",
        Event::EdgeOutage { .. } => "edge_outage",
        Event::Partition { .. } => "partition",
        Event::CrashStorm { .. } => "crash_storm",
    }
}

/// A dispatched-but-not-yet-completed local training run. The real compute
/// happens eagerly at dispatch (results depend only on weights + seed, not
/// on simulated time); the simulated completion is the queued event. The
/// trained model lives IN the store while in flight (an rc-1 pooled
/// buffer, not a raw Vec) so the memory observables count it and the
/// free-list recycles it.
struct PendingTrain {
    /// The trained result, already adopted into the store, tagged with
    /// the edge-model version the training started from (read off the
    /// edge's `ModelRef` at dispatch) — the FedAsync staleness base.
    r: ModelRef,
    last_loss: Option<f64>,
    t: f64,
    energy: f64,
    /// Set when the device flipped (left, possibly rejoined) mid-flight:
    /// the result trained against a pre-departure model and is discarded
    /// on completion even if the device is active again by then.
    void: bool,
}

/// Model snapshot riding an in-flight transfer: an rc-held store handle
/// (`ModelStore::share` — no copy; copy-on-write keeps the snapshot
/// intact if the live line mutates mid-flight). The link layer schedules
/// pure timing; the engine owns the payloads, keyed by transfer id. The
/// handle's version tag doubles as the ordering guard: edge-aggregation
/// version for uploads, cloud-window version for downlinks.
enum Payload {
    /// Edge→cloud: the edge model as of its version at upload start.
    Upload { edge: usize, r: ModelRef },
    /// Cloud→edge: the global model broadcast by the cloud window in
    /// `r.version()` (one shared buffer serves every edge's downlink).
    Downlink { edge: usize, r: ModelRef },
    /// Warm-start delivery for a re-clustering: `edge`'s model at
    /// migration time, bound for the devices migrated onto it. `seq`
    /// identifies the re-clustering; a later one (or a leave+rejoin)
    /// supersedes the pending warm-start per device.
    Migration {
        edge: usize,
        r: ModelRef,
        devices: Vec<usize>,
        seq: u64,
    },
}

impl Payload {
    /// Surrender the payload's store handle (whatever the variant).
    fn into_ref(self) -> ModelRef {
        match self {
            Payload::Upload { r, .. }
            | Payload::Downlink { r, .. }
            | Payload::Migration { r, .. } => r,
        }
    }
}

pub struct AsyncHflEngine {
    pub eng: HflEngine,
    pub mode: SyncMode,
    queue: EventQueue,
    /// Per-edge local epochs for dispatched jobs (the edge-aggregation
    /// period; re-armed by `set_control` at cloud decision points).
    g1: Vec<usize>,
    /// Per-edge staleness-discount exponents α_j (`Async` mode; default
    /// `sync.staleness_alpha` everywhere, re-armed by `set_control`).
    alpha: Vec<f64>,
    /// device -> owning edge.
    dev_edge: Vec<usize>,
    in_flight: Vec<Option<PendingTrain>>,
    /// Per-edge devices reported since the edge last aggregated.
    reported: Vec<Vec<usize>>,
    // Per-edge model versions, the per-device start versions, the landed
    // ordering guard and the cloud window counter all used to be parallel
    // `Vec<u64>` counters here; they now ride the `ModelRef` handles
    // themselves (edge_w/cloud_w tags, the in-flight result's tag,
    // landed/payload tags) — staleness is a handle version delta.
    /// Window index (cloud version) of the edge's last *landed* upload
    /// (cloud freshness).
    edge_last_update_round: Vec<u64>,
    /// Edge aggregations inside the current cloud window.
    window_edge_aggs: Vec<usize>,
    acc: RoundAccumulator,
    window_start: f64,
    // ---- transfer layer state ------------------------------------------
    /// Payloads of in-flight transfers, keyed by transfer id.
    payloads: HashMap<usize, Payload>,
    /// Latest edge model that has landed at the cloud, per edge (a share
    /// of the initial global model until anything lands); the handle's
    /// version is the out-of-order landing guard.
    landed_w: Vec<ModelRef>,
    /// Uploads landed in the current cloud window, per edge.
    window_landings: Vec<usize>,
    /// Last observed transfer durations per edge (feed T_j^ec; 0 until
    /// the first landing).
    obs_up: Vec<f64>,
    obs_down: Vec<f64>,
    /// Cloud window of the broadcast each edge last adopted: a stale
    /// broadcast landing late (contention reorder) must not revert the
    /// edge to an older global model.
    adopted_cloud_round: Vec<u64>,
    /// Busy-interval sweeper: engine state is piecewise constant between
    /// events, so integrating at every pop is exact.
    sweep_t: f64,
    training_count: Vec<usize>,
    win_compute_busy: Vec<f64>,
    win_up_busy: Vec<f64>,
    win_down_busy: Vec<f64>,
    win_comm_busy: Vec<f64>,
    win_overlap: Vec<f64>,
    /// (transfer id, edge, landing time) of every completed transfer, in
    /// landing order — the determinism witness of the transfer path.
    pub transfer_log: Vec<(usize, usize, f64)>,
    /// Per-device pending warm-start: the re-clustering seq whose
    /// migration downlink the device is waiting for (0 = none). Awaiting
    /// devices are never dispatched.
    migration_seq: Vec<u64>,
    /// Monotone id of executed re-clusterings within the run.
    recluster_seq: u64,
    /// (recluster seq, device, new edge) of every warm-start that landed
    /// and was applied, in landing order.
    pub migration_log: Vec<(u64, usize, usize)>,
    /// Set for the end-of-run tail flush: the event loop is over, so new
    /// training dispatches and transfers could never complete — skip them
    /// instead of burning real compute on dead work.
    draining: bool,
    // ---- lifecycle / fault state (`hfl::lifecycle`) --------------------
    /// Injected-outage flag per edge (`Event::EdgeOutage`): a down edge
    /// dispatches nothing, its pending reports die with it, and its
    /// cloud transfers are dropped until the recovery event.
    edge_faulted: Vec<bool>,
    /// Injected-partition flag per edge (`Event::Partition`): a
    /// partitioned edge keeps training and aggregating locally, but its
    /// uplink/downlink to the cloud is severed until the heal.
    edge_partitioned: Vec<bool>,
    /// Stragglers abandoned this window, per edge: over-selection's
    /// first-K close plus fault-voided in-flight work. Drained into
    /// `EdgeStats::abandoned` at each cloud decision point.
    win_abandoned: Vec<usize>,
    /// Injected fault events handled this window (down and up edges of
    /// outages, partitions and storms); stamped into
    /// `RoundStats::fault_events`.
    win_fault_events: usize,
}

impl AsyncHflEngine {
    pub fn new(cfg: ExperimentConfig, use_profiling: bool) -> Result<Self> {
        let mode = SyncMode::from_config(&cfg.sync);
        let seed = cfg.seed;
        let mut eng = HflEngine::new(cfg, use_profiling)?;
        let n = eng.cfg.topology.devices;
        let m = eng.cfg.topology.edges;
        let mut dev_edge = vec![0usize; n];
        for (j, edge) in eng.topo.edges.iter().enumerate() {
            for &d in &edge.members {
                dev_edge[d] = j;
            }
        }
        let g1 = vec![eng.cfg.hfl.gamma1; m];
        let alpha = vec![eng.cfg.sync.staleness_alpha; m];
        // The cloud's landed view starts as rc-shares of the edge models
        // (all still the one init buffer) — no clones.
        let landed_w = eng.share_edge_handles();
        Ok(AsyncHflEngine {
            // Same seed as ever (the tie-break stream is part of the
            // trajectory); capacity/backend are bitwise invisible.
            queue: EventQueue::for_scale(
                seed ^ 0xa57c,
                n * 4 + 64,
                eng.cfg.sim.queue_backend,
            ),
            g1,
            alpha,
            dev_edge,
            in_flight: (0..n).map(|_| None).collect(),
            reported: vec![Vec::new(); m],
            edge_last_update_round: vec![0; m],
            window_edge_aggs: vec![0; m],
            acc: RoundAccumulator::new(m),
            window_start: 0.0,
            payloads: HashMap::new(),
            landed_w,
            window_landings: vec![0; m],
            obs_up: vec![0.0; m],
            obs_down: vec![0.0; m],
            adopted_cloud_round: vec![0; m],
            sweep_t: 0.0,
            training_count: vec![0; m],
            win_compute_busy: vec![0.0; m],
            win_up_busy: vec![0.0; m],
            win_down_busy: vec![0.0; m],
            win_comm_busy: vec![0.0; m],
            win_overlap: vec![0.0; m],
            transfer_log: Vec::new(),
            migration_seq: vec![0; n],
            recluster_seq: 0,
            migration_log: Vec::new(),
            draining: false,
            edge_faulted: vec![false; m],
            edge_partitioned: vec![false; m],
            win_abandoned: vec![0; m],
            win_fault_events: 0,
            mode,
            eng,
        })
    }

    pub fn edges(&self) -> usize {
        self.eng.edges()
    }

    /// Attach an [`Observer`](crate::obs::Observer) to the underlying
    /// engine. Hooks are read-only and may never feed back into the
    /// simulation — an instrumented run is bitwise identical to an
    /// uninstrumented one (enforced by an integration test).
    pub fn attach_observer(&mut self, obs: Box<dyn crate::obs::Observer>) {
        self.eng.attach_observer(obs);
    }

    /// Detach and return the current observer, if any.
    pub fn detach_observer(
        &mut self,
    ) -> Option<Box<dyn crate::obs::Observer>> {
        self.eng.detach_observer()
    }

    /// Run the configured mode to the time threshold with uniform default
    /// frequencies.
    pub fn run_to_threshold(&mut self) -> Result<RunHistory> {
        let g1 = vec![self.eng.cfg.hfl.gamma1; self.edges()];
        self.run_with(&g1)
    }

    /// Run the configured mode to the time threshold under per-edge local
    /// epochs `g1` (gamma2 only applies in `Synchronous`, from the config).
    pub fn run_with(&mut self, g1: &[usize]) -> Result<RunHistory> {
        anyhow::ensure!(
            g1.len() == self.edges(),
            "need {} per-edge frequencies",
            self.edges()
        );
        match self.mode {
            SyncMode::Synchronous => {
                self.eng.reset();
                let g2 = vec![self.eng.cfg.hfl.gamma2; self.edges()];
                let mut hist = RunHistory::default();
                while self.eng.remaining_time() > 0.0 {
                    hist.push(self.run_round(g1, &g2, None)?);
                }
                Ok(hist)
            }
            _ => {
                self.begin_run(g1)?;
                let mut hist = RunHistory::default();
                while let Some(stats) = self.run_window()? {
                    hist.push(stats);
                }
                Ok(hist)
            }
        }
    }

    /// Swap the per-edge control knobs at a cloud-aggregation decision
    /// point (the learned-sync hook): future dispatches run `g1[j]` local
    /// epochs per report — re-arming edge j's aggregation period — and
    /// future staleness discounts use exponent `alpha[j]`. Nothing
    /// in flight is re-timed, so re-arming with the values already in
    /// force leaves the run bit-for-bit unchanged.
    pub fn set_control(&mut self, g1: &[usize], alpha: &[f64]) -> Result<()> {
        let m = self.edges();
        anyhow::ensure!(
            g1.len() == m && alpha.len() == m,
            "need {m} per-edge control values"
        );
        anyhow::ensure!(
            g1.iter().all(|&g| g >= 1),
            "per-edge gamma1 must be >= 1"
        );
        anyhow::ensure!(
            alpha.iter().all(|&a| a.is_finite() && a >= 0.0),
            "per-edge alpha must be finite and >= 0"
        );
        self.g1.copy_from_slice(g1);
        self.alpha.copy_from_slice(alpha);
        Ok(())
    }

    /// Current per-edge (γ1_j, α_j) control values.
    pub fn control(&self) -> (&[usize], &[f64]) {
        (&self.g1, &self.alpha)
    }

    // -----------------------------------------------------------------
    // Synchronous mode: one barriered cloud round, event-driven.
    // -----------------------------------------------------------------

    /// Execute one synchronous cloud round through the event queue.
    /// Equivalent to `HflEngine::run_round` bit-for-bit under the same
    /// seed: the same RNG streams are consumed in the same order, and the
    /// event timeline reproduces the barrier arithmetic exactly (an edge's
    /// aggregate fires at its slowest member's completion; the cloud when
    /// the straggler edge's upload lands through the shared link layer).
    pub fn run_round(
        &mut self,
        gamma1: &[usize],
        gamma2: &[usize],
        participation: Option<&[bool]>,
    ) -> Result<RoundStats> {
        if !matches!(self.mode, SyncMode::Synchronous) {
            bail!(
                "run_round is the synchronous entry point; {} mode runs \
                 through run_with/run_to_threshold",
                self.mode.name()
            );
        }
        let m = self.edges();
        anyhow::ensure!(
            gamma1.len() == m && gamma2.len() == m,
            "need {m} per-edge frequencies"
        );
        let mut acc = RoundAccumulator::new(m);
        let mut edge_clock = vec![0.0f64; m];
        let max_gamma2 = gamma2.iter().copied().max().unwrap_or(1).max(1);

        for sub in 0..max_gamma2 {
            // One relative-time queue per sub-round: edges advance their
            // gamma2 schedules in *parallel* simulated time, so a fast
            // edge's sub-k+1 events may precede a slow edge's sub-k ones —
            // each drain unit gets its own timeline (and its events carry
            // the per-edge clock, matching run_round's accumulators
            // bit-for-bit).
            let mut q = EventQueue::for_scale(
                self.eng.cfg.seed
                    ^ 0x51ac
                    ^ ((self.eng.round as u64) << 8)
                    ^ ((sub as u64) << 40),
                self.eng.cfg.topology.devices * 2 + 16,
                self.eng.cfg.sim.queue_backend,
            );
            let (jobs, job_edges) =
                self.eng.gather_jobs(sub, gamma1, gamma2, participation);
            if jobs.is_empty() {
                continue;
            }
            let results = self.eng.train_batch(jobs)?;
            // Schedule every member's completion; count expected reports.
            // The per-device simulation is batched over the sim worker
            // pool (bit-identical to the serial loop at any sim.workers).
            let reqs: Vec<(usize, usize)> = results
                .iter()
                .map(|res| (res.device, res.losses.len()))
                .collect();
            let sims = self.eng.simulate_train_batch(&reqs);
            let mut expect = vec![0usize; m];
            let mut seen = vec![0usize; m];
            for ((res, &j), &(t_dev, e_dev)) in
                results.iter().zip(&job_edges).zip(&sims)
            {
                acc.record_train(
                    j,
                    res.device,
                    t_dev,
                    e_dev,
                    res.losses.last().copied(),
                );
                q.schedule(
                    edge_clock[j] + t_dev,
                    Event::DeviceTrainDone {
                        device: res.device,
                        edge: j,
                    },
                );
                expect[j] += 1;
            }
            for res in results {
                self.eng.commit_device(res.device, res.w);
            }
            // Drain the sub-round: an edge aggregates when its last member
            // reports, at that member's completion time.
            let mut remaining = expect.iter().sum::<usize>();
            while remaining > 0 {
                let (t, ev) = q.pop().expect("sync sub-round queue underflow");
                remaining -= 1;
                match ev {
                    Event::DeviceTrainDone { edge, .. } => {
                        seen[edge] += 1;
                        if seen[edge] == expect[edge] {
                            q.schedule(t, Event::EdgeAggregate { edge });
                            remaining += 1;
                        }
                    }
                    Event::EdgeAggregate { edge } => {
                        let devs =
                            self.eng.edge_participants(edge, participation);
                        if !devs.is_empty() {
                            self.eng.edge_aggregate_devices(edge, &devs)?;
                            edge_clock[edge] = t;
                        }
                    }
                    _ => unreachable!("unexpected event in sync sub-round"),
                }
            }
        }

        // Edge -> cloud communication through the link layer: the round
        // closes when the last upload lands (shared with HflEngine).
        let mut round_time = self.eng.sync_comm_phase(&edge_clock, &mut acc);
        let active: Vec<usize> =
            (0..m).filter(|&j| acc.per_edge[j].active > 0).collect();
        self.eng.cloud_aggregate_edges(&active, None)?;
        self.eng.broadcast_cloud();

        self.eng.clock.advance(round_time);
        self.eng.round += 1;
        self.eng.total_energy += acc.round_energy;
        let flips = self.eng.mobility.step();
        self.eng.membership.observe(flips);
        // Same between-rounds re-clustering call as HflEngine::run_round,
        // in the same position: identical RNG consumption and identical
        // accounting keep the two engines bit-for-bit equal in
        // synchronous mode.
        if let Some(out) = self.eng.maybe_recluster_barrier(&mut acc)? {
            round_time += out.migration_downlink_time;
            self.refresh_dev_edge();
        }
        self.eng
            .record_lifecycle_baseline(&mut acc, self.eng.clock.now());

        let (accuracy, test_loss) = self.eng.evaluate()?;
        let mut stats = acc.finish(
            self.eng.round,
            accuracy,
            test_loss,
            round_time,
            self.eng.clock.now(),
            gamma1,
            gamma2,
        );
        self.eng.finalize_membership_stats(&mut stats);
        self.eng.finalize_memory_stats(&mut stats);
        self.eng.emit_round_observation(&stats);
        self.eng.last_round = Some(stats.clone());
        Ok(stats)
    }

    /// Rebuild the device→edge map from the (possibly re-clustered)
    /// topology.
    fn refresh_dev_edge(&mut self) {
        for (j, e) in self.eng.topo.edges.iter().enumerate() {
            for &d in &e.members {
                self.dev_edge[d] = j;
            }
        }
    }

    // -----------------------------------------------------------------
    // SemiSync / Async modes: the free-running event loop.
    // -----------------------------------------------------------------

    /// Reset and arm a fresh timer-driven run: models, event queue, link
    /// and window state, the initial `CloudAggregate`/`MobilityFlip`
    /// timers, and the first dispatch of every device. The run then
    /// advances one cloud window per [`AsyncHflEngine::run_window`] call
    /// (with optional [`AsyncHflEngine::set_control`] swaps in between);
    /// `run_with` is the uncontrolled convenience loop over it.
    pub fn begin_run(&mut self, g1: &[usize]) -> Result<()> {
        anyhow::ensure!(
            !matches!(self.mode, SyncMode::Synchronous),
            "begin_run drives the timer modes; synchronous runs use \
             run_round/run_with"
        );
        anyhow::ensure!(
            g1.len() == self.edges(),
            "need {} per-edge frequencies",
            self.edges()
        );
        let m = self.edges();
        let n = self.eng.cfg.topology.devices;
        // Hand this engine's own store handles back before the reset
        // rebuilds the hierarchy: stale payloads, parked in-flight
        // results and the landed view must not keep last run's buffers
        // alive.
        for (_, p) in self.payloads.drain() {
            let r = p.into_ref();
            self.eng.store.release(r);
        }
        for slot in self.in_flight.iter_mut() {
            if let Some(p) = slot.take() {
                self.eng.store.release(p.r);
            }
        }
        for r in self.landed_w.drain(..) {
            self.eng.store.release(r);
        }
        self.eng.reset();
        self.g1 = g1.to_vec();
        self.alpha = vec![self.eng.cfg.sync.staleness_alpha; m];
        self.queue = EventQueue::for_scale(
            self.eng.cfg.seed ^ 0xa57c,
            n * 4 + 64,
            self.eng.cfg.sim.queue_backend,
        );
        self.in_flight = (0..n).map(|_| None).collect();
        self.reported = vec![Vec::new(); m];
        self.edge_last_update_round = vec![0; m];
        self.window_edge_aggs = vec![0; m];
        self.acc = RoundAccumulator::new(m);
        self.window_start = 0.0;
        self.landed_w = self.eng.share_edge_handles();
        self.window_landings = vec![0; m];
        self.obs_up = vec![0.0; m];
        self.obs_down = vec![0.0; m];
        self.adopted_cloud_round = vec![0; m];
        self.sweep_t = 0.0;
        self.training_count = vec![0; m];
        self.win_compute_busy = vec![0.0; m];
        self.win_up_busy = vec![0.0; m];
        self.win_down_busy = vec![0.0; m];
        self.win_comm_busy = vec![0.0; m];
        self.win_overlap = vec![0.0; m];
        self.transfer_log.clear();
        self.migration_seq = vec![0; n];
        self.recluster_seq = 0;
        self.migration_log.clear();
        self.refresh_dev_edge();
        self.draining = false;
        self.edge_faulted = vec![false; m];
        self.edge_partitioned = vec![false; m];
        self.win_abandoned = vec![0; m];
        self.win_fault_events = 0;

        let interval = self.mode.cloud_interval();
        self.queue.schedule(interval, Event::CloudAggregate);
        // Mobility steps once per window, offset to avoid timer ties.
        self.queue.schedule(0.5 * interval, Event::MobilityFlip);
        // Injected faults are scheduled events, never ambient state
        // (`hfl::lifecycle` determinism rules): the plan expands the
        // `fault.*` knobs once from a dedicated stream and lands in the
        // queue like any other event. A zero-count plan is empty —
        // no schedule calls, no tie-break draws — so a fault-free run
        // is bitwise identical to one built before faults existed.
        let plan = FaultPlan::build(
            &self.eng.cfg.fault,
            m,
            self.eng.cfg.hfl.threshold_time,
            self.eng.cfg.seed,
        );
        for &(t, ev) in plan.events() {
            self.queue.schedule(t, ev);
        }
        let cohort = self.initial_cohort();
        self.dispatch(&cohort, 0.0)
    }

    /// Devices to dispatch at run start: everyone — unless semi-sync
    /// over-selection is on, in which case each edge fields its
    /// `ceil(K·overselect)` cohort (currently-available members first,
    /// so pace steering shapes who leads the wave).
    fn initial_cohort(&self) -> Vec<usize> {
        let factor = self.eng.cfg.lifecycle.overselect;
        match self.mode {
            SyncMode::SemiSync { quorum, .. } if factor > 0.0 => {
                let mut out = Vec::new();
                for j in 0..self.edges() {
                    out.extend(self.edge_cohort(j, quorum, factor, 0.0));
                }
                out
            }
            _ => (0..self.eng.cfg.topology.devices).collect(),
        }
    }

    /// Edge `j`'s over-selected dispatch cohort at time `t`:
    /// `ceil(K·factor)` of its live members where K is the effective
    /// quorum, preferring members inside their availability window
    /// (`lifecycle::select_dispatch` — deterministic, draw-free).
    fn edge_cohort(
        &self,
        j: usize,
        quorum: usize,
        factor: f64,
        t: f64,
    ) -> Vec<usize> {
        let live: Vec<usize> = self.eng.topo.edges[j]
            .members
            .iter()
            .copied()
            .filter(|&d| self.eng.mobility.is_active(d))
            .collect();
        let k = effective_quorum(quorum, live.len());
        let n = overselect_count(k, factor, live.len());
        select_dispatch(&live, n, self.eng.avail.as_ref(), t)
    }

    /// Advance the armed run to its next cloud-aggregation decision point
    /// and return that window's stats; `None` once the time budget is
    /// exhausted and the tail has been flushed. Event order is identical
    /// to the single-call loop — stepping changes *when the caller gets
    /// control*, never the simulated timeline.
    pub fn run_window(&mut self) -> Result<Option<RoundStats>> {
        let threshold = self.eng.cfg.hfl.threshold_time;
        while let Some(t_next) = self.queue.peek_time() {
            if t_next > threshold {
                break;
            }
            // Wall-clock reads are gated on an attached observer: with
            // none, this path performs no `Instant` syscalls. Either way
            // wall time only flows into observer records, never into the
            // simulated timeline (the observer-on == observer-off bitwise
            // guarantee).
            let t_pop = self
                .eng
                .obs
                .as_ref()
                .map(|_| std::time::Instant::now());
            let (t, ev) = self.queue.pop().expect("peeked event vanished");
            let t_handle = t_pop.map(|_| std::time::Instant::now());
            let variant = event_variant(&ev);
            self.sweep(t);
            let mut window = None;
            match ev {
                Event::DeviceTrainDone { device, edge } => {
                    self.on_train_done(device, edge, t)?;
                }
                Event::EdgeAggregate { edge } => {
                    self.on_edge_aggregate(edge, t)?;
                }
                Event::CloudAggregate => {
                    window = Some(self.on_cloud_aggregate(t)?);
                }
                Event::MobilityFlip => self.on_mobility_flip(t)?,
                Event::Recluster => self.on_recluster(t)?,
                Event::TransferDone { transfer } => {
                    self.on_transfer_done(transfer, t)?;
                }
                Event::EdgeOutage { edge, up } => {
                    self.on_edge_outage(edge, up, t)?;
                }
                Event::Partition { mask, up } => self.on_partition(mask, up),
                Event::CrashStorm { seed, frac_bits, up } => {
                    self.on_crash_storm(seed, frac_bits, up, t)?;
                }
            }
            if let Some(o) = self.eng.obs.as_mut() {
                let lag_ns = t_pop
                    .zip(t_handle)
                    .map(|(p, h)| h.duration_since(p).as_nanos() as u64)
                    .unwrap_or(0);
                let handler_ns = t_handle
                    .map(|h| h.elapsed().as_nanos() as u64)
                    .unwrap_or(0);
                o.on_event_handled(variant, t, lag_ns, handler_ns);
            }
            if let Some(stats) = window {
                return Ok(Some(stats));
            }
        }
        // Flush the tail: training completed after the last timer tick
        // (or a cloud_interval longer than the whole run) would otherwise
        // drop its energy/accuracy from the history entirely. Draining
        // suppresses new dispatches/transfers — they could never finish.
        if self.acc.per_edge.iter().any(|e| e.active > 0) {
            self.draining = true;
            let stats = self.on_cloud_aggregate(threshold)?;
            self.draining = false;
            return Ok(Some(stats));
        }
        Ok(None)
    }

    /// Integrate the per-edge busy intervals up to `t`. Every state change
    /// happens at an event, so the (training, transferring) indicator pair
    /// is constant over the gap since the previous event.
    fn sweep(&mut self, t: f64) {
        let dt = t - self.sweep_t;
        if dt <= 0.0 {
            return;
        }
        for j in 0..self.edges() {
            let c = self.training_count[j] > 0;
            let u = self.eng.links.active_count(j, Direction::Up) > 0;
            let d = self.eng.links.active_count(j, Direction::Down) > 0;
            if c {
                self.win_compute_busy[j] += dt;
            }
            if u {
                self.win_up_busy[j] += dt;
            }
            if d {
                self.win_down_busy[j] += dt;
            }
            if u || d {
                self.win_comm_busy[j] += dt;
            }
            if c && (u || d) {
                self.win_overlap[j] += dt;
            }
        }
        self.sweep_t = t;
    }

    /// Start local training on every listed device that is active and
    /// idle: run the real compute now, schedule the simulated completion.
    fn dispatch(&mut self, devs: &[usize], now: f64) -> Result<()> {
        if self.draining {
            return Ok(());
        }
        let mut jobs = Vec::new();
        for &d in devs {
            // Devices awaiting a migration warm-start idle until their new
            // edge's model lands.
            if !self.eng.mobility.is_active(d)
                || self.in_flight[d].is_some()
                || self.migration_seq[d] != 0
            {
                continue;
            }
            let j = self.dev_edge[d];
            // A downed aggregator has nobody to report to; its members
            // idle until the recovery event re-dispatches them.
            if self.edge_faulted[j] {
                continue;
            }
            jobs.push(TrainJob {
                device: d,
                // The one materialization point: the worker pool needs an
                // owned buffer (Send).
                w: self.eng.store.slice(&self.eng.device_w[d]).to_vec(),
                epochs: self.g1[j],
                seed: self.eng.fork_job_seed(d),
            });
        }
        if jobs.is_empty() {
            return Ok(());
        }
        let results = self.eng.train_batch(jobs)?;
        // Batched simulated time/energy (parallel across sim.workers,
        // bit-identical to per-device serial calls).
        let reqs: Vec<(usize, usize)> = results
            .iter()
            .map(|res| (res.device, res.losses.len()))
            .collect();
        let sims = self.eng.simulate_train_batch(&reqs);
        for (res, &(t_dev, e_dev)) in results.into_iter().zip(&sims) {
            let d = res.device;
            let j = self.dev_edge[d];
            // Adopt the trained result into the store immediately, tagged
            // with the edge version it started from (the staleness base):
            // the in-flight model recycles a pooled buffer and is counted
            // by the memory observables instead of hiding in a raw Vec.
            let version = self.eng.edge_w[j].version();
            let r = self.eng.store.insert(res.w, version);
            self.in_flight[d] = Some(PendingTrain {
                r,
                last_loss: res.losses.last().copied(),
                t: t_dev,
                energy: e_dev,
                void: false,
            });
            self.training_count[j] += 1;
            // Pace steering: a device outside its availability window
            // *defers* its start to the window's edge (never skips —
            // a skipped device could stall its edge forever, since no
            // future event would close the round). The lag is pure
            // arithmetic from the seeded diurnal model, so it is
            // identical at any worker count; with pace steering off the
            // lag is exactly 0.0 and the timeline is unchanged.
            let lag = self
                .eng
                .avail
                .as_ref()
                .map(|a| a.delay_until(d, now))
                .unwrap_or(0.0);
            self.queue.schedule(
                now + lag + t_dev,
                Event::DeviceTrainDone { device: d, edge: j },
            );
            if let Some(o) = self.eng.obs.as_mut() {
                // Training burst on the edge's trace track; both span
                // endpoints are simulated times, so the trace is
                // deterministic under a fixed seed.
                o.on_span(crate::obs::Span {
                    track: format!("edge/{j}"),
                    name: format!("train d{d}"),
                    t0_sim: now,
                    t1_sim: now + lag + t_dev,
                    wall_ns: 0,
                });
            }
        }
        Ok(())
    }

    fn on_train_done(
        &mut self,
        device: usize,
        edge: usize,
        t: f64,
    ) -> Result<()> {
        let Some(p) = self.in_flight[device].take() else {
            return Ok(());
        };
        self.training_count[edge] =
            self.training_count[edge].saturating_sub(1);
        // Energy was spent even if the device has since left.
        self.acc.record_train(edge, device, p.t, p.energy, p.last_loss);
        if p.void {
            // Flipped mid-flight: the pre-departure result is stale even
            // if the device rejoined. It restarts from the model the
            // rejoin handed it (no-op if it is still departed).
            self.eng.store.release(p.r);
            return self.dispatch(&[device], t);
        }
        if !self.eng.mobility.is_active(device) {
            self.eng.store.release(p.r);
            return Ok(()); // departed mid-flight: result discarded
        }
        // The device line takes over the in-flight handle (already
        // version-tagged with its staleness base at dispatch).
        self.eng.store.adopt(&mut self.eng.device_w[device], p.r);
        self.reported[edge].push(device);
        match self.mode {
            SyncMode::SemiSync { quorum, .. } => {
                if quorum_satisfied(
                    self.reported[edge].len(),
                    quorum,
                    self.live_members(edge),
                ) {
                    self.queue
                        .schedule(t, Event::EdgeAggregate { edge });
                }
            }
            SyncMode::Async { .. } => {
                self.queue.schedule(t, Event::EdgeAggregate { edge });
            }
            SyncMode::Synchronous => {
                unreachable!("sync mode does not use the free-running loop")
            }
        }
        Ok(())
    }

    /// Currently active members of `edge`.
    fn live_members(&self, edge: usize) -> usize {
        self.eng.topo.edges[edge]
            .members
            .iter()
            .filter(|&&d| self.eng.mobility.is_active(d))
            .count()
    }

    fn on_edge_aggregate(&mut self, edge: usize, t: f64) -> Result<()> {
        let devs = std::mem::take(&mut self.reported[edge]);
        if devs.is_empty() {
            return Ok(()); // already flushed (duplicate trigger)
        }
        // Over-selection's first-K close: the quorum landed, so every
        // cohort member still in flight is abandoned through the
        // stale-result void path — its completion discards the result
        // (energy already spent) and re-enters dispatch selection.
        if matches!(self.mode, SyncMode::SemiSync { .. })
            && self.eng.cfg.lifecycle.overselect > 0.0
        {
            self.abandon_stragglers(edge);
        }
        match self.mode {
            SyncMode::SemiSync { .. } => {
                // Quorum closes like a small synchronous edge round (the
                // edge version advances inside).
                self.eng.edge_aggregate_devices(edge, &devs)?;
            }
            SyncMode::Async { .. } => {
                let edge_data = self.eng.edge_data_weight(edge);
                // Per-edge α_j: default sync.staleness_alpha, possibly
                // re-armed by the learned controller (`set_control`).
                let alpha_j = self.alpha[edge];
                for &d in &devs {
                    // Staleness = version delta between the live edge
                    // handle and the version the device trained from.
                    let s = self.eng.edge_w[edge].version()
                        - self.eng.device_w[d].version();
                    let share = self.eng.topo.shards[d].n as f32 / edge_data;
                    let beta = share * staleness_discount(s, alpha_j);
                    self.eng.mix_device_into_edge(edge, d, beta);
                }
                self.eng.edge_w[edge].bump_version();
                for &d in &devs {
                    // O(1) re-point: reporting devices pick up the fresh
                    // edge model by reference (was: one clone each).
                    self.eng.store.repoint(
                        &mut self.eng.device_w[d],
                        &self.eng.edge_w[edge],
                    );
                }
            }
            SyncMode::Synchronous => unreachable!(),
        }
        self.window_edge_aggs[edge] += 1;
        // The fresh edge model goes up as an in-flight transfer while the
        // reporting devices restart training — the overlap the lump model
        // could never express.
        self.start_upload(edge, t);
        // Over-selection fields a fresh ceil(K·factor) cohort for the
        // next edge round (abandoned stragglers are still busy and are
        // filtered by dispatch; they re-enter selection once their void
        // completion lands). Off, the reporters restart — the
        // historical path, byte for byte.
        let next = match self.mode {
            SyncMode::SemiSync { quorum, .. }
                if self.eng.cfg.lifecycle.overselect > 0.0 =>
            {
                self.edge_cohort(
                    edge,
                    quorum,
                    self.eng.cfg.lifecycle.overselect,
                    t,
                )
            }
            _ => devs,
        };
        self.dispatch(&next, t)
    }

    /// Void every in-flight training run of `edge`'s members and count
    /// the newly-abandoned ones into the window's lifecycle observables
    /// (first-K close and edge-outage both route through here).
    fn abandon_stragglers(&mut self, edge: usize) {
        let mut dropped = 0usize;
        for idx in 0..self.eng.topo.edges[edge].members.len() {
            let d = self.eng.topo.edges[edge].members[idx];
            if let Some(p) = self.in_flight[d].as_mut() {
                if !p.void {
                    p.void = true;
                    dropped += 1;
                }
            }
        }
        self.win_abandoned[edge] += dropped;
    }

    /// Snapshot `edge`'s model (an rc-share — CoW keeps it intact while
    /// in flight) and put it on the uplink at time `t`.
    fn start_upload(&mut self, edge: usize, t: f64) {
        if self.draining {
            return;
        }
        // A downed or partitioned edge cannot reach the cloud: the
        // upload is dropped (the cloud aggregates without this edge,
        // and its staleness observable grows until the heal).
        if self.edge_faulted[edge] || self.edge_partitioned[edge] {
            return;
        }
        let region = self.eng.topo.edges[edge].region;
        let work = self.eng.sample_one_way(region, Direction::Up);
        let bytes = crate::sim::network::model_bytes(self.eng.p);
        let (id, resched) =
            self.eng.links.start(edge, Direction::Up, bytes, work, t);
        let r = self.eng.store.share(&self.eng.edge_w[edge]);
        self.payloads.insert(id, Payload::Upload { edge, r });
        for (tid, finish) in resched {
            self.queue
                .schedule(finish, Event::TransferDone { transfer: tid });
        }
    }

    /// Put the cloud model on `edge`'s downlink at time `t`: one shared
    /// buffer serves every edge's transfer, and the handle's version (the
    /// broadcasting cloud window) is the out-of-order landing guard.
    fn start_downlink(&mut self, edge: usize, t: f64) {
        if self.draining {
            return;
        }
        // No broadcast reaches a downed or partitioned edge; it keeps
        // its older global model until a post-heal window's downlink.
        if self.edge_faulted[edge] || self.edge_partitioned[edge] {
            return;
        }
        let region = self.eng.topo.edges[edge].region;
        let work = self.eng.sample_one_way(region, Direction::Down);
        let bytes = crate::sim::network::model_bytes(self.eng.p);
        let (id, resched) =
            self.eng.links.start(edge, Direction::Down, bytes, work, t);
        let r = self.eng.store.share(&self.eng.cloud_w);
        self.payloads.insert(id, Payload::Downlink { edge, r });
        for (tid, finish) in resched {
            self.queue
                .schedule(finish, Event::TransferDone { transfer: tid });
        }
    }

    /// A `TransferDone` popped: stale predictions are dropped; a live one
    /// lands its payload (upload → cloud's view, downlink → edge model).
    fn on_transfer_done(&mut self, id: usize, t: f64) -> Result<()> {
        let Some((tr, resched)) = self.eng.links.poll(id, t) else {
            return Ok(()); // superseded prediction
        };
        // Remaining sharers speed up; chase their new predictions.
        for (tid, finish) in resched {
            self.queue
                .schedule(finish, Event::TransferDone { transfer: tid });
        }
        let payload = self
            .payloads
            .remove(&tr.id)
            .expect("live transfer without payload");
        self.transfer_log.push((tr.id, tr.edge, t));
        if let Some(o) = self.eng.obs.as_mut() {
            o.on_transfer(
                tr.edge,
                tr.dir.name(),
                tr.bytes as f64,
                tr.start,
                tr.finish,
            );
        }
        match payload {
            Payload::Upload { edge, r } => {
                self.obs_up[edge] = tr.finish - tr.start;
                self.window_landings[edge] += 1;
                self.edge_last_update_round[edge] =
                    self.eng.cloud_w.version();
                // Latest *version* wins at the cloud: contention can land
                // an older snapshot after a newer one. The guard is the
                // version delta between the payload and landed handles.
                if r.version() > self.landed_w[edge].version() {
                    self.eng.store.adopt(&mut self.landed_w[edge], r);
                } else {
                    self.eng.store.release(r);
                }
            }
            Payload::Downlink { edge, r } => {
                self.obs_down[edge] = tr.finish - tr.start;
                // The edge adopts the global model only now that the
                // broadcast landed; devices pick it up at their next edge
                // aggregation. Contention can land broadcasts out of
                // order — never revert to an older window's model. The
                // edge keeps its own version tag: adopting a broadcast
                // is not an edge aggregation.
                if r.version() > self.adopted_cloud_round[edge] {
                    self.adopted_cloud_round[edge] = r.version();
                    self.eng.store.adopt_keep_version(
                        &mut self.eng.edge_w[edge],
                        r,
                    );
                } else {
                    self.eng.store.release(r);
                }
            }
            Payload::Migration { edge, r, devices, seq } => {
                self.obs_down[edge] = tr.finish - tr.start;
                let mut resume = Vec::new();
                for d in devices {
                    // A later re-clustering or a leave(+rejoin) supersedes
                    // this warm-start for the device.
                    if self.migration_seq[d] != seq {
                        continue;
                    }
                    debug_assert_eq!(
                        self.dev_edge[d], edge,
                        "pending warm-start on the wrong edge"
                    );
                    self.migration_seq[d] = 0;
                    // Warm start by reference: every migrant shares the
                    // delivered snapshot (O(1) per device).
                    self.eng.store.repoint(&mut self.eng.device_w[d], &r);
                    self.migration_log.push((seq, d, edge));
                    resume.push(d);
                }
                self.eng.store.release(r);
                // Migrants resume training from the delivered model
                // (dispatch skips any that have since departed).
                self.dispatch(&resume, t)?;
            }
        }
        Ok(())
    }

    fn on_cloud_aggregate(&mut self, t: f64) -> Result<RoundStats> {
        self.sweep(t); // a tail flush arrives outside the event loop
        let m = self.edges();
        // Control observables at the decision point, captured before the
        // quorum flush perturbs them: staleness of each edge's last
        // landed upload (in windows), uploads still in flight, and the
        // semi-sync quorum fill of the outstanding reports. These become
        // the `EdgeStats` rows the extended DRL state reads.
        let ctrl: Vec<(f64, usize, f64)> = (0..m)
            .map(|j| {
                // Staleness in windows: version delta between the cloud
                // handle and the window the edge's last upload landed in.
                let staleness = (self.eng.cloud_w.version()
                    - self.edge_last_update_round[j])
                    as f64;
                let in_flight = self.eng.links.active_count(j, Direction::Up);
                let fill = match self.mode {
                    SyncMode::SemiSync { quorum, .. } => {
                        self.reported[j].len() as f64
                            / effective_quorum(quorum, self.live_members(j))
                                as f64
                    }
                    _ => 0.0,
                };
                (staleness, in_flight, fill)
            })
            .collect();
        // Flush partial quorums so no edge (or idle-waiting device) can
        // starve across windows; their uploads start now and land later.
        for j in 0..m {
            if !self.reported[j].is_empty() {
                self.on_edge_aggregate(j, t)?;
            }
        }
        // The cloud aggregates what has LANDED by its timer — not the
        // live edge models, which may still be in flight. The landed
        // views resolve to slices at the aggregation boundary; committing
        // advances the cloud version by one (an empty semi-sync window
        // bumps the version without a new model — the window counts).
        let contributors: Vec<usize> = match self.mode {
            SyncMode::Async { .. } => (0..m).collect(),
            SyncMode::SemiSync { .. } => (0..m)
                .filter(|&j| self.window_landings[j] > 0)
                .collect(),
            SyncMode::Synchronous => unreachable!(),
        };
        // Async: landed models are discounted by how many windows ago
        // they landed (pure echoes decay fastest) under the edge's
        // current α_j.
        let factors: Option<Vec<f32>> = match self.mode {
            SyncMode::Async { .. } => Some(
                contributors
                    .iter()
                    .map(|&j| {
                        staleness_discount(
                            self.eng.cloud_w.version()
                                - self.edge_last_update_round[j],
                            self.alpha[j],
                        )
                    })
                    .collect(),
            ),
            _ => None,
        };
        if contributors.is_empty() {
            self.eng.bump_cloud_version();
        } else {
            let weights =
                self.eng.cloud_weights(&contributors, factors.as_deref());
            let agg = {
                let models: Vec<&[f32]> = contributors
                    .iter()
                    .map(|&j| self.eng.store.slice(&self.landed_w[j]))
                    .collect();
                self.eng.aggregate(&models, &weights)?
            };
            self.eng.commit_cloud(agg);
        }
        // Broadcast as in-flight downlink transfers (was: instantaneous
        // broadcast_cloud); each edge adopts the model when it lands.
        // One shared buffer (rc-shared, not cloned) serves all m
        // downlinks, tagged with the new cloud version.
        for j in 0..m {
            self.start_downlink(j, t);
        }

        // Close the window's stats from observed transfers + busy sweep.
        for j in 0..m {
            self.acc.record_window(
                j,
                self.obs_up[j],
                self.obs_down[j],
                self.win_compute_busy[j],
                self.win_up_busy[j],
                self.win_down_busy[j],
                self.win_comm_busy[j],
                self.win_overlap[j],
            );
            let (staleness, in_flight, fill) = ctrl[j];
            self.acc.record_ctrl(j, staleness, in_flight, fill);
            // Lifecycle observables at the decision point: stragglers
            // abandoned this window (first-K close + fault voids) and
            // the edge's membership availability right now. Recorded
            // unconditionally — lifecycle-off yields the (0, 1.0)
            // baseline — so schema-v2 rows are uniform across runs.
            let dropped = std::mem::take(&mut self.win_abandoned[j]);
            let avail_j = self.eng.edge_availability(j, t);
            self.acc.record_lifecycle(j, dropped, avail_j);
        }
        self.window_landings = vec![0; m];
        self.win_compute_busy = vec![0.0; m];
        self.win_up_busy = vec![0.0; m];
        self.win_down_busy = vec![0.0; m];
        self.win_comm_busy = vec![0.0; m];
        self.win_overlap = vec![0.0; m];

        let round_time = t - self.window_start;
        self.eng.clock.advance(round_time);
        self.eng.round += 1;
        self.eng.total_energy += self.acc.round_energy;
        let (accuracy, test_loss) = self.eng.evaluate()?;
        let g2_observed = std::mem::replace(
            &mut self.window_edge_aggs,
            vec![0; m],
        );
        let acc = std::mem::replace(&mut self.acc, RoundAccumulator::new(m));
        let mut stats = acc.finish(
            self.eng.round,
            accuracy,
            test_loss,
            round_time,
            self.eng.clock.now(),
            &self.g1,
            &g2_observed,
        );
        self.eng.finalize_membership_stats(&mut stats);
        self.eng.finalize_memory_stats(&mut stats);
        stats.fault_events = std::mem::take(&mut self.win_fault_events);
        self.eng.emit_round_observation(&stats);
        self.eng.last_round = Some(stats.clone());
        self.window_start = t;
        if !self.draining {
            self.queue.schedule(
                t + self.mode.cloud_interval(),
                Event::CloudAggregate,
            );
        }
        Ok(stats)
    }

    fn on_mobility_flip(&mut self, t: f64) -> Result<()> {
        let flips = self.eng.mobility.step();
        self.eng.membership.observe(flips);
        // The model reports who flipped — no full active-vector re-scan.
        let flipped: Vec<usize> = self.eng.mobility.flipped().to_vec();
        // A flipped device's pending report is void either way: a leaver
        // took its update with it, and a rejoiner restarts from the edge
        // model — without this purge a report-leave-rejoin sequence would
        // enter reported[] twice and double-weight the device.
        for &d in &flipped {
            self.reported[self.dev_edge[d]].retain(|&x| x != d);
            // A run already in flight trained against a pre-departure
            // model: void it so a leave(+rejoin) can never land a stale
            // update at full weight.
            if let Some(p) = self.in_flight[d].as_mut() {
                p.void = true;
            }
            // Any pending migration warm-start is moot either way: a
            // leaver is re-parked by later re-clusterings (its delivery
            // must not apply), and a rejoiner takes the current edge
            // model below. Without this clear, a departed migrant kept
            // its seq and a late landing could warm-start it onto the
            // wrong edge.
            self.migration_seq[d] = 0;
        }
        // Quorum liveness: a departure can shrink an edge's live set to
        // (or below) the reports already outstanding; without this
        // re-check the edge round could only close at the next timer
        // flush, because no further DeviceTrainDone will fire for it.
        self.recheck_quorums(
            flipped.iter().map(|&d| self.dev_edge[d]).collect(),
            t,
        );
        let rejoined: Vec<usize> = flipped
            .iter()
            .copied()
            .filter(|&d| self.eng.mobility.is_active(d))
            .collect();
        // Rejoining devices start from their edge's current model (at
        // least as fresh as any migration snapshot; the pending-warm-start
        // flag was cleared in the purge loop above). O(1) re-points.
        for &d in &rejoined {
            let j = self.dev_edge[d];
            self.eng.store.repoint(
                &mut self.eng.device_w[d],
                &self.eng.edge_w[j],
            );
        }
        self.dispatch(&rejoined, t)?;
        // Membership drift check: re-cluster as a scheduled event when the
        // churn pushed drift past the threshold (O(1) gate before the
        // O(n) imbalance scan).
        if self.eng.membership.wants_check(t)
            && self.eng.membership.should_recluster(
                t,
                self.eng.cfg.topology.devices,
                self.eng.membership_imbalance(),
            )
        {
            self.queue.schedule(t, Event::Recluster);
        }
        self.queue
            .schedule(t + self.mode.cloud_interval(), Event::MobilityFlip);
        Ok(())
    }

    /// Execute a churn-driven re-clustering live: re-profile + re-cluster
    /// the active population (`HflEngine::recluster_core`), then migrate
    /// the running topology — void in-flight work of migrated devices,
    /// purge their pending reports, re-derive semi-sync quorums, and ship
    /// each destination edge's model to its migrants as an in-flight
    /// downlink transfer.
    fn on_recluster(&mut self, t: f64) -> Result<()> {
        let n = self.eng.cfg.topology.devices;
        // Re-check: the drift that scheduled this event may have been
        // handled already (duplicate trigger), or may no longer qualify.
        if !self.eng.membership.wants_check(t)
            || !self.eng.membership.should_recluster(
                t,
                n,
                self.eng.membership_imbalance(),
            )
        {
            return Ok(());
        }
        let t_wall = self
            .eng
            .obs
            .as_ref()
            .map(|_| std::time::Instant::now());
        let Some(out) = self.eng.recluster_core(t)? else {
            return Ok(()); // infeasible region split; retried on later flips
        };
        self.refresh_dev_edge();
        self.recluster_seq += 1;
        let seq = self.recluster_seq;
        let mut by_dest: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &(d, old, new) in &out.migrated {
            // Stale-result protocol (as for leavers): the device's pending
            // report and in-flight training were computed against its old
            // edge's model — void them.
            self.reported[old].retain(|&x| x != d);
            if let Some(p) = self.in_flight[d].as_mut() {
                p.void = true;
            }
            self.migration_seq[d] = seq;
            by_dest.entry(new).or_default().push(d);
        }
        // Warm-start delivery: one downlink per destination edge, carrying
        // its model snapshot for all its migrants. The snapshot is an
        // rc-share — copy-on-write preserves it if the edge aggregates
        // while the downlink is in flight.
        for (edge, devices) in by_dest {
            let r = self.eng.store.share(&self.eng.edge_w[edge]);
            self.start_migration_downlink(edge, r, devices, seq, t);
        }
        // Re-derive semi-sync quorums against the new membership: an edge
        // that lost members may now satisfy its (live-clamped) quorum
        // with the reports it already holds.
        self.recheck_quorums(
            out.migrated
                .iter()
                .flat_map(|&(_, old, new)| [old, new])
                .collect(),
            t,
        );
        if let Some(o) = self.eng.obs.as_mut() {
            let wall_ns = t_wall
                .map(|i| i.elapsed().as_nanos() as u64)
                .unwrap_or(0);
            o.on_recluster(t, out.migrated.len(), wall_ns);
        }
        self.eng.last_recluster = Some(out);
        Ok(())
    }

    /// Semi-sync only: re-check the K-quorum of the listed edges against
    /// their current live membership and close any edge round that the
    /// outstanding reports now satisfy (shared by the churn and
    /// re-clustering paths — both shrink live sets out from under
    /// pending reports).
    fn recheck_quorums(&mut self, mut hit: Vec<usize>, t: f64) {
        let SyncMode::SemiSync { quorum, .. } = self.mode else {
            return;
        };
        hit.sort_unstable();
        hit.dedup();
        for j in hit {
            if !self.reported[j].is_empty()
                && quorum_satisfied(
                    self.reported[j].len(),
                    quorum,
                    self.live_members(j),
                )
            {
                self.queue.schedule(t, Event::EdgeAggregate { edge: j });
            }
        }
    }

    /// `Event::EdgeOutage`: sever (down) or restore (up) one edge
    /// aggregator. Down, the edge's pending reports die with it and all
    /// in-flight member work is voided (stale-result protocol — the
    /// edge model those runs trained against is lost); members idle
    /// until recovery. Up, live idle members warm-restart from the
    /// edge's current model, exactly like a churn rejoin.
    fn on_edge_outage(
        &mut self,
        edge: usize,
        up: bool,
        t: f64,
    ) -> Result<()> {
        self.win_fault_events += 1;
        if !up {
            if !self.edge_faulted[edge] {
                self.edge_faulted[edge] = true;
                self.reported[edge].clear();
                self.abandon_stragglers(edge);
                if let Some(o) = self.eng.obs.as_mut() {
                    o.on_fault("outage");
                }
            }
            return Ok(());
        }
        if !self.edge_faulted[edge] {
            return Ok(()); // overlapping plans: already recovered
        }
        self.edge_faulted[edge] = false;
        if let Some(o) = self.eng.obs.as_mut() {
            o.on_fault("recovery");
        }
        let mut idle = Vec::new();
        for idx in 0..self.eng.topo.edges[edge].members.len() {
            let d = self.eng.topo.edges[edge].members[idx];
            if self.eng.mobility.is_active(d) && self.in_flight[d].is_none()
            {
                // O(1) re-point: the pre-outage device line is stale.
                self.eng.store.repoint(
                    &mut self.eng.device_w[d],
                    &self.eng.edge_w[edge],
                );
                idle.push(d);
            }
        }
        let resume = match self.mode {
            SyncMode::SemiSync { quorum, .. }
                if self.eng.cfg.lifecycle.overselect > 0.0 =>
            {
                self.edge_cohort(
                    edge,
                    quorum,
                    self.eng.cfg.lifecycle.overselect,
                    t,
                )
            }
            _ => idle,
        };
        self.dispatch(&resume, t)
    }

    /// `Event::Partition`: sever (down) or heal (up) the cloud links of
    /// every edge whose bit is set in `mask` (edge `j` maps to bit
    /// `j % 64`). Partitioned edges keep training and aggregating
    /// locally — only their uplink/downlink transfers are dropped, so
    /// the cloud ages them (staleness grows) until the heal.
    fn on_partition(&mut self, mask: u64, up: bool) {
        self.win_fault_events += 1;
        let mut touched = false;
        for j in 0..self.edges() {
            if (mask >> (j % 64)) & 1 == 0 {
                continue;
            }
            touched = touched || self.edge_partitioned[j] == up;
            self.edge_partitioned[j] = !up;
        }
        if touched {
            if let Some(o) = self.eng.obs.as_mut() {
                o.on_fault(if up { "recovery" } else { "partition" });
            }
        }
    }

    /// `Event::CrashStorm`: crash the storm's device set, or revive it
    /// `fault.rejoin_delay` later. Membership is the pure predicate
    /// `lifecycle::storm_hits(seed, device, frac_bits)` — no draws, so
    /// the crash and rejoin events recompute exactly the same set and
    /// the storm is identical at any worker count. Crashing routes
    /// through the churn machinery: reports purged, in-flight work
    /// voided, pending warm-starts cleared, quorum liveness re-checked.
    fn on_crash_storm(
        &mut self,
        storm: u64,
        frac_bits: u32,
        up: bool,
        t: f64,
    ) -> Result<()> {
        self.win_fault_events += 1;
        let n = self.eng.cfg.topology.devices;
        if !up {
            let mut hit_edges = Vec::new();
            let mut crashed = false;
            for d in 0..n {
                if !storm_hits(storm, d, frac_bits)
                    || !self.eng.mobility.is_active(d)
                {
                    continue;
                }
                self.eng.mobility.set_active(d, false);
                crashed = true;
                let j = self.dev_edge[d];
                self.reported[j].retain(|&x| x != d);
                if let Some(p) = self.in_flight[d].as_mut() {
                    if !p.void {
                        p.void = true;
                        self.win_abandoned[j] += 1;
                    }
                }
                self.migration_seq[d] = 0;
                hit_edges.push(j);
            }
            if crashed {
                if let Some(o) = self.eng.obs.as_mut() {
                    o.on_fault("crash");
                }
            }
            // A storm can shrink an edge's live set to (or below) its
            // outstanding reports — same liveness re-check as churn.
            self.recheck_quorums(hit_edges, t);
            return Ok(());
        }
        let mut revived = Vec::new();
        for d in 0..n {
            if storm_hits(storm, d, frac_bits)
                && !self.eng.mobility.is_active(d)
            {
                self.eng.mobility.set_active(d, true);
                let j = self.dev_edge[d];
                self.eng.store.repoint(
                    &mut self.eng.device_w[d],
                    &self.eng.edge_w[j],
                );
                revived.push(d);
            }
        }
        if !revived.is_empty() {
            if let Some(o) = self.eng.obs.as_mut() {
                o.on_fault("recovery");
            }
        }
        self.dispatch(&revived, t)
    }

    /// Put `edge`'s warm-start snapshot on its downlink for its migrants.
    fn start_migration_downlink(
        &mut self,
        edge: usize,
        r: ModelRef,
        devices: Vec<usize>,
        seq: u64,
        t: f64,
    ) {
        if self.draining {
            self.eng.store.release(r);
            return;
        }
        let region = self.eng.topo.edges[edge].region;
        let work = self.eng.sample_one_way(region, Direction::Down);
        let bytes = crate::sim::network::model_bytes(self.eng.p);
        let (id, resched) =
            self.eng.links.start(edge, Direction::Down, bytes, work, t);
        self.payloads
            .insert(id, Payload::Migration { edge, r, devices, seq });
        for (tid, finish) in resched {
            self.queue
                .schedule(finish, Event::TransferDone { transfer: tid });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SyncConfig;

    #[test]
    fn mode_from_config() {
        assert_eq!(
            SyncMode::from_config(&SyncConfig::default()),
            SyncMode::Synchronous
        );
        let sc = SyncConfig {
            mode: SyncModeCfg::SemiSync,
            quorum: 3,
            staleness_alpha: 0.7,
            cloud_interval: 90.0,
            ..SyncConfig::default()
        };
        assert_eq!(
            SyncMode::from_config(&sc),
            SyncMode::SemiSync {
                quorum: 3,
                cloud_interval: 90.0
            }
        );
        let sc = SyncConfig {
            mode: SyncModeCfg::Async,
            ..sc
        };
        match SyncMode::from_config(&sc) {
            SyncMode::Async {
                staleness_alpha,
                cloud_interval,
            } => {
                assert!((staleness_alpha - 0.7).abs() < 1e-12);
                assert!((cloud_interval - 90.0).abs() < 1e-12);
            }
            other => panic!("wrong mode {other:?}"),
        }
    }

    #[test]
    fn mode_names() {
        assert_eq!(SyncMode::Synchronous.name(), "synchronous");
        assert_eq!(
            SyncMode::SemiSync {
                quorum: 2,
                cloud_interval: 1.0
            }
            .name(),
            "semi-sync"
        );
        assert_eq!(
            SyncMode::Async {
                staleness_alpha: 0.5,
                cloud_interval: 1.0
            }
            .name(),
            "async"
        );
    }

    #[test]
    fn effective_quorum_clamps() {
        assert_eq!(effective_quorum(3, 5), 3);
        assert_eq!(effective_quorum(3, 2), 2);
        assert_eq!(effective_quorum(0, 4), 4);
        assert_eq!(effective_quorum(0, 0), 1);
        assert_eq!(effective_quorum(3, 0), 1);
    }

    #[test]
    fn quorum_clamps_to_live_membership() {
        // Plain quorum against a healthy edge.
        assert!(!quorum_satisfied(2, 3, 5));
        assert!(quorum_satisfied(3, 3, 5));
        // quorum 0 = "all live members".
        assert!(!quorum_satisfied(3, 0, 4));
        assert!(quorum_satisfied(4, 0, 4));
        // The liveness regression: membership shrank below the configured
        // quorum while 2 reports were outstanding — the round must be
        // closable with what is still alive.
        assert!(quorum_satisfied(2, 3, 2));
        assert!(quorum_satisfied(1, 3, 1));
        // Even a fully-departed edge (live = 0 clamps to 1) closes on one
        // outstanding report rather than deadlocking.
        assert!(quorum_satisfied(1, 3, 0));
        assert!(!quorum_satisfied(0, 3, 0));
    }
}
