//! Event-driven HFL engine: one executor, three synchronization modes.
//!
//! Where [`HflEngine::run_round`] can only express lock-step rounds (every
//! edge advances through barrier-synchronized sub-rounds), this engine is
//! driven by the deterministic discrete-event queue of [`crate::sim::event`]
//! and supports the synchronization families the paper's scheme decides
//! *between*:
//!
//! * **`SyncMode::Synchronous`** — the classic HFL schedule, re-expressed
//!   as events: every device's `DeviceTrainDone` is scheduled, each edge's
//!   `EdgeAggregate` fires when its last member reports, `CloudAggregate`
//!   fires on the straggler path. Reproduces `HflEngine::run_round`
//!   **bit-for-bit** under the same seed (same RNG streams consumed in the
//!   same order; equality is enforced by an integration test), proving the
//!   event core models the barrier semantics exactly.
//! * **`SyncMode::SemiSync`** — K-quorum edge aggregation: an edge
//!   aggregates as soon as `quorum` of its members have reported (reported
//!   devices idle until the quorum closes, then restart from the new edge
//!   model), while the cloud aggregates on a fixed timer. Stragglers can
//!   no longer stall their whole edge.
//! * **`SyncMode::Async`** — fully asynchronous, staleness-discounted
//!   aggregation after arXiv:2107.11415 / FedAsync: every device report
//!   immediately blends into the edge model with weight
//!   `data_share · 1/(1+s)^α` where `s` counts edge-model versions the
//!   update is stale by; the cloud timer aggregates edge models weighted by
//!   data size and per-edge freshness. Devices never wait; communication
//!   fully overlaps computation.
//!
//! In the timer-driven modes one `RoundStats` is emitted per cloud
//! aggregation window: `round_time` is the window length, `gamma2` reports
//! the *observed* per-edge aggregation counts of the window, and
//! `EdgeStats::total_time` covers only the edge→cloud path (edges never
//! block on a barrier). Everything stays deterministic from the experiment
//! seed: real training goes through the same seeded worker-pool jobs, and
//! simultaneous events are ordered by the queue's seeded tie-break.

use anyhow::{bail, Result};

use crate::config::{ExperimentConfig, SyncConfig, SyncModeCfg};
use crate::runtime::pool::TrainJob;
use crate::sim::{Event, EventQueue};

use super::aggregate::staleness_discount;
use super::engine::HflEngine;
use super::metrics::{RoundAccumulator, RoundStats, RunHistory};

/// Synchronization policy the event loop executes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SyncMode {
    Synchronous,
    SemiSync {
        /// Device reports that close an edge round (0 = all active members).
        quorum: usize,
        /// Cloud aggregation period, simulated seconds.
        cloud_interval: f64,
    },
    Async {
        /// Staleness discount exponent α of `1/(1+s)^α`.
        staleness_alpha: f64,
        cloud_interval: f64,
    },
}

impl SyncMode {
    pub fn from_config(sync: &SyncConfig) -> Self {
        match sync.mode {
            SyncModeCfg::Synchronous => SyncMode::Synchronous,
            SyncModeCfg::SemiSync => SyncMode::SemiSync {
                quorum: sync.quorum,
                cloud_interval: sync.cloud_interval,
            },
            SyncModeCfg::Async => SyncMode::Async {
                staleness_alpha: sync.staleness_alpha,
                cloud_interval: sync.cloud_interval,
            },
        }
    }

    fn cloud_interval(&self) -> f64 {
        match self {
            SyncMode::Synchronous => f64::INFINITY,
            SyncMode::SemiSync { cloud_interval, .. }
            | SyncMode::Async { cloud_interval, .. } => *cloud_interval,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SyncMode::Synchronous => "synchronous",
            SyncMode::SemiSync { .. } => "semi-sync",
            SyncMode::Async { .. } => "async",
        }
    }
}

/// A dispatched-but-not-yet-completed local training run. The real compute
/// happens eagerly at dispatch (results depend only on weights + seed, not
/// on simulated time); the simulated completion is the queued event.
struct PendingTrain {
    w: Vec<f32>,
    last_loss: Option<f64>,
    t: f64,
    energy: f64,
}

pub struct AsyncHflEngine {
    pub eng: HflEngine,
    pub mode: SyncMode,
    queue: EventQueue,
    /// Per-edge local epochs for dispatched jobs.
    g1: Vec<usize>,
    /// device -> owning edge.
    dev_edge: Vec<usize>,
    in_flight: Vec<Option<PendingTrain>>,
    /// Per-edge devices reported since the edge last aggregated.
    reported: Vec<Vec<usize>>,
    /// Per-edge model version (bumped per edge aggregation).
    edge_version: Vec<u64>,
    /// Edge version a device's current training started from.
    device_version: Vec<u64>,
    /// Cloud aggregation windows completed.
    cloud_round_idx: u64,
    /// Window index of each edge's last aggregation (cloud freshness).
    edge_last_update_round: Vec<u64>,
    /// Edge aggregations inside the current cloud window.
    window_edge_aggs: Vec<usize>,
    acc: RoundAccumulator,
    window_start: f64,
}

impl AsyncHflEngine {
    pub fn new(cfg: ExperimentConfig, use_profiling: bool) -> Result<Self> {
        let mode = SyncMode::from_config(&cfg.sync);
        let seed = cfg.seed;
        let eng = HflEngine::new(cfg, use_profiling)?;
        let n = eng.cfg.topology.devices;
        let m = eng.cfg.topology.edges;
        let mut dev_edge = vec![0usize; n];
        for (j, edge) in eng.topo.edges.iter().enumerate() {
            for &d in &edge.members {
                dev_edge[d] = j;
            }
        }
        let g1 = vec![eng.cfg.hfl.gamma1; m];
        Ok(AsyncHflEngine {
            queue: EventQueue::new(seed ^ 0xa57c),
            g1,
            dev_edge,
            in_flight: (0..n).map(|_| None).collect(),
            reported: vec![Vec::new(); m],
            edge_version: vec![0; m],
            device_version: vec![0; n],
            cloud_round_idx: 0,
            edge_last_update_round: vec![0; m],
            window_edge_aggs: vec![0; m],
            acc: RoundAccumulator::new(m),
            window_start: 0.0,
            mode,
            eng,
        })
    }

    pub fn edges(&self) -> usize {
        self.eng.edges()
    }

    /// Run the configured mode to the time threshold with uniform default
    /// frequencies.
    pub fn run_to_threshold(&mut self) -> Result<RunHistory> {
        let g1 = vec![self.eng.cfg.hfl.gamma1; self.edges()];
        self.run_with(&g1)
    }

    /// Run the configured mode to the time threshold under per-edge local
    /// epochs `g1` (gamma2 only applies in `Synchronous`, from the config).
    pub fn run_with(&mut self, g1: &[usize]) -> Result<RunHistory> {
        anyhow::ensure!(
            g1.len() == self.edges(),
            "need {} per-edge frequencies",
            self.edges()
        );
        match self.mode {
            SyncMode::Synchronous => {
                self.eng.reset();
                let g2 = vec![self.eng.cfg.hfl.gamma2; self.edges()];
                let mut hist = RunHistory::default();
                while self.eng.remaining_time() > 0.0 {
                    hist.push(self.run_round(g1, &g2, None)?);
                }
                Ok(hist)
            }
            _ => self.run_event_loop(g1),
        }
    }

    // -----------------------------------------------------------------
    // Synchronous mode: one barriered cloud round, event-driven.
    // -----------------------------------------------------------------

    /// Execute one synchronous cloud round through the event queue.
    /// Equivalent to `HflEngine::run_round` bit-for-bit under the same
    /// seed: the same RNG streams are consumed in the same order, and the
    /// event timeline reproduces the barrier arithmetic exactly (an edge's
    /// aggregate fires at its slowest member's completion; the cloud at
    /// the straggler edge's path).
    pub fn run_round(
        &mut self,
        gamma1: &[usize],
        gamma2: &[usize],
        participation: Option<&[bool]>,
    ) -> Result<RoundStats> {
        if !matches!(self.mode, SyncMode::Synchronous) {
            bail!(
                "run_round is the synchronous entry point; {} mode runs \
                 through run_with/run_to_threshold",
                self.mode.name()
            );
        }
        let m = self.edges();
        anyhow::ensure!(
            gamma1.len() == m && gamma2.len() == m,
            "need {m} per-edge frequencies"
        );
        let mut acc = RoundAccumulator::new(m);
        let mut edge_clock = vec![0.0f64; m];
        let max_gamma2 = gamma2.iter().copied().max().unwrap_or(1).max(1);

        for sub in 0..max_gamma2 {
            // One relative-time queue per sub-round: edges advance their
            // gamma2 schedules in *parallel* simulated time, so a fast
            // edge's sub-k+1 events may precede a slow edge's sub-k ones —
            // each drain unit gets its own timeline (and its events carry
            // the per-edge clock, matching run_round's accumulators
            // bit-for-bit).
            let mut q = EventQueue::new(
                self.eng.cfg.seed
                    ^ 0x51ac
                    ^ ((self.eng.round as u64) << 8)
                    ^ ((sub as u64) << 40),
            );
            let (jobs, job_edges) =
                self.eng.gather_jobs(sub, gamma1, gamma2, participation);
            if jobs.is_empty() {
                continue;
            }
            let results = self.eng.train_batch(jobs)?;
            // Schedule every member's completion; count expected reports.
            let mut expect = vec![0usize; m];
            let mut seen = vec![0usize; m];
            for (res, &j) in results.iter().zip(&job_edges) {
                let (t_dev, e_dev) =
                    self.eng.simulate_train(res.device, res.losses.len());
                acc.record_train(
                    j,
                    res.device,
                    t_dev,
                    e_dev,
                    res.losses.last().copied(),
                );
                q.schedule(
                    edge_clock[j] + t_dev,
                    Event::DeviceTrainDone {
                        device: res.device,
                        edge: j,
                    },
                );
                expect[j] += 1;
            }
            for res in results {
                self.eng.device_w[res.device] = res.w;
            }
            // Drain the sub-round: an edge aggregates when its last member
            // reports, at that member's completion time.
            let mut remaining = expect.iter().sum::<usize>();
            while remaining > 0 {
                let (t, ev) =
                    q.pop().expect("sync sub-round queue underflow");
                remaining -= 1;
                match ev {
                    Event::DeviceTrainDone { edge, .. } => {
                        seen[edge] += 1;
                        if seen[edge] == expect[edge] {
                            q.schedule(t, Event::EdgeAggregate { edge });
                            remaining += 1;
                        }
                    }
                    Event::EdgeAggregate { edge } => {
                        let devs =
                            self.eng.edge_participants(edge, participation);
                        if !devs.is_empty() {
                            self.eng.edge_aggregate_devices(edge, &devs)?;
                            edge_clock[edge] = t;
                        }
                    }
                    _ => unreachable!("unexpected event in sync sub-round"),
                }
            }
        }

        // Edge -> cloud communication (straggler path per edge).
        for j in 0..m {
            let region = self.eng.topo.edges[j].region;
            let t_ec = self.eng.sample_comm_time(region);
            acc.record_comm(j, t_ec, edge_clock[j]);
        }
        // Cloud aggregation at the straggler path, then the mobility
        // process advances (the barrier makes their event times trivial —
        // round_time — so no queue is needed for this tail).
        let round_time = acc.round_time();
        let active: Vec<usize> =
            (0..m).filter(|&j| acc.per_edge[j].active > 0).collect();
        self.eng.cloud_aggregate_edges(&active, None)?;
        self.eng.broadcast_cloud();

        self.eng.clock.advance(round_time);
        self.eng.round += 1;
        self.eng.total_energy += acc.round_energy;
        self.eng.mobility.step();

        let (accuracy, test_loss) = self.eng.evaluate()?;
        let stats = acc.finish(
            self.eng.round,
            accuracy,
            test_loss,
            round_time,
            self.eng.clock.now(),
            gamma1,
            gamma2,
        );
        self.eng.last_round = Some(stats.clone());
        Ok(stats)
    }

    // -----------------------------------------------------------------
    // SemiSync / Async modes: the free-running event loop.
    // -----------------------------------------------------------------

    fn run_event_loop(&mut self, g1: &[usize]) -> Result<RunHistory> {
        let m = self.edges();
        let n = self.eng.cfg.topology.devices;
        self.eng.reset();
        self.g1 = g1.to_vec();
        self.queue = EventQueue::new(self.eng.cfg.seed ^ 0xa57c);
        self.in_flight = (0..n).map(|_| None).collect();
        self.reported = vec![Vec::new(); m];
        self.edge_version = vec![0; m];
        self.device_version = vec![0; n];
        self.cloud_round_idx = 0;
        self.edge_last_update_round = vec![0; m];
        self.window_edge_aggs = vec![0; m];
        self.acc = RoundAccumulator::new(m);
        self.window_start = 0.0;

        let interval = self.mode.cloud_interval();
        self.queue.schedule(interval, Event::CloudAggregate);
        // Mobility steps once per window, offset to avoid timer ties.
        self.queue.schedule(0.5 * interval, Event::MobilityFlip);
        let all: Vec<usize> = (0..n).collect();
        self.dispatch(&all, 0.0)?;

        let threshold = self.eng.cfg.hfl.threshold_time;
        let mut hist = RunHistory::default();
        while let Some(t_next) = self.queue.peek_time() {
            if t_next > threshold {
                break;
            }
            let (t, ev) = self.queue.pop().expect("peeked event vanished");
            match ev {
                Event::DeviceTrainDone { device, edge } => {
                    self.on_train_done(device, edge, t)?;
                }
                Event::EdgeAggregate { edge } => {
                    self.on_edge_aggregate(edge, t)?;
                }
                Event::CloudAggregate => {
                    hist.push(self.on_cloud_aggregate(t)?);
                }
                Event::MobilityFlip => self.on_mobility_flip(t)?,
            }
        }
        // Flush the tail: training completed after the last timer tick
        // (or a cloud_interval longer than the whole run) would otherwise
        // drop its energy/accuracy from the history entirely.
        if self.acc.per_edge.iter().any(|e| e.active > 0) {
            hist.push(self.on_cloud_aggregate(threshold)?);
        }
        Ok(hist)
    }

    /// Start local training on every listed device that is active and
    /// idle: run the real compute now, schedule the simulated completion.
    fn dispatch(&mut self, devs: &[usize], now: f64) -> Result<()> {
        let mut jobs = Vec::new();
        for &d in devs {
            if !self.eng.mobility.is_active(d) || self.in_flight[d].is_some()
            {
                continue;
            }
            let j = self.dev_edge[d];
            jobs.push(TrainJob {
                device: d,
                w: self.eng.device_w[d].clone(),
                epochs: self.g1[j],
                seed: self.eng.fork_job_seed(d),
            });
        }
        if jobs.is_empty() {
            return Ok(());
        }
        let results = self.eng.train_batch(jobs)?;
        for res in results {
            let d = res.device;
            let (t_dev, e_dev) =
                self.eng.simulate_train(d, res.losses.len());
            self.device_version[d] = self.edge_version[self.dev_edge[d]];
            self.in_flight[d] = Some(PendingTrain {
                w: res.w,
                last_loss: res.losses.last().copied(),
                t: t_dev,
                energy: e_dev,
            });
            self.queue.schedule(
                now + t_dev,
                Event::DeviceTrainDone {
                    device: d,
                    edge: self.dev_edge[d],
                },
            );
        }
        Ok(())
    }

    fn on_train_done(
        &mut self,
        device: usize,
        edge: usize,
        t: f64,
    ) -> Result<()> {
        let Some(p) = self.in_flight[device].take() else {
            return Ok(());
        };
        // Energy was spent even if the device has since left.
        self.acc.record_train(edge, device, p.t, p.energy, p.last_loss);
        if !self.eng.mobility.is_active(device) {
            return Ok(()); // departed mid-flight: result discarded
        }
        self.eng.device_w[device] = p.w;
        self.reported[edge].push(device);
        match self.mode {
            SyncMode::SemiSync { quorum, .. } => {
                if self.reported[edge].len()
                    >= self.effective_quorum(edge, quorum)
                {
                    self.queue
                        .schedule(t, Event::EdgeAggregate { edge });
                }
            }
            SyncMode::Async { .. } => {
                self.queue.schedule(t, Event::EdgeAggregate { edge });
            }
            SyncMode::Synchronous => {
                unreachable!("sync mode does not use the free-running loop")
            }
        }
        Ok(())
    }

    /// K-quorum resolved against the edge's currently active population.
    fn effective_quorum(&self, edge: usize, quorum: usize) -> usize {
        let active = self.eng.topo.edges[edge]
            .members
            .iter()
            .filter(|&&d| self.eng.mobility.is_active(d))
            .count()
            .max(1);
        if quorum == 0 {
            active
        } else {
            quorum.min(active)
        }
    }

    fn on_edge_aggregate(&mut self, edge: usize, t: f64) -> Result<()> {
        let devs = std::mem::take(&mut self.reported[edge]);
        if devs.is_empty() {
            return Ok(()); // already flushed (duplicate trigger)
        }
        match self.mode {
            SyncMode::SemiSync { .. } => {
                // Quorum closes like a small synchronous edge round.
                self.eng.edge_aggregate_devices(edge, &devs)?;
            }
            SyncMode::Async { staleness_alpha, .. } => {
                let edge_data = self.eng.edge_data_weight(edge);
                for &d in &devs {
                    let s = self.edge_version[edge] - self.device_version[d];
                    let share =
                        self.eng.topo.shards[d].n as f32 / edge_data;
                    let beta = share * staleness_discount(s, staleness_alpha);
                    self.eng.mix_device_into_edge(edge, d, beta);
                }
                for &d in &devs {
                    self.eng.device_w[d] =
                        self.eng.edge_w[edge].clone();
                }
            }
            SyncMode::Synchronous => unreachable!(),
        }
        self.edge_version[edge] += 1;
        self.edge_last_update_round[edge] = self.cloud_round_idx;
        self.window_edge_aggs[edge] += 1;
        // Reporting devices restart from the fresh edge model.
        self.dispatch(&devs, t)
    }

    fn on_cloud_aggregate(&mut self, t: f64) -> Result<RoundStats> {
        let m = self.edges();
        // Flush partial quorums so no edge (or idle-waiting device) can
        // starve across windows.
        for j in 0..m {
            if !self.reported[j].is_empty() {
                self.on_edge_aggregate(j, t)?;
            }
        }
        for j in 0..m {
            let region = self.eng.topo.edges[j].region;
            let t_ec = self.eng.sample_comm_time(region);
            self.acc.record_comm(j, t_ec, 0.0);
        }
        match self.mode {
            SyncMode::Async { staleness_alpha, .. } => {
                // All edges contribute, discounted by how many windows ago
                // they last aggregated (pure cloud echoes decay fastest).
                let edges: Vec<usize> = (0..m).collect();
                let factors: Vec<f32> = (0..m)
                    .map(|j| {
                        staleness_discount(
                            self.cloud_round_idx
                                - self.edge_last_update_round[j],
                            staleness_alpha,
                        )
                    })
                    .collect();
                self.eng.cloud_aggregate_edges(&edges, Some(&factors))?;
            }
            SyncMode::SemiSync { .. } => {
                // Only edges that actually aggregated this window.
                let edges: Vec<usize> = (0..m)
                    .filter(|&j| self.window_edge_aggs[j] > 0)
                    .collect();
                self.eng.cloud_aggregate_edges(&edges, None)?;
            }
            SyncMode::Synchronous => unreachable!(),
        }
        // Push the new global model down to the edges only; devices are
        // mid-training and pick it up at their next edge aggregation
        // (overlapped communication).
        let cloud = self.eng.cloud_w.clone();
        for e in self.eng.edge_w.iter_mut() {
            e.clone_from(&cloud);
        }
        self.cloud_round_idx += 1;

        let round_time = t - self.window_start;
        self.eng.clock.advance(round_time);
        self.eng.round += 1;
        self.eng.total_energy += self.acc.round_energy;
        let (accuracy, test_loss) = self.eng.evaluate()?;
        let g2_observed = std::mem::replace(
            &mut self.window_edge_aggs,
            vec![0; m],
        );
        let acc = std::mem::replace(&mut self.acc, RoundAccumulator::new(m));
        let stats = acc.finish(
            self.eng.round,
            accuracy,
            test_loss,
            round_time,
            self.eng.clock.now(),
            &self.g1,
            &g2_observed,
        );
        self.eng.last_round = Some(stats.clone());
        self.window_start = t;
        self.queue
            .schedule(t + self.mode.cloud_interval(), Event::CloudAggregate);
        Ok(stats)
    }

    fn on_mobility_flip(&mut self, t: f64) -> Result<()> {
        let n = self.eng.cfg.topology.devices;
        let was: Vec<bool> =
            (0..n).map(|d| self.eng.mobility.is_active(d)).collect();
        self.eng.mobility.step();
        let flipped: Vec<usize> = (0..n)
            .filter(|&d| self.eng.mobility.is_active(d) != was[d])
            .collect();
        // A flipped device's pending report is void either way: a leaver
        // took its update with it, and a rejoiner restarts from the edge
        // model — without this purge a report-leave-rejoin sequence would
        // enter reported[] twice and double-weight the device.
        for &d in &flipped {
            self.reported[self.dev_edge[d]].retain(|&x| x != d);
        }
        let rejoined: Vec<usize> = flipped
            .iter()
            .copied()
            .filter(|&d| self.eng.mobility.is_active(d))
            .collect();
        // Rejoining devices start from their edge's current model.
        for &d in &rejoined {
            self.eng.device_w[d] =
                self.eng.edge_w[self.dev_edge[d]].clone();
        }
        self.dispatch(&rejoined, t)?;
        self.queue
            .schedule(t + self.mode.cloud_interval(), Event::MobilityFlip);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SyncConfig;

    #[test]
    fn mode_from_config() {
        assert_eq!(
            SyncMode::from_config(&SyncConfig::default()),
            SyncMode::Synchronous
        );
        let sc = SyncConfig {
            mode: SyncModeCfg::SemiSync,
            quorum: 3,
            staleness_alpha: 0.7,
            cloud_interval: 90.0,
        };
        assert_eq!(
            SyncMode::from_config(&sc),
            SyncMode::SemiSync {
                quorum: 3,
                cloud_interval: 90.0
            }
        );
        let sc = SyncConfig {
            mode: SyncModeCfg::Async,
            ..sc
        };
        match SyncMode::from_config(&sc) {
            SyncMode::Async {
                staleness_alpha,
                cloud_interval,
            } => {
                assert!((staleness_alpha - 0.7).abs() < 1e-12);
                assert!((cloud_interval - 90.0).abs() < 1e-12);
            }
            other => panic!("wrong mode {other:?}"),
        }
    }

    #[test]
    fn mode_names() {
        assert_eq!(SyncMode::Synchronous.name(), "synchronous");
        assert_eq!(
            SyncMode::SemiSync {
                quorum: 2,
                cloud_interval: 1.0
            }
            .name(),
            "semi-sync"
        );
        assert_eq!(
            SyncMode::Async {
                staleness_alpha: 0.5,
                cloud_interval: 1.0
            }
            .name(),
            "async"
        );
    }
}
