//! Event-driven HFL engine: one executor, three synchronization modes,
//! a first-class transfer layer — and a **sharded event loop** that is
//! bitwise identical at any worker count.
//!
//! Where [`HflEngine::run_round`] can only express lock-step rounds (every
//! edge advances through barrier-synchronized sub-rounds), this engine is
//! driven by deterministic discrete-event queues ([`crate::sim::event`])
//! and supports the synchronization families the paper's scheme decides
//! *between*:
//!
//! * **`SyncMode::Synchronous`** — the classic HFL schedule, re-expressed
//!   as events: every device's `DeviceTrainDone` is scheduled, each edge's
//!   `EdgeAggregate` fires when its last member reports, and the
//!   communication tail routes through the shared link layer
//!   (`HflEngine::sync_comm_phase`): the round closes when the straggler's
//!   upload lands. Reproduces `HflEngine::run_round` **bit-for-bit** under
//!   the same seed (same RNG streams consumed in the same order; equality
//!   is enforced by an integration test), proving the event core models
//!   the barrier semantics exactly. This mode runs serially on one queue —
//!   it is the reference trajectory and is untouched by the sharding.
//! * **`SyncMode::SemiSync`** — K-quorum edge aggregation: an edge
//!   aggregates as soon as `quorum` of its members have reported (reported
//!   devices idle until the quorum closes, then restart from the new edge
//!   model), while the cloud aggregates on a fixed timer. Stragglers can
//!   no longer stall their whole edge.
//! * **`SyncMode::Async`** — fully asynchronous, staleness-discounted
//!   aggregation after arXiv:2107.11415 / FedAsync: every device report
//!   immediately blends into the edge model with weight
//!   `data_share · 1/(1+s)^α` where `s` counts edge-model versions the
//!   update is stale by; the cloud timer aggregates edge models weighted by
//!   data size and per-edge freshness.
//!
//! # The sharded event loop
//!
//! The timer-driven modes no longer advance one global heap serially.
//! The loop is split in two:
//!
//! * **Ctrl queue** (this struct, serial): holds only the events with
//!   cross-edge effects — `CloudAggregate`, `MobilityFlip`, `Recluster`,
//!   `EdgeOutage`, `Partition`, `CrashStorm`. Same seed as the historical
//!   single queue (`seed ^ 0xa57c`), same backend.
//! * **Shard heaps** ([`EngineShard`], one per `min(edges, 64)` shard,
//!   edges dealt `j % n_shards`): each shard owns the event heap, RNG
//!   streams (queue tie-break, link jitter, job seeds — forked from the
//!   master seed and the *shard index*, never from a worker id), its
//!   edges' uplink/downlink `Link`s, device lifecycle/availability
//!   state, and the CPU-time models of its devices. Shard heaps hold
//!   only `DeviceTrainDone`, `EdgeAggregate`, `TransferDone` — events
//!   whose effects are confined to one edge.
//!
//! **Window bound derivation.** Shards advance in parallel to a
//! conservative bound with no speculation and no rollback. The bound is
//! simply the next ctrl timestamp: by construction *every* cross-shard
//! coupling in the timer modes is a ctrl event — the cloud timer (the
//! only reader of landed uploads and the only writer of the broadcast),
//! churn (the mobility model steps once per window), re-clustering,
//! and injected faults. Between two ctrl timestamps an edge's timeline
//! is a pure function of its own state, so a shard draining every event
//! with `t <= t_ctrl` can never miss an input from another shard. No
//! per-shard `peek_time` minimum or link-latency margin is needed —
//! the couplings are barrier-only, which makes the bound exact rather
//! than heuristic.
//!
//! **Barrier-ordered merge.** While a shard advances, it appends every
//! externally-visible decision to an ordered action log
//! ([`EngineAction`]): training dispatches (with pre-drawn job seeds and
//! pre-simulated CPU times), landings, aggregations (with pre-computed
//! staleness betas), transfer dispositions (adopt/release decided
//! shard-side from version mirrors). At the bound, the coordinator
//! *replays* the logs against the real `ModelStore` **in fixed shard
//! order 0..n** — so every model mutation, store allocation, observer
//! call and accumulator update happens in an order chosen by the
//! deterministic timelines, never by thread scheduling. Ctrl events then
//! run serially with `&mut` access to all shards (quorum re-derivation,
//! recluster migration with cross-shard device hand-off, fault fan-out,
//! the `set_control` re-arm — all merge steps between windows). Landed
//! payloads merge in fixed shard order for the same reason.
//!
//! **Worker invariance is structural.** `sim.workers` only picks how
//! many OS threads `shard_scope` spreads the *same* per-shard
//! computations over (shard `i` → lane `i % workers`; `workers <= 1`
//! runs inline). Shard count is fixed by topology, per-shard RNG
//! streams are functions of the shard index, and the merge order is
//! fixed — so the trajectory (every `RoundStats`, CSV byte, cloud
//! model, ctrl observation) is bitwise identical at any `sim.workers` ×
//! `sim.queue_backend` × observer/profiler combination. This extends
//! all six standing guarantees (sync-mode equality, zero-churn no-op,
//! fixed-knob re-arm no-op, observer-on == off, workers×backend
//! invisibility, zero-fault-plan no-op) to the full engine.
//!
//! Relative to the historical serial loop, the sharded timer modes make
//! these *documented, deterministic* trajectory changes (the sync mode
//! is bit-equal as ever): shard events at `t == t_ctrl` drain before
//! the ctrl event (the old loop interleaved by heap tie-break); the
//! cloud flush visits edges grouped by owning shard instead of globally
//! by index; job seeds come from per-shard streams; transfer ids are
//! shard-local (the `transfer_log` keys repeat across shards); and the
//! per-window `T_j^ec` observables reset each window (0 when nothing
//! landed) instead of holding the last run-wide landing.
//!
//! # Communication is in-flight, not a lump
//!
//! Edge↔cloud communication is never sampled as a lump at the cloud
//! timer. In the timer-driven modes, an edge that aggregates schedules an
//! **in-flight upload** of the fresh edge model on its uplink
//! ([`crate::sim::link::LinkManager`], shard-owned) and keeps training —
//! upload time overlaps the next local round (pace steering à la
//! arXiv:1902.01046). The cloud timer aggregates whatever uploads have
//! *landed* by the tick (latest version per edge, discounted by per-edge
//! freshness in `Async` mode), and the cloud→edge broadcast is a set of
//! **downlink transfers**: an edge only adopts the new global model when
//! its broadcast lands, and devices pick it up at their next edge
//! aggregation. Overlapping transfers on one link fair-share its
//! bandwidth when `link.contention` is on, and every landing is a
//! `TransferDone` event, so the whole timeline stays deterministic from
//! the experiment seed.
//!
//! # Membership migrates live
//!
//! When churn drifts the active set past `cluster.recluster_threshold`,
//! a `MobilityFlip` schedules an [`Event::Recluster`] and the membership
//! subsystem (`hfl::membership`) re-profiles and re-clusters the live
//! population *without stopping the run*. The migration is a barrier
//! merge step: migrated devices hand their shard-side state from source
//! to destination shard (in-flight training is voided through the
//! stale-result tombstone protocol when the shards differ), pending
//! quorum reports are purged and semi-sync quorums re-derived against
//! the new membership, and each destination edge's current model rides
//! a real in-flight downlink — a migrated device resumes training only
//! when its warm-start model lands. Synchronous mode re-clusters
//! between cloud rounds through the same `HflEngine` path as the
//! barrier engine (bit-for-bit equal).
//!
//! In the timer-driven modes one `RoundStats` is emitted per cloud
//! aggregation window: `round_time` is the window length, `gamma2` reports
//! the *observed* per-edge aggregation counts of the window, `T_j^ec` is
//! the *observed* duration of the edge's last landed transfers within the
//! window, and the per-edge `compute_busy`/`up_busy`/`down_busy`/
//! `comm_overlap` fields split the window into compute vs in-flight
//! communication time (integrated shard-side by the busy sweeper).
//!
//! # Model state is shared, versioned, copy-on-write
//!
//! Every model buffer lives in the engine's [`crate::hfl::ModelStore`];
//! `edge_w`/`device_w`/the landed view/in-flight payloads are all
//! version-tagged `ModelRef` handles, owned by the coordinator and
//! touched only during replay (shards carry plain `u64` version mirrors,
//! never model values). Broadcast landings, edge→device sync, rejoin
//! resets and migration warm-starts are O(1) handle re-points;
//! upload/downlink/migration payloads are rc-held snapshots kept intact
//! by copy-on-write while in flight. The version tags *are* the
//! staleness bookkeeping: the FedAsync device discount is the delta
//! between the shard's edge-version mirror and the version the device
//! trained from, the cloud's out-of-order landing guards compare
//! version mirrors shard-side (the replay applies the pre-decided
//! adopt/release), and `EdgeStats::staleness` is the delta between the
//! cloud version and the window of the edge's last landed upload.
//!
//! # Learned per-edge control
//!
//! The timer-driven modes expose the knobs the DRL agent drives
//! (`agent::arena`, `sync.learned`): [`AsyncHflEngine::begin_run`] /
//! [`AsyncHflEngine::run_window`] step the run one cloud window at a
//! time, and [`AsyncHflEngine::set_control`] swaps the per-edge
//! local-epoch counts γ1_j (the edge-aggregation period — future
//! dispatches pick it up) and the per-edge staleness exponents α_j
//! (future discount computations pick them up) at the cloud-aggregation
//! decision point. The re-arm propagates to every shard at the next
//! window's knob refresh — nothing in flight is touched (no queued
//! event, transfer, or pending training is re-timed), so re-arming with
//! the values already in force is bitwise invisible, and every run
//! stays a pure function of the experiment seed. The cloud decision
//! point also stamps each edge's control observables into `EdgeStats`
//! (`staleness`/`in_flight_up`/`quorum_fill`) — the rows the extended
//! DRL state is built from.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{ExperimentConfig, SyncConfig, SyncModeCfg};
use crate::obs::profiler::{PoolWindowProfile, ShardWindowProfile};
use crate::runtime::pool::TrainJob;
use crate::sim::shard::WindowRow;
use crate::sim::{Event, EventQueue};
use crate::util::threadpool::shard_scope;

use super::aggregate::staleness_discount;
use super::engine::HflEngine;
use super::engine_shard::{
    DispatchJob, EngineAction, EngineShard, Landing, ShardPhysics,
    TrainOutcome,
};
use super::lifecycle::FaultPlan;
use super::metrics::{RoundAccumulator, RoundStats, RunHistory};
use super::model_store::ModelRef;

/// Synchronization policy the event loop executes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SyncMode {
    Synchronous,
    SemiSync {
        /// Device reports that close an edge round (0 = all active members).
        quorum: usize,
        /// Cloud aggregation period, simulated seconds.
        cloud_interval: f64,
    },
    Async {
        /// Staleness discount exponent α of `1/(1+s)^α` — the *immutable
        /// config default* only. The running engine discounts with its
        /// per-edge `alpha` vector (seeded from this value, re-armed by
        /// `set_control`); never read this field on a live run.
        staleness_alpha: f64,
        cloud_interval: f64,
    },
}

impl SyncMode {
    pub fn from_config(sync: &SyncConfig) -> Self {
        match sync.mode {
            SyncModeCfg::Synchronous => SyncMode::Synchronous,
            SyncModeCfg::SemiSync => SyncMode::SemiSync {
                quorum: sync.quorum,
                cloud_interval: sync.cloud_interval,
            },
            SyncModeCfg::Async => SyncMode::Async {
                staleness_alpha: sync.staleness_alpha,
                cloud_interval: sync.cloud_interval,
            },
        }
    }

    fn cloud_interval(&self) -> f64 {
        match self {
            SyncMode::Synchronous => f64::INFINITY,
            SyncMode::SemiSync { cloud_interval, .. }
            | SyncMode::Async { cloud_interval, .. } => *cloud_interval,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SyncMode::Synchronous => "synchronous",
            SyncMode::SemiSync { .. } => "semi-sync",
            SyncMode::Async { .. } => "async",
        }
    }
}

/// Effective K-quorum against `live` members: clamps to the live count
/// (never below 1), with `quorum == 0` meaning "all live members".
pub(crate) fn effective_quorum(quorum: usize, live: usize) -> usize {
    let live = live.max(1);
    if quorum == 0 {
        live
    } else {
        quorum.min(live)
    }
}

/// True when `reported` outstanding reports satisfy the K-quorum against
/// the edge's `live` membership. The quorum clamps to the live count, so a
/// departure that shrinks an edge below K cannot leave its round unclosable
/// (the semi-sync liveness fix; re-checked on every `MobilityFlip`).
pub(crate) fn quorum_satisfied(
    reported: usize,
    quorum: usize,
    live: usize,
) -> bool {
    reported >= effective_quorum(quorum, live)
}

/// Stable per-variant label for observer hooks and metric names.
fn event_variant(ev: &Event) -> &'static str {
    match ev {
        Event::DeviceTrainDone { .. } => "train_done",
        Event::EdgeAggregate { .. } => "edge_aggregate",
        Event::CloudAggregate => "cloud_aggregate",
        Event::MobilityFlip => "mobility_flip",
        Event::Recluster => "recluster",
        Event::TransferDone { .. } => "transfer_done",
        Event::EdgeOutage { .. } => "edge_outage",
        Event::Partition { .. } => "partition",
        Event::CrashStorm { .. } => "crash_storm",
    }
}

/// A trained result materialized at replay time, parked until the
/// shard's `Train` action resolves it (land / void / depart). The
/// trained model lives IN the store while parked (an rc-1 pooled
/// buffer, not a raw Vec) so the memory observables count it and the
/// free-list recycles it. The disposition itself is decided shard-side;
/// this struct only carries what replay needs to apply it.
struct Parked {
    /// The trained result, already adopted into the store, tagged with
    /// the shard's edge-version mirror at dispatch (the FedAsync
    /// staleness base).
    r: ModelRef,
    last_loss: Option<f64>,
    t: f64,
    energy: f64,
}

/// Model snapshot riding an in-flight transfer: an rc-held store handle
/// (`ModelStore::share` — no copy; copy-on-write keeps the snapshot
/// intact if the live line mutates mid-flight). The shard schedules
/// pure timing and decides the landing disposition; the coordinator
/// owns the payloads, keyed by `(shard, shard-local transfer id)`.
enum Payload {
    /// Edge→cloud: the edge model as of its version at upload start.
    Upload { r: ModelRef },
    /// Cloud→edge: the global model broadcast by a cloud window (one
    /// shared buffer serves every edge's downlink).
    Downlink { r: ModelRef },
    /// Warm-start delivery for a re-clustering: the destination edge's
    /// model at migration time, bound for the devices migrated onto it.
    Migration { r: ModelRef },
}

impl Payload {
    /// Surrender the payload's store handle (whatever the variant).
    fn into_ref(self) -> ModelRef {
        match self {
            Payload::Upload { r }
            | Payload::Downlink { r }
            | Payload::Migration { r } => r,
        }
    }
}

pub struct AsyncHflEngine {
    pub eng: HflEngine,
    pub mode: SyncMode,
    /// The serial ctrl queue: cloud timers, churn, re-clustering and
    /// injected faults — every event with cross-shard effects. Same
    /// seed as the historical single queue (the tie-break stream is
    /// part of the trajectory).
    ctrl: EventQueue,
    /// The shard fleet (built at `begin_run`; empty before the first
    /// run). Shard count is `EngineShard::auto_shards(edges)` — a
    /// function of topology only, never of `sim.workers`.
    shards: Vec<EngineShard>,
    /// Per-edge local epochs for dispatched jobs (the edge-aggregation
    /// period; re-armed by `set_control` at cloud decision points and
    /// pushed to shards at the next window's knob refresh).
    g1: Vec<usize>,
    /// Per-edge staleness-discount exponents α_j (`Async` mode; default
    /// `sync.staleness_alpha` everywhere, re-armed by `set_control`).
    alpha: Vec<f64>,
    /// device -> owning edge (coordinator mirror of the topology).
    dev_edge: Vec<usize>,
    /// device -> owning shard (follows re-cluster migrations).
    dev_shard: Vec<usize>,
    /// Trained results materialized at dispatch replay, waiting for
    /// their `Train` action.
    parked: Vec<Option<Parked>>,
    acc: RoundAccumulator,
    window_start: f64,
    // ---- transfer layer state ------------------------------------------
    /// Payloads of in-flight transfers, keyed by (shard, shard-local
    /// transfer id).
    payloads: HashMap<(usize, usize), Payload>,
    /// Latest edge model that has landed at the cloud, per edge (a share
    /// of the initial global model until anything lands). The
    /// adopt-vs-release ordering guard lives in the shard's version
    /// mirrors; replay applies its decision.
    landed_w: Vec<ModelRef>,
    /// (transfer id, edge, landing time) of every completed transfer, in
    /// replay order — the determinism witness of the transfer path.
    /// Ids are shard-local, so the same id can appear for different
    /// edges; the (id, edge, time) triple is still a worker-invariant
    /// fingerprint of the whole transfer timeline.
    pub transfer_log: Vec<(usize, usize, f64)>,
    /// Monotone id of executed re-clusterings within the run.
    recluster_seq: u64,
    /// (recluster seq, device, new edge) of every warm-start that landed
    /// and was applied, in replay order.
    pub migration_log: Vec<(u64, usize, usize)>,
    /// Set for the end-of-run tail flush: the event loop is over, so new
    /// training dispatches and transfers could never complete — shards
    /// skip them instead of burning real compute on dead work.
    draining: bool,
    /// Injected fault events handled this window (down and up edges of
    /// outages, partitions and storms); stamped into
    /// `RoundStats::fault_events`.
    win_fault_events: usize,
    // ---- engine-shard telemetry (observer+profiler gated) --------------
    /// Wall time spent inside `shard_scope` advances this window.
    win_wall_ns: u64,
    /// Per-shard busy wall-ns this window (advance calls only).
    win_shard_busy_ns: Vec<u64>,
}

impl AsyncHflEngine {
    pub fn new(cfg: ExperimentConfig, use_profiling: bool) -> Result<Self> {
        let mode = SyncMode::from_config(&cfg.sync);
        let seed = cfg.seed;
        let mut eng = HflEngine::new(cfg, use_profiling)?;
        let n = eng.cfg.topology.devices;
        let m = eng.cfg.topology.edges;
        let mut dev_edge = vec![0usize; n];
        for (j, edge) in eng.topo.edges.iter().enumerate() {
            for &d in &edge.members {
                dev_edge[d] = j;
            }
        }
        let g1 = vec![eng.cfg.hfl.gamma1; m];
        let alpha = vec![eng.cfg.sync.staleness_alpha; m];
        // The cloud's landed view starts as rc-shares of the edge models
        // (all still the one init buffer) — no clones.
        let landed_w = eng.share_edge_handles();
        Ok(AsyncHflEngine {
            // Same seed as ever (the tie-break stream is part of the
            // trajectory); capacity/backend are bitwise invisible.
            ctrl: EventQueue::for_scale(
                seed ^ 0xa57c,
                n * 4 + 64,
                eng.cfg.sim.queue_backend,
            ),
            shards: Vec::new(),
            g1,
            alpha,
            dev_edge,
            dev_shard: vec![0; n],
            parked: (0..n).map(|_| None).collect(),
            acc: RoundAccumulator::new(m),
            window_start: 0.0,
            payloads: HashMap::new(),
            landed_w,
            transfer_log: Vec::new(),
            recluster_seq: 0,
            migration_log: Vec::new(),
            draining: false,
            win_fault_events: 0,
            win_wall_ns: 0,
            win_shard_busy_ns: Vec::new(),
            mode,
            eng,
        })
    }

    pub fn edges(&self) -> usize {
        self.eng.edges()
    }

    /// Attach an [`Observer`](crate::obs::Observer) to the underlying
    /// engine. Hooks are read-only and may never feed back into the
    /// simulation — an instrumented run is bitwise identical to an
    /// uninstrumented one (enforced by an integration test).
    pub fn attach_observer(&mut self, obs: Box<dyn crate::obs::Observer>) {
        self.eng.attach_observer(obs);
    }

    /// Detach and return the current observer, if any.
    pub fn detach_observer(
        &mut self,
    ) -> Option<Box<dyn crate::obs::Observer>> {
        self.eng.detach_observer()
    }

    /// Run the configured mode to the time threshold with uniform default
    /// frequencies.
    pub fn run_to_threshold(&mut self) -> Result<RunHistory> {
        let g1 = vec![self.eng.cfg.hfl.gamma1; self.edges()];
        self.run_with(&g1)
    }

    /// Run the configured mode to the time threshold under per-edge local
    /// epochs `g1` (gamma2 only applies in `Synchronous`, from the config).
    pub fn run_with(&mut self, g1: &[usize]) -> Result<RunHistory> {
        anyhow::ensure!(
            g1.len() == self.edges(),
            "need {} per-edge frequencies",
            self.edges()
        );
        match self.mode {
            SyncMode::Synchronous => {
                self.eng.reset();
                let g2 = vec![self.eng.cfg.hfl.gamma2; self.edges()];
                let mut hist = RunHistory::default();
                while self.eng.remaining_time() > 0.0 {
                    hist.push(self.run_round(g1, &g2, None)?);
                }
                Ok(hist)
            }
            _ => {
                self.begin_run(g1)?;
                let mut hist = RunHistory::default();
                while let Some(stats) = self.run_window()? {
                    hist.push(stats);
                }
                Ok(hist)
            }
        }
    }

    /// Swap the per-edge control knobs at a cloud-aggregation decision
    /// point (the learned-sync hook): future dispatches run `g1[j]` local
    /// epochs per report — re-arming edge j's aggregation period — and
    /// future staleness discounts use exponent `alpha[j]`. Nothing
    /// in flight is re-timed, so re-arming with the values already in
    /// force leaves the run bit-for-bit unchanged.
    pub fn set_control(&mut self, g1: &[usize], alpha: &[f64]) -> Result<()> {
        let m = self.edges();
        anyhow::ensure!(
            g1.len() == m && alpha.len() == m,
            "need {m} per-edge control values"
        );
        anyhow::ensure!(
            g1.iter().all(|&g| g >= 1),
            "per-edge gamma1 must be >= 1"
        );
        anyhow::ensure!(
            alpha.iter().all(|&a| a.is_finite() && a >= 0.0),
            "per-edge alpha must be finite and >= 0"
        );
        self.g1.copy_from_slice(g1);
        self.alpha.copy_from_slice(alpha);
        Ok(())
    }

    /// Current per-edge (γ1_j, α_j) control values.
    pub fn control(&self) -> (&[usize], &[f64]) {
        (&self.g1, &self.alpha)
    }

    // -----------------------------------------------------------------
    // Synchronous mode: one barriered cloud round, event-driven.
    // -----------------------------------------------------------------

    /// Execute one synchronous cloud round through the event queue.
    /// Equivalent to `HflEngine::run_round` bit-for-bit under the same
    /// seed: the same RNG streams are consumed in the same order, and the
    /// event timeline reproduces the barrier arithmetic exactly (an edge's
    /// aggregate fires at its slowest member's completion; the cloud when
    /// the straggler edge's upload lands through the shared link layer).
    pub fn run_round(
        &mut self,
        gamma1: &[usize],
        gamma2: &[usize],
        participation: Option<&[bool]>,
    ) -> Result<RoundStats> {
        if !matches!(self.mode, SyncMode::Synchronous) {
            bail!(
                "run_round is the synchronous entry point; {} mode runs \
                 through run_with/run_to_threshold",
                self.mode.name()
            );
        }
        let m = self.edges();
        anyhow::ensure!(
            gamma1.len() == m && gamma2.len() == m,
            "need {m} per-edge frequencies"
        );
        let mut acc = RoundAccumulator::new(m);
        let mut edge_clock = vec![0.0f64; m];
        let max_gamma2 = gamma2.iter().copied().max().unwrap_or(1).max(1);

        for sub in 0..max_gamma2 {
            // One relative-time queue per sub-round: edges advance their
            // gamma2 schedules in *parallel* simulated time, so a fast
            // edge's sub-k+1 events may precede a slow edge's sub-k ones —
            // each drain unit gets its own timeline (and its events carry
            // the per-edge clock, matching run_round's accumulators
            // bit-for-bit).
            let mut q = EventQueue::for_scale(
                self.eng.cfg.seed
                    ^ 0x51ac
                    ^ ((self.eng.round as u64) << 8)
                    ^ ((sub as u64) << 40),
                self.eng.cfg.topology.devices * 2 + 16,
                self.eng.cfg.sim.queue_backend,
            );
            let (jobs, job_edges) =
                self.eng.gather_jobs(sub, gamma1, gamma2, participation);
            if jobs.is_empty() {
                continue;
            }
            let results = self.eng.train_batch(jobs)?;
            // Schedule every member's completion; count expected reports.
            // The per-device simulation is batched over the sim worker
            // pool (bit-identical to the serial loop at any sim.workers).
            let reqs: Vec<(usize, usize)> = results
                .iter()
                .map(|res| (res.device, res.losses.len()))
                .collect();
            let sims = self.eng.simulate_train_batch(&reqs);
            let mut expect = vec![0usize; m];
            let mut seen = vec![0usize; m];
            for ((res, &j), &(t_dev, e_dev)) in
                results.iter().zip(&job_edges).zip(&sims)
            {
                acc.record_train(
                    j,
                    res.device,
                    t_dev,
                    e_dev,
                    res.losses.last().copied(),
                );
                q.schedule(
                    edge_clock[j] + t_dev,
                    Event::DeviceTrainDone {
                        device: res.device,
                        edge: j,
                    },
                );
                expect[j] += 1;
            }
            for res in results {
                self.eng.commit_device(res.device, res.w);
            }
            // Drain the sub-round: an edge aggregates when its last member
            // reports, at that member's completion time.
            let mut remaining = expect.iter().sum::<usize>();
            while remaining > 0 {
                let (t, ev) = q.pop().expect("sync sub-round queue underflow");
                remaining -= 1;
                match ev {
                    Event::DeviceTrainDone { edge, .. } => {
                        seen[edge] += 1;
                        if seen[edge] == expect[edge] {
                            q.schedule(t, Event::EdgeAggregate { edge });
                            remaining += 1;
                        }
                    }
                    Event::EdgeAggregate { edge } => {
                        let devs =
                            self.eng.edge_participants(edge, participation);
                        if !devs.is_empty() {
                            self.eng.edge_aggregate_devices(edge, &devs)?;
                            edge_clock[edge] = t;
                        }
                    }
                    _ => unreachable!("unexpected event in sync sub-round"),
                }
            }
        }

        // Edge -> cloud communication through the link layer: the round
        // closes when the last upload lands (shared with HflEngine).
        let mut round_time = self.eng.sync_comm_phase(&edge_clock, &mut acc);
        let active: Vec<usize> =
            (0..m).filter(|&j| acc.per_edge[j].active > 0).collect();
        self.eng.cloud_aggregate_edges(&active, None)?;
        self.eng.broadcast_cloud();

        self.eng.clock.advance(round_time);
        self.eng.round += 1;
        self.eng.total_energy += acc.round_energy;
        let flips = self.eng.mobility.step();
        self.eng.membership.observe(flips);
        // Same between-rounds re-clustering call as HflEngine::run_round,
        // in the same position: identical RNG consumption and identical
        // accounting keep the two engines bit-for-bit equal in
        // synchronous mode.
        if let Some(out) = self.eng.maybe_recluster_barrier(&mut acc)? {
            round_time += out.migration_downlink_time;
            self.refresh_dev_edge();
        }
        self.eng
            .record_lifecycle_baseline(&mut acc, self.eng.clock.now());

        let (accuracy, test_loss) = self.eng.evaluate()?;
        let mut stats = acc.finish(
            self.eng.round,
            accuracy,
            test_loss,
            round_time,
            self.eng.clock.now(),
            gamma1,
            gamma2,
        );
        self.eng.finalize_membership_stats(&mut stats);
        self.eng.finalize_memory_stats(&mut stats);
        self.eng.emit_round_observation(&stats);
        self.eng.last_round = Some(stats.clone());
        Ok(stats)
    }

    /// Rebuild the device→edge map from the (possibly re-clustered)
    /// topology.
    fn refresh_dev_edge(&mut self) {
        for (j, e) in self.eng.topo.edges.iter().enumerate() {
            for &d in &e.members {
                self.dev_edge[d] = j;
            }
        }
    }

    // -----------------------------------------------------------------
    // SemiSync / Async modes: the sharded free-running event loop.
    // -----------------------------------------------------------------

    /// Reset and arm a fresh timer-driven run: models, the ctrl queue,
    /// the shard fleet (heaps, links, RNG streams, lifecycle state), the
    /// initial `CloudAggregate`/`MobilityFlip` timers, and the first
    /// dispatch of every device. The run then advances one cloud window
    /// per [`AsyncHflEngine::run_window`] call (with optional
    /// [`AsyncHflEngine::set_control`] swaps in between); `run_with` is
    /// the uncontrolled convenience loop over it.
    pub fn begin_run(&mut self, g1: &[usize]) -> Result<()> {
        anyhow::ensure!(
            !matches!(self.mode, SyncMode::Synchronous),
            "begin_run drives the timer modes; synchronous runs use \
             run_round/run_with"
        );
        anyhow::ensure!(
            g1.len() == self.edges(),
            "need {} per-edge frequencies",
            self.edges()
        );
        let m = self.edges();
        let n = self.eng.cfg.topology.devices;
        // Hand this engine's own store handles back before the reset
        // rebuilds the hierarchy: stale payloads, parked results and the
        // landed view must not keep last run's buffers alive. Payload
        // keys release in sorted order — the store free-list is
        // order-sensitive and HashMap drain order is not deterministic.
        let mut keys: Vec<(usize, usize)> =
            self.payloads.keys().copied().collect();
        keys.sort_unstable();
        for k in keys {
            let p = self.payloads.remove(&k).expect("payload key vanished");
            self.eng.store.release(p.into_ref());
        }
        for slot in self.parked.iter_mut() {
            if let Some(p) = slot.take() {
                self.eng.store.release(p.r);
            }
        }
        for r in self.landed_w.drain(..) {
            self.eng.store.release(r);
        }
        self.eng.reset();
        self.g1 = g1.to_vec();
        self.alpha = vec![self.eng.cfg.sync.staleness_alpha; m];
        self.parked = (0..n).map(|_| None).collect();
        self.acc = RoundAccumulator::new(m);
        self.window_start = 0.0;
        self.landed_w = self.eng.share_edge_handles();
        self.transfer_log.clear();
        self.recluster_seq = 0;
        self.migration_log.clear();
        self.refresh_dev_edge();
        self.draining = false;
        self.win_fault_events = 0;
        self.win_wall_ns = 0;

        // ---- the shard fleet -------------------------------------------
        // Shard count is a function of topology only; edges deal
        // round-robin so shard i's streams are identical at any worker
        // count (shard_scope pins shard i → lane i % workers).
        let n_shards = EngineShard::auto_shards(m);
        let phys = ShardPhysics {
            nb: self.eng.rt.manifest.config.nb,
            pbytes: crate::sim::network::model_bytes(self.eng.p),
            up_scale: self.eng.cfg.link.up_bandwidth_scale,
            down_scale: self.eng.cfg.link.down_bandwidth_scale,
            contention: self.eng.cfg.link.contention,
            net: self.eng.net.clone(),
            energy: self.eng.energy_model.clone(),
            avail: self.eng.avail.clone(),
            regions: self.eng.topo.edges.iter().map(|e| e.region).collect(),
            data_n: Arc::new(
                self.eng.topo.shards.iter().map(|s| s.n as f32).collect(),
            ),
            mode: self.mode,
            overselect: self.eng.cfg.lifecycle.overselect,
        };
        let expected = n / n_shards * 4 + 64;
        let mut shards: Vec<EngineShard> = (0..n_shards)
            .map(|s| {
                EngineShard::new(
                    s,
                    n_shards,
                    self.eng.cfg.seed,
                    self.eng.cfg.sim.queue_backend,
                    expected,
                    phys.clone(),
                )
            })
            .collect();
        for j in 0..m {
            let s = EngineShard::shard_of(j, n_shards);
            shards[s].install_edge(j, self.eng.topo.edges[j].members.clone());
        }
        let mut dev_shard = vec![0usize; n];
        for d in 0..n {
            let j = self.dev_edge[d];
            let s = EngineShard::shard_of(j, n_shards);
            dev_shard[d] = s;
            // Each shard clones its devices' CPU models: the coordinator
            // copies in `topo.cpus` stay untouched by the timer modes, so
            // a later synchronous run still sees the post-reset states.
            shards[s].install_device(
                d,
                j,
                self.eng.mobility.is_active(d),
                self.eng.device_w[d].version(),
                self.eng.topo.cpus[d].clone(),
            );
        }
        self.shards = shards;
        self.dev_shard = dev_shard;
        self.win_shard_busy_ns = vec![0; n_shards];

        // ---- the ctrl timeline -----------------------------------------
        self.ctrl = EventQueue::for_scale(
            self.eng.cfg.seed ^ 0xa57c,
            64,
            self.eng.cfg.sim.queue_backend,
        );
        let interval = self.mode.cloud_interval();
        self.ctrl.schedule(interval, Event::CloudAggregate);
        // Mobility steps once per window, offset to avoid timer ties.
        self.ctrl.schedule(0.5 * interval, Event::MobilityFlip);
        // Injected faults are scheduled events, never ambient state
        // (`hfl::lifecycle` determinism rules): the plan expands the
        // `fault.*` knobs once from a dedicated stream and lands in the
        // ctrl queue like any other event. A zero-count plan is empty —
        // no schedule calls, no tie-break draws — so a fault-free run
        // is bitwise identical to one built before faults existed.
        let plan = FaultPlan::build(
            &self.eng.cfg.fault,
            m,
            self.eng.cfg.hfl.threshold_time,
            self.eng.cfg.seed,
        );
        for &(t, ev) in plan.events() {
            self.ctrl.schedule(t, ev);
        }

        // First dispatch of every edge's cohort, shard-side, replayed in
        // shard order (the order every later merge uses too).
        let obs_on = self.eng.obs.is_some();
        let profile = obs_on && self.eng.cfg.sim.profiler;
        for s in 0..self.shards.len() {
            self.shards[s].refresh_knobs(
                &self.g1,
                &self.alpha,
                obs_on,
                profile,
                false,
            );
            self.shards[s].initial_dispatch(0.0);
            let log = self.shards[s].take_actions();
            self.replay_log(s, &log)?;
            self.shards[s].recycle(log);
        }
        Ok(())
    }

    /// Advance the armed run to its next cloud-aggregation decision point
    /// and return that window's stats; `None` once the time budget is
    /// exhausted and the tail has been flushed. Event order is identical
    /// to the single-call loop — stepping changes *when the caller gets
    /// control*, never the simulated timeline.
    pub fn run_window(&mut self) -> Result<Option<RoundStats>> {
        let threshold = self.eng.cfg.hfl.threshold_time;
        while let Some(t_ctrl) = self.ctrl.peek_time() {
            if t_ctrl > threshold {
                break;
            }
            // Conservative window: every cross-shard coupling is a ctrl
            // event (module doc), so the shards advance in parallel to
            // exactly the next ctrl timestamp — no speculation, no
            // rollback — and their action logs replay in shard order.
            self.advance_to(t_ctrl)?;
            // Wall-clock reads are gated on an attached observer: with
            // none, this path performs no `Instant` syscalls. Either way
            // wall time only flows into observer records, never into the
            // simulated timeline (the observer-on == observer-off bitwise
            // guarantee).
            let t_pop = self
                .eng
                .obs
                .as_ref()
                .map(|_| std::time::Instant::now());
            let (t, ev) = self.ctrl.pop().expect("peeked event vanished");
            let t_handle = t_pop.map(|_| std::time::Instant::now());
            let variant = event_variant(&ev);
            let mut window = None;
            match ev {
                Event::CloudAggregate => {
                    window = Some(self.cloud_barrier(t)?);
                }
                Event::MobilityFlip => self.flip_barrier(t)?,
                Event::Recluster => self.recluster_barrier(t)?,
                Event::EdgeOutage { edge, up } => {
                    self.outage_barrier(edge, up, t)?;
                }
                Event::Partition { mask, up } => {
                    self.partition_barrier(mask, up);
                }
                Event::CrashStorm { seed, frac_bits, up } => {
                    self.storm_barrier(seed, frac_bits, up, t)?;
                }
                other => {
                    unreachable!("shard event {other:?} in ctrl queue")
                }
            }
            if let Some(o) = self.eng.obs.as_mut() {
                let lag_ns = t_pop
                    .zip(t_handle)
                    .map(|(p, h)| h.duration_since(p).as_nanos() as u64)
                    .unwrap_or(0);
                let handler_ns = t_handle
                    .map(|h| h.elapsed().as_nanos() as u64)
                    .unwrap_or(0);
                o.on_event_handled(variant, t, lag_ns, handler_ns);
            }
            if let Some(stats) = window {
                return Ok(Some(stats));
            }
        }
        // Run the shard timelines out to the threshold, then flush the
        // tail: training completed after the last timer tick (or a
        // cloud_interval longer than the whole run) would otherwise drop
        // its energy/accuracy from the history entirely. Draining
        // suppresses new dispatches/transfers — they could never finish.
        self.advance_to(threshold)?;
        if self.acc.per_edge.iter().any(|e| e.active > 0) {
            self.draining = true;
            let stats = self.cloud_barrier(threshold)?;
            self.draining = false;
            return Ok(Some(stats));
        }
        Ok(None)
    }

    /// Push the current knobs to every shard and advance them all to
    /// `bound` in parallel, then replay their action logs in fixed shard
    /// order. The only wall-clock reads are profiler-gated and flow only
    /// into observer records.
    fn advance_to(&mut self, bound: f64) -> Result<()> {
        let obs_on = self.eng.obs.is_some();
        let profile = obs_on && self.eng.cfg.sim.profiler;
        for sh in self.shards.iter_mut() {
            sh.refresh_knobs(
                &self.g1,
                &self.alpha,
                obs_on,
                profile,
                self.draining,
            );
        }
        let workers = self.eng.sim_workers();
        let w0 = if profile {
            Some(std::time::Instant::now())
        } else {
            None
        };
        let logs = shard_scope(workers, &mut self.shards, |_idx, sh| {
            let b0 = if profile {
                Some(std::time::Instant::now())
            } else {
                None
            };
            sh.advance(bound);
            let busy =
                b0.map(|p| p.elapsed().as_nanos() as u64).unwrap_or(0);
            (sh.take_actions(), busy)
        });
        if let Some(p) = w0 {
            self.win_wall_ns += p.elapsed().as_nanos() as u64;
        }
        for (s, (log, busy)) in logs.into_iter().enumerate() {
            self.win_shard_busy_ns[s] += busy;
            self.replay_log(s, &log)?;
            self.shards[s].recycle(log);
        }
        Ok(())
    }

    /// Apply one shard's window log to the coordinator state: the real
    /// training, every model movement, the accumulators and the observer
    /// stream — in exactly the order the shard's timeline decided them.
    /// Reads the actions by reference so the log's inner buffers can go
    /// back to the shard's spare pools afterwards (`EngineShard::recycle`).
    fn replay_log(&mut self, s: usize, acts: &[EngineAction]) -> Result<()> {
        for a in acts {
            match a {
                EngineAction::Obs {
                    variant,
                    t,
                    lag_ns,
                    handler_ns,
                } => {
                    if let Some(o) = self.eng.obs.as_mut() {
                        o.on_event_handled(variant, *t, *lag_ns, *handler_ns);
                    }
                }
                EngineAction::Dispatch {
                    t,
                    jobs,
                    sim_wall_ns,
                } => {
                    self.replay_dispatch(*t, jobs, *sim_wall_ns)?;
                }
                EngineAction::Train {
                    edge,
                    device,
                    outcome,
                } => {
                    let p = self.parked[*device]
                        .take()
                        .expect("train done without a parked result");
                    // Energy was spent even if the result is discarded.
                    self.acc.record_train(
                        *edge, *device, p.t, p.energy, p.last_loss,
                    );
                    match outcome {
                        TrainOutcome::Landed => {
                            // The device line takes over the parked handle
                            // (already version-tagged with its staleness
                            // base at dispatch).
                            self.eng
                                .store
                                .adopt(&mut self.eng.device_w[*device], p.r);
                        }
                        TrainOutcome::Voided | TrainOutcome::Departed => {
                            self.eng.store.release(p.r);
                        }
                    }
                }
                EngineAction::EdgeAgg { edge, devs, mixes } => {
                    if mixes.is_empty() {
                        // Semi-sync quorum close: a small synchronous edge
                        // round (the edge version advances inside).
                        self.eng.edge_aggregate_devices(*edge, devs)?;
                    } else {
                        // Async staleness-discounted blend: betas were
                        // computed shard-side from version mirrors and
                        // data shares — replay only applies them.
                        for &(d, beta) in mixes {
                            self.eng.mix_device_into_edge(*edge, d, beta);
                        }
                        self.eng.edge_w[*edge].bump_version();
                        for &d in devs {
                            // O(1) re-point: reporting devices pick up the
                            // fresh edge model by reference.
                            self.eng.store.repoint(
                                &mut self.eng.device_w[d],
                                &self.eng.edge_w[*edge],
                            );
                        }
                    }
                }
                EngineAction::UploadStart { edge, id } => {
                    // Snapshot the edge model (rc-share — CoW keeps it
                    // intact while in flight) as the uplink payload.
                    let r = self.eng.store.share(&self.eng.edge_w[*edge]);
                    self.payloads.insert((s, *id), Payload::Upload { r });
                }
                EngineAction::Rejoin { edge, devices } => {
                    // Rejoining devices start from their edge's current
                    // model. O(1) re-points.
                    for &d in devices {
                        self.eng.store.repoint(
                            &mut self.eng.device_w[d],
                            &self.eng.edge_w[*edge],
                        );
                    }
                }
                EngineAction::Transfer {
                    id,
                    edge,
                    t,
                    dir,
                    bytes,
                    start,
                    finish,
                    landing,
                } => {
                    let payload = self
                        .payloads
                        .remove(&(s, *id))
                        .expect("live transfer without payload");
                    self.transfer_log.push((*id, *edge, *t));
                    if let Some(o) = self.eng.obs.as_mut() {
                        o.on_transfer(*edge, dir, *bytes, *start, *finish);
                    }
                    let r = payload.into_ref();
                    match landing {
                        // The adopt/release decision was made shard-side
                        // against the version mirrors (latest version
                        // wins; contention can land older snapshots
                        // late) — replay just applies it.
                        Landing::Upload { adopt } => {
                            if *adopt {
                                self.eng
                                    .store
                                    .adopt(&mut self.landed_w[*edge], r);
                            } else {
                                self.eng.store.release(r);
                            }
                        }
                        Landing::Downlink { adopt } => {
                            // Adopting a broadcast is not an edge
                            // aggregation: the edge keeps its own
                            // version tag.
                            if *adopt {
                                self.eng.store.adopt_keep_version(
                                    &mut self.eng.edge_w[*edge],
                                    r,
                                );
                            } else {
                                self.eng.store.release(r);
                            }
                        }
                        Landing::Migration { devices, seq } => {
                            // Warm start by reference: every still-pending
                            // migrant (filtered shard-side) shares the
                            // delivered snapshot.
                            for &d in devices {
                                self.eng.store.repoint(
                                    &mut self.eng.device_w[d],
                                    &r,
                                );
                                self.migration_log.push((*seq, d, *edge));
                            }
                            self.eng.store.release(r);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Run the real compute for a shard-dispatched training burst. The
    /// shard already drew the job seeds, simulated the CPU times and
    /// scheduled the completions — replay materializes the input weights
    /// (the one copy point: the worker pool needs owned buffers), trains,
    /// and parks the results for their `Train` actions.
    fn replay_dispatch(
        &mut self,
        t: f64,
        jobs: &[DispatchJob],
        sim_wall_ns: u64,
    ) -> Result<()> {
        let batch: Vec<TrainJob> = jobs
            .iter()
            .map(|jb| TrainJob {
                device: jb.device,
                w: self
                    .eng
                    .store
                    .slice(&self.eng.device_w[jb.device])
                    .to_vec(),
                epochs: jb.epochs,
                seed: jb.seed,
            })
            .collect();
        let results = self.eng.train_batch(batch)?;
        for (res, jb) in results.into_iter().zip(jobs) {
            debug_assert_eq!(res.device, jb.device, "train batch reordered");
            // Adopt the trained result into the store immediately, tagged
            // with the edge version it started from (the staleness base).
            let r = self.eng.store.insert(res.w, jb.start_version);
            self.parked[jb.device] = Some(Parked {
                r,
                last_loss: res.losses.last().copied(),
                t: jb.t_dev,
                energy: jb.e_dev,
            });
            if let Some(o) = self.eng.obs.as_mut() {
                // Training burst on the edge's trace track; both span
                // endpoints are simulated times, so the trace is
                // deterministic under a fixed seed.
                o.on_span(crate::obs::Span {
                    track: format!("edge/{}", jb.edge),
                    name: format!("train d{}", jb.device),
                    t0_sim: t,
                    t1_sim: t + jb.lag + jb.t_dev,
                    wall_ns: 0,
                });
            }
        }
        // The shard's wall cost of the CPU simulation (profiler-gated,
        // 0 otherwise); the sim ran shard-side on one thread.
        if sim_wall_ns > 0 && !jobs.is_empty() {
            if let Some(o) = self.eng.obs.as_mut() {
                o.on_sim_batch(jobs.len(), 1, sim_wall_ns);
            }
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Barrier merge steps (serial, fixed shard order).
    // -----------------------------------------------------------------

    /// The cloud-aggregation barrier: flush pending quorums, aggregate
    /// the landed views, broadcast over per-edge downlinks, and close the
    /// window's `RoundStats` — all against `&mut` shard access, merged
    /// in fixed shard order.
    fn cloud_barrier(&mut self, t: f64) -> Result<RoundStats> {
        let m = self.edges();
        let n_shards = self.shards.len();
        for sh in self.shards.iter_mut() {
            sh.draining = self.draining;
            sh.barrier_sweep(t);
        }
        // Control observables at the decision point, captured before the
        // quorum flush perturbs them: staleness of each edge's last
        // landed upload (in windows), uploads still in flight, and the
        // semi-sync quorum fill of the outstanding reports. These become
        // the `EdgeStats` rows the extended DRL state reads.
        let cloud_v = self.eng.cloud_w.version();
        let ctrl_obs: Vec<(f64, usize, f64)> = (0..m)
            .map(|j| {
                let sh = &self.shards[EngineShard::shard_of(j, n_shards)];
                let staleness =
                    (cloud_v - sh.edge_last_update[j]) as f64;
                let in_flight = sh.uplink_in_flight(j);
                let fill = match self.mode {
                    SyncMode::SemiSync { quorum, .. } => {
                        sh.reported_len(j) as f64
                            / effective_quorum(quorum, sh.live_members(j))
                                as f64
                    }
                    _ => 0.0,
                };
                (staleness, in_flight, fill)
            })
            .collect();
        // Flush partial quorums so no edge (or idle-waiting device) can
        // starve across windows; their uploads start now and land later.
        // Grouped by owning shard (the fixed merge order), edges in
        // shard-local order within each.
        for s in 0..n_shards {
            for i in 0..self.shards[s].edges.len() {
                let j = self.shards[s].edges[i];
                self.shards[s].flush_edge(j, t);
            }
            let log = self.shards[s].take_actions();
            self.replay_log(s, &log)?;
            self.shards[s].recycle(log);
        }
        // The cloud aggregates what has LANDED by its timer — not the
        // live edge models, which may still be in flight. The landed
        // views resolve to slices at the aggregation boundary; committing
        // advances the cloud version by one (an empty semi-sync window
        // bumps the version without a new model — the window counts).
        let contributors: Vec<usize> = match self.mode {
            SyncMode::Async { .. } => (0..m).collect(),
            SyncMode::SemiSync { .. } => (0..m)
                .filter(|&j| {
                    let s = EngineShard::shard_of(j, n_shards);
                    self.shards[s].window_landings[j] > 0
                })
                .collect(),
            SyncMode::Synchronous => unreachable!(),
        };
        // Async: landed models are discounted by how many windows ago
        // they landed (pure echoes decay fastest) under the edge's
        // current α_j.
        let factors: Option<Vec<f32>> = match self.mode {
            SyncMode::Async { .. } => Some(
                contributors
                    .iter()
                    .map(|&j| {
                        let s = EngineShard::shard_of(j, n_shards);
                        staleness_discount(
                            cloud_v - self.shards[s].edge_last_update[j],
                            self.alpha[j],
                        )
                    })
                    .collect(),
            ),
            _ => None,
        };
        if contributors.is_empty() {
            self.eng.bump_cloud_version();
        } else {
            let weights =
                self.eng.cloud_weights(&contributors, factors.as_deref());
            let agg = {
                let models: Vec<&[f32]> = contributors
                    .iter()
                    .map(|&j| self.eng.store.slice(&self.landed_w[j]))
                    .collect();
                self.eng.aggregate(&models, &weights)?
            };
            self.eng.commit_cloud(agg);
        }
        // Every shard's cloud-version mirror moves at the barrier (the
        // staleness bookkeeping and the downlink ordering guard).
        let v = self.eng.cloud_w.version();
        for sh in self.shards.iter_mut() {
            sh.set_cloud_version(v);
        }
        // Broadcast as in-flight downlink transfers; each edge adopts
        // the model when it lands. One shared buffer (rc-shared, not
        // cloned) serves all m downlinks, tagged with the new cloud
        // version. Timing draws come from the owning shard's link
        // stream, payload keys from its shard-local transfer ids.
        for j in 0..m {
            let s = EngineShard::shard_of(j, n_shards);
            if let Some(id) = self.shards[s].start_downlink(j, t) {
                let r = self.eng.store.share(&self.eng.cloud_w);
                self.payloads.insert((s, id), Payload::Downlink { r });
            }
        }

        // Close the window's stats from observed transfers + busy sweep,
        // per edge in index order (the CSV row order).
        let mut g2_observed = vec![0usize; m];
        for j in 0..m {
            let s = EngineShard::shard_of(j, n_shards);
            let (ou, od, wc, wu, wd, wcm, wo) = {
                let sh = &self.shards[s];
                (
                    sh.obs_up[j],
                    sh.obs_down[j],
                    sh.win_compute[j],
                    sh.win_up[j],
                    sh.win_down[j],
                    sh.win_comm[j],
                    sh.win_overlap[j],
                )
            };
            self.acc.record_window(j, ou, od, wc, wu, wd, wcm, wo);
            let (staleness, in_flight, fill) = ctrl_obs[j];
            self.acc.record_ctrl(j, staleness, in_flight, fill);
            // Lifecycle observables at the decision point: stragglers
            // abandoned this window (first-K close + fault voids) and
            // the edge's membership availability right now. Recorded
            // unconditionally — lifecycle-off yields the (0, 1.0)
            // baseline — so schema-v2 rows are uniform across runs.
            let dropped =
                std::mem::take(&mut self.shards[s].win_abandoned[j]);
            let avail_j = self.eng.edge_availability(j, t);
            self.acc.record_lifecycle(j, dropped, avail_j);
            g2_observed[j] =
                std::mem::take(&mut self.shards[s].window_edge_aggs[j]);
            self.shards[s].window_reset_edge(j);
        }

        let round_time = t - self.window_start;
        self.eng.clock.advance(round_time);
        self.eng.round += 1;
        self.eng.total_energy += self.acc.round_energy;
        let (accuracy, test_loss) = self.eng.evaluate()?;
        let acc = std::mem::replace(&mut self.acc, RoundAccumulator::new(m));
        let mut stats = acc.finish(
            self.eng.round,
            accuracy,
            test_loss,
            round_time,
            self.eng.clock.now(),
            &self.g1,
            &g2_observed,
        );
        self.eng.finalize_membership_stats(&mut stats);
        self.eng.finalize_memory_stats(&mut stats);
        stats.fault_events = std::mem::take(&mut self.win_fault_events);
        self.eng.emit_round_observation(&stats);
        self.eng.last_round = Some(stats.clone());
        self.emit_shard_barrier(&stats, t);
        self.window_start = t;
        if !self.draining {
            self.ctrl.schedule(
                t + self.mode.cloud_interval(),
                Event::CloudAggregate,
            );
        }
        Ok(stats)
    }

    /// Telemetry follow-through for `arena run --serve`: per-shard
    /// profile rows and the pool balance of this window, through the
    /// same `on_shard_barrier` path `ShardedDeviceSim` uses — so the
    /// dashboard's `arena_shard_*` series and sparklines show the real
    /// engine shard imbalance. Profiler-gated; drains the per-window
    /// wall counters either way so they never leak across windows.
    fn emit_shard_barrier(&mut self, stats: &RoundStats, t: f64) {
        let profile = self.eng.obs.is_some() && self.eng.cfg.sim.profiler;
        let wall = std::mem::take(&mut self.win_wall_ns);
        let n_shards = self.shards.len();
        if !profile {
            for b in self.win_shard_busy_ns.iter_mut() {
                *b = 0;
            }
            return;
        }
        let workers = self.eng.sim_workers().max(1).min(n_shards.max(1));
        let mut rows: Vec<ShardWindowProfile> =
            Vec::with_capacity(n_shards);
        let mut busy = vec![0u64; workers];
        let mut events = 0u64;
        let mut aggregates = 0u64;
        let mut faults = 0u64;
        let mut live = 0usize;
        for s in 0..n_shards {
            let mut p = self.shards[s].drain_profile();
            p.advance_wall_ns =
                std::mem::take(&mut self.win_shard_busy_ns[s]);
            // shard_scope pins shard s → lane s % workers.
            busy[s % workers] += p.advance_wall_ns;
            events += p.events;
            aggregates += p.aggregates;
            faults += p.outages + p.partitions + p.crashes;
            live += p.live_devices;
            rows.push(p);
        }
        fn mix(h: &mut u64, x: u64) {
            *h ^= x;
            *h = h.wrapping_mul(0x100_0000_01b3);
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        mix(&mut h, stats.round as u64);
        mix(&mut h, t.to_bits());
        mix(&mut h, events);
        mix(&mut h, aggregates);
        mix(&mut h, faults);
        mix(&mut h, live as u64);
        let row = WindowRow {
            window: stats.round,
            sim_time: t,
            events,
            live,
            loss: stats.train_loss,
            energy: stats.energy,
            aggregates,
            cloud_version: self.eng.cloud_w.version(),
            faults,
            checksum: h,
        };
        let pool = PoolWindowProfile {
            window: stats.round,
            t0_sim: self.window_start,
            t1_sim: t,
            workers,
            n_shards,
            window_wall_ns: wall,
            worker_busy_ns: busy,
        };
        if let Some(o) = self.eng.obs.as_mut() {
            o.on_shard_barrier(&row, &rows, &pool);
        }
    }

    /// The churn barrier: step the mobility model once, fan the flips
    /// out to their owning shards in parallel (purge reports, void
    /// in-flight work, rejoin + re-dispatch), re-derive semi-sync
    /// quorums, then replay in shard order. Zero churn ⇒ no flips, no
    /// actions, no draws — bitwise a no-op, as ever.
    fn flip_barrier(&mut self, t: f64) -> Result<()> {
        let flips = self.eng.mobility.step();
        self.eng.membership.observe(flips);
        // The model reports who flipped — no full active-vector re-scan.
        // The coordinator's mobility model stays the authority for
        // active state; shards hold per-device mirrors.
        let flipped: Vec<usize> = self.eng.mobility.flipped().to_vec();
        let n_shards = self.shards.len();
        let mut parts: Vec<Vec<(usize, bool)>> = vec![Vec::new(); n_shards];
        let mut rejoins: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        for &d in &flipped {
            let s = self.dev_shard[d];
            let active = self.eng.mobility.is_active(d);
            parts[s].push((d, active));
            if active {
                rejoins[s].push(d);
            }
        }
        let workers = self.eng.sim_workers();
        let logs = shard_scope(workers, &mut self.shards, |idx, sh| {
            sh.barrier_sweep(t);
            // A flipped device's pending report is void either way: a
            // leaver took its update with it, and a rejoiner restarts
            // from the edge model.
            for &(d, active) in &parts[idx] {
                sh.apply_flip(d, active);
            }
            if !rejoins[idx].is_empty() {
                sh.rejoin_devices(&rejoins[idx], t);
            }
            // Quorum liveness: a departure can shrink an edge's live set
            // to (or below) the reports already outstanding; without
            // this re-check the edge round could only close at the next
            // timer flush. Safe on every owned edge: a quorum that was
            // already satisfiable scheduled its close during the
            // advance, so only membership changes can newly satisfy one.
            for i in 0..sh.edges.len() {
                let j = sh.edges[i];
                sh.recheck_quorum(j, t);
            }
            sh.take_actions()
        });
        for (s, log) in logs.into_iter().enumerate() {
            self.replay_log(s, &log)?;
            self.shards[s].recycle(log);
        }
        // Membership drift check: re-cluster as a scheduled ctrl event
        // when the churn pushed drift past the threshold (O(1) gate
        // before the O(n) imbalance scan).
        if self.eng.membership.wants_check(t)
            && self.eng.membership.should_recluster(
                t,
                self.eng.cfg.topology.devices,
                self.eng.membership_imbalance(),
            )
        {
            self.ctrl.schedule(t, Event::Recluster);
        }
        self.ctrl
            .schedule(t + self.mode.cloud_interval(), Event::MobilityFlip);
        Ok(())
    }

    /// The re-clustering barrier: re-profile + re-cluster the live
    /// population (`HflEngine::recluster_core`), then migrate the
    /// running topology across shards — devices hand their shard state
    /// from source to destination shard (in-flight training absorbed by
    /// the tombstone protocol when the shards differ, voided in place
    /// when they don't), member lists refresh everywhere, semi-sync
    /// quorums re-derive, and each destination edge's model ships to
    /// its migrants as an in-flight downlink.
    fn recluster_barrier(&mut self, t: f64) -> Result<()> {
        let n = self.eng.cfg.topology.devices;
        // Re-check: the drift that scheduled this event may have been
        // handled already (duplicate trigger), or may no longer qualify.
        if !self.eng.membership.wants_check(t)
            || !self.eng.membership.should_recluster(
                t,
                n,
                self.eng.membership_imbalance(),
            )
        {
            return Ok(());
        }
        let t_wall = self
            .eng
            .obs
            .as_ref()
            .map(|_| std::time::Instant::now());
        let Some(out) = self.eng.recluster_core(t)? else {
            return Ok(()); // infeasible region split; retried on later flips
        };
        self.refresh_dev_edge();
        self.recluster_seq += 1;
        let seq = self.recluster_seq;
        let n_shards = self.shards.len();
        for sh in self.shards.iter_mut() {
            sh.barrier_sweep(t);
        }
        let mut by_dest: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &(d, _old, new) in &out.migrated {
            let src = self.dev_shard[d];
            let dst = EngineShard::shard_of(new, n_shards);
            if src == dst {
                // Same owner: the device entry moves edges in place (no
                // tombstone — the pending DeviceTrainDone still resolves
                // against the same heap).
                self.shards[src].migrate_local(d, new, seq);
            } else if let Some((active, version, cpu)) =
                self.shards[src].migrate_out(d, new, seq)
            {
                self.shards[dst].migrate_in(d, new, active, version, seq, cpu);
            }
            self.dev_shard[d] = dst;
            by_dest.entry(new).or_default().push(d);
        }
        // Refresh every edge's member list from the re-clustered
        // topology (cohort selection and quorum denominators read it).
        for j in 0..self.edges() {
            let s = EngineShard::shard_of(j, n_shards);
            self.shards[s]
                .install_edge(j, self.eng.topo.edges[j].members.clone());
        }
        // Warm-start delivery: one downlink per destination edge,
        // carrying its model snapshot for all its migrants. The snapshot
        // is an rc-share — copy-on-write preserves it if the edge
        // aggregates while the downlink is in flight.
        for (edge, devices) in by_dest {
            let s = EngineShard::shard_of(edge, n_shards);
            if let Some(id) =
                self.shards[s].start_migration(edge, devices, seq, t)
            {
                let r = self.eng.store.share(&self.eng.edge_w[edge]);
                self.payloads.insert((s, id), Payload::Migration { r });
            }
        }
        // Re-derive semi-sync quorums against the new membership: an
        // edge that lost members may now satisfy its (live-clamped)
        // quorum with the reports it already holds.
        let mut hit: Vec<usize> = out
            .migrated
            .iter()
            .flat_map(|&(_, old, new)| [old, new])
            .collect();
        hit.sort_unstable();
        hit.dedup();
        for j in hit {
            let s = EngineShard::shard_of(j, n_shards);
            self.shards[s].recheck_quorum(j, t);
        }
        for s in 0..n_shards {
            let log = self.shards[s].take_actions();
            self.replay_log(s, &log)?;
            self.shards[s].recycle(log);
        }
        if let Some(o) = self.eng.obs.as_mut() {
            let wall_ns = t_wall
                .map(|i| i.elapsed().as_nanos() as u64)
                .unwrap_or(0);
            o.on_recluster(t, out.migrated.len(), wall_ns);
        }
        self.eng.last_recluster = Some(out);
        Ok(())
    }

    /// `Event::EdgeOutage`: sever (down) or restore (up) one edge
    /// aggregator — a single-shard barrier. Down, the edge's pending
    /// reports die with it and all in-flight member work is voided
    /// (stale-result protocol); members idle until recovery. Up, live
    /// idle members warm-restart from the edge's current model.
    fn outage_barrier(&mut self, edge: usize, up: bool, t: f64) -> Result<()> {
        self.win_fault_events += 1;
        let s = EngineShard::shard_of(edge, self.shards.len());
        self.shards[s].barrier_sweep(t);
        let changed = self.shards[s].apply_outage(edge, up, t);
        if changed {
            if let Some(o) = self.eng.obs.as_mut() {
                o.on_fault(if up { "recovery" } else { "outage" });
            }
        }
        let log = self.shards[s].take_actions();
        self.replay_log(s, &log)?;
        self.shards[s].recycle(log);
        Ok(())
    }

    /// `Event::Partition`: sever (down) or heal (up) the cloud links of
    /// every edge whose bit is set in `mask` (edge `j` maps to bit
    /// `j % 64`). Partitioned edges keep training and aggregating
    /// locally — only their uplink/downlink to the cloud is blocked, so
    /// the cloud ages them (staleness grows) until the heal. Pure flag
    /// flips; no shard emits actions.
    fn partition_barrier(&mut self, mask: u64, up: bool) {
        self.win_fault_events += 1;
        let mut touched = 0usize;
        for sh in self.shards.iter_mut() {
            touched += sh.apply_partition(mask, up);
        }
        if touched > 0 {
            if let Some(o) = self.eng.obs.as_mut() {
                o.on_fault(if up { "recovery" } else { "partition" });
            }
        }
    }

    /// `Event::CrashStorm`: crash the storm's device set, or revive it
    /// `fault.rejoin_delay` later. Membership is the pure predicate
    /// `lifecycle::storm_hits(seed, device, frac_bits)` — no draws, so
    /// every shard recomputes exactly the same subset of its own devices
    /// in parallel and the storm is identical at any worker count. The
    /// changed lists sync the coordinator's mobility model back at the
    /// merge (fixed shard order), keeping it the single authority that
    /// `edge_availability` and the next re-cluster read.
    fn storm_barrier(
        &mut self,
        storm: u64,
        frac_bits: u32,
        up: bool,
        t: f64,
    ) -> Result<()> {
        self.win_fault_events += 1;
        let workers = self.eng.sim_workers();
        let results = shard_scope(workers, &mut self.shards, |_idx, sh| {
            sh.barrier_sweep(t);
            let changed = sh.apply_crash_storm(storm, frac_bits, up, t);
            (sh.take_actions(), changed)
        });
        let mut any = false;
        for (s, (log, changed)) in results.into_iter().enumerate() {
            for &d in &changed {
                self.eng.mobility.set_active(d, up);
            }
            any = any || !changed.is_empty();
            self.replay_log(s, &log)?;
            self.shards[s].recycle(log);
        }
        if any {
            if let Some(o) = self.eng.obs.as_mut() {
                o.on_fault(if up { "recovery" } else { "crash" });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SyncConfig;

    #[test]
    fn mode_from_config() {
        assert_eq!(
            SyncMode::from_config(&SyncConfig::default()),
            SyncMode::Synchronous
        );
        let sc = SyncConfig {
            mode: SyncModeCfg::SemiSync,
            quorum: 3,
            staleness_alpha: 0.7,
            cloud_interval: 90.0,
            ..SyncConfig::default()
        };
        assert_eq!(
            SyncMode::from_config(&sc),
            SyncMode::SemiSync {
                quorum: 3,
                cloud_interval: 90.0
            }
        );
        let sc = SyncConfig {
            mode: SyncModeCfg::Async,
            ..sc
        };
        match SyncMode::from_config(&sc) {
            SyncMode::Async {
                staleness_alpha,
                cloud_interval,
            } => {
                assert!((staleness_alpha - 0.7).abs() < 1e-12);
                assert!((cloud_interval - 90.0).abs() < 1e-12);
            }
            other => panic!("wrong mode {other:?}"),
        }
    }

    #[test]
    fn mode_names() {
        assert_eq!(SyncMode::Synchronous.name(), "synchronous");
        assert_eq!(
            SyncMode::SemiSync {
                quorum: 2,
                cloud_interval: 1.0
            }
            .name(),
            "semi-sync"
        );
        assert_eq!(
            SyncMode::Async {
                staleness_alpha: 0.5,
                cloud_interval: 1.0
            }
            .name(),
            "async"
        );
    }

    #[test]
    fn effective_quorum_clamps() {
        assert_eq!(effective_quorum(3, 5), 3);
        assert_eq!(effective_quorum(3, 2), 2);
        assert_eq!(effective_quorum(0, 4), 4);
        assert_eq!(effective_quorum(0, 0), 1);
        assert_eq!(effective_quorum(3, 0), 1);
    }

    #[test]
    fn quorum_clamps_to_live_membership() {
        // Plain quorum against a healthy edge.
        assert!(!quorum_satisfied(2, 3, 5));
        assert!(quorum_satisfied(3, 3, 5));
        // quorum 0 = "all live members".
        assert!(!quorum_satisfied(3, 0, 4));
        assert!(quorum_satisfied(4, 0, 4));
        // The liveness regression: membership shrank below the configured
        // quorum while 2 reports were outstanding — the round must be
        // closable with what is still alive.
        assert!(quorum_satisfied(2, 3, 2));
        assert!(quorum_satisfied(1, 3, 1));
        // Even a fully-departed edge (live = 0 clamps to 1) closes on one
        // outstanding report rather than deadlocking.
        assert!(quorum_satisfied(1, 3, 0));
        assert!(!quorum_satisfied(0, 3, 0));
    }
}
