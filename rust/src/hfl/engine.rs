//! The HFL synchronization executor.

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::obs::Observer;
use crate::pca::PcaModel;
use crate::runtime::{pool::TrainJob, DevicePool, HostTensor, Runtime};
use crate::sim::{
    AvailabilityModel, CpuModel, Direction, EnergyModel, LinkManager,
    MobilityModel, NetworkModel, SimClock,
};
use crate::util::rng::Rng;
use crate::util::threadpool::par_for_each;

use super::aggregate::aggregate_native_auto;
use super::membership::{self, MembershipTracker, ReclusterOutcome};
use super::metrics::{RoundAccumulator, RoundStats};
use super::model_store::{ModelRef, ModelStore};
use super::topology::{build_topology, Topology};
use crate::runtime::pool::TrainResult;
use crate::sim::Region;

pub struct HflEngine {
    pub cfg: ExperimentConfig,
    /// Main-thread runtime: eval / aggregate / pca_project artifacts.
    pub rt: Runtime,
    pool: DevicePool,
    pub topo: Topology,
    pub clock: SimClock,
    pub energy_model: EnergyModel,
    pub net: NetworkModel,
    /// Per-edge uplink/downlink transfer scheduling (`sim::link`); all
    /// edge↔cloud communication of both engines routes through it.
    pub links: LinkManager,
    pub mobility: MobilityModel,
    /// Membership subsystem: drift tracking + churn-driven re-clustering
    /// policy (`hfl::membership`, `cluster.*` config knobs).
    pub membership: MembershipTracker,
    /// Outcome of the most recent re-clustering, if any ran this run.
    pub last_recluster: Option<ReclusterOutcome>,
    /// Diurnal availability windows (`lifecycle.pace_day` > 0): pace
    /// steering's substrate. `None` keeps every selection path bitwise
    /// identical to the pre-lifecycle engine — the model draws from its
    /// own stream (`seed ^ 0xd1a1`) only at construction, never during
    /// a run.
    pub avail: Option<AvailabilityModel>,
    rng: Rng,
    /// Flat model parameter count.
    pub p: usize,
    /// Shared ownership layer: every model buffer in the system lives
    /// here, and the `*_w` fields below are version-tagged handles into
    /// it (`hfl::model_store`) — broadcast and edge→device sync are O(1)
    /// handle re-points, mutation is copy-on-write.
    pub store: ModelStore,
    pub cloud_w: ModelRef,
    pub edge_w: Vec<ModelRef>,
    pub device_w: Vec<ModelRef>,
    init_w: Vec<f32>,
    /// Worker count for the chunked native aggregation (`cfg.workers`
    /// as resolved by the device pool).
    agg_workers: usize,
    test_x: HostTensor,
    test_y: HostTensor,
    pub round: usize,
    pub total_energy: f64,
    pub last_round: Option<RoundStats>,
    /// Optional run instrumentation (`crate::obs`). Hooks only read;
    /// engines gate every wall-clock read on `obs.is_some()`, so a run
    /// with an observer attached stays bitwise identical to one without
    /// (the observer-noop determinism guarantee).
    pub(crate) obs: Option<Box<dyn Observer>>,
}

impl HflEngine {
    pub fn new(cfg: ExperimentConfig, use_profiling: bool) -> Result<Self> {
        let mut rng = Rng::new(cfg.seed);
        let ds = cfg.hfl.dataset.name();
        let eval_art = format!("{ds}_eval");
        let agg_art = format!("{ds}_aggregate");
        let pca_art = format!("{ds}_pca_project");
        let mut rt = Runtime::load(
            &cfg.artifacts_dir,
            &[eval_art.as_str(), agg_art.as_str(), pca_art.as_str()],
        )?;
        // Pre-compile any n_PCA ablation variants present in the manifest
        // (pca_scores is &self and cannot compile lazily).
        let variants: Vec<String> = rt
            .manifest
            .artifacts
            .keys()
            .filter(|k| k.starts_with(&format!("{pca_art}_npca")))
            .cloned()
            .collect();
        for v in &variants {
            rt.compile(v)?;
        }
        rt.manifest.validate_config(&cfg)?;
        let topo = build_topology(&cfg, use_profiling, &mut rng)?;
        let pool = DevicePool::new(
            cfg.workers,
            &cfg.artifacts_dir,
            ds,
            topo.shards.clone(),
        )?;
        let p = rt.manifest.param_count(ds)?;
        let init_w = rt.load_init_params(ds)?;
        // Test set, shaped for the eval artifact.
        let ts = rt.manifest.config.test_size;
        let (tx, ty) = topo.dataset.test_set(ts, cfg.seed ^ 0x7e57);
        let [h, w_, c] = topo.dataset.shape();
        let test_x = HostTensor::f32(vec![ts, h, w_, c], tx);
        let test_y = HostTensor::i32(vec![ts], ty);
        let m = cfg.topology.edges;
        let n = cfg.topology.devices;
        let energy_model =
            EnergyModel::new(cfg.sim.power_idle, cfg.sim.power_max);
        let net = NetworkModel::from_config(&cfg.sim);
        let links = LinkManager::new(m, cfg.link.contention);
        let mobility = MobilityModel::from_config(n, &cfg.sim, cfg.seed);
        let membership =
            MembershipTracker::from_config(&cfg.cluster, cfg.seed);
        let avail = if cfg.lifecycle.pace_day > 0.0 {
            Some(AvailabilityModel::new(
                n,
                cfg.lifecycle.pace_day,
                cfg.lifecycle.avail_frac,
                cfg.seed,
            ))
        } else {
            None
        };
        // One buffer serves the whole hierarchy at startup: cloud, edges
        // and devices are all shares of the same init model (was: N+M+1
        // full clones — the O(N·p) wall this store breaks).
        let mut store = ModelStore::new(p);
        let cloud_w = store.insert(init_w.clone(), 0);
        let edge_w: Vec<ModelRef> =
            (0..m).map(|_| store.share(&cloud_w)).collect();
        let device_w: Vec<ModelRef> =
            (0..n).map(|_| store.share(&cloud_w)).collect();
        let agg_workers = pool.workers();
        Ok(HflEngine {
            p,
            store,
            cloud_w,
            edge_w,
            device_w,
            init_w,
            agg_workers,
            test_x,
            test_y,
            rt,
            pool,
            topo,
            clock: SimClock::new(),
            energy_model,
            net,
            links,
            mobility,
            membership,
            last_recluster: None,
            avail,
            rng,
            round: 0,
            total_energy: 0.0,
            last_round: None,
            obs: None,
            cfg,
        })
    }

    /// Attach run instrumentation. The observer only ever reads —
    /// attaching one must not change any simulated outcome (asserted by
    /// the `observer_attach_is_bitwise_noop` integration test).
    pub fn attach_observer(&mut self, obs: Box<dyn Observer>) {
        self.obs = Some(obs);
    }

    /// Detach and return the current observer, if any.
    pub fn detach_observer(&mut self) -> Option<Box<dyn Observer>> {
        self.obs.take()
    }

    /// Reset models/clock/energy for a fresh run (new DRL episode or new
    /// scheme comparison) while keeping data, clusters and CPU states.
    pub fn reset(&mut self) {
        // Rebuild the whole hierarchy as shares of one fresh init buffer
        // (live model buffers drop back to 1; version tags to 0).
        let fresh = self.store.insert(self.init_w.clone(), 0);
        self.store.adopt(&mut self.cloud_w, fresh);
        for e in self.edge_w.iter_mut() {
            self.store.repoint(e, &self.cloud_w);
        }
        for d in self.device_w.iter_mut() {
            self.store.repoint(d, &self.cloud_w);
        }
        self.clock.reset();
        self.links.reset();
        self.membership.reset();
        self.last_recluster = None;
        self.round = 0;
        self.total_energy = 0.0;
        self.last_round = None;
    }

    pub fn edges(&self) -> usize {
        self.cfg.topology.edges
    }

    pub fn remaining_time(&self) -> f64 {
        self.cfg.hfl.threshold_time - self.clock.now()
    }

    /// Weighted aggregation (Eq. 1/2): through the fedavg_reduce Pallas
    /// artifact by default, or natively in rust when
    /// `cfg.native_aggregation` is set (§Perf: interpret-mode Pallas is
    /// emulated on CPU; the native loop is the roofline there).
    pub fn aggregate(
        &self,
        models: &[&[f32]],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        if self.cfg.native_aggregation {
            return Ok(aggregate_native_auto(
                models,
                weights,
                self.p,
                self.agg_workers,
            ));
        }
        let nmax = self.rt.manifest.config.nmax;
        anyhow::ensure!(
            models.len() <= nmax && models.len() == weights.len(),
            "aggregate: {} models vs nmax {nmax}",
            models.len()
        );
        let mut flat = vec![0.0f32; nmax * self.p];
        for (i, m) in models.iter().enumerate() {
            anyhow::ensure!(m.len() == self.p, "model {i} wrong size");
            flat[i * self.p..(i + 1) * self.p].copy_from_slice(m);
        }
        let mut w = vec![0.0f32; nmax];
        w[..weights.len()].copy_from_slice(weights);
        let art = format!("{}_aggregate", self.cfg.hfl.dataset.name());
        let out = self.rt.execute(
            &art,
            &[
                HostTensor::f32(vec![nmax, self.p], flat),
                HostTensor::f32(vec![nmax], w),
            ],
        )?;
        out.into_iter()
            .next()
            .context("aggregate produced no output")?
            .into_f32()
    }

    /// Evaluate the cloud model on the held-out test set -> (acc, loss).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        self.evaluate_model(self.store.slice(&self.cloud_w))
    }

    /// The current cloud model, resolved through the store (the boundary
    /// accessor for tests / examples / experiment code).
    pub fn cloud_model(&self) -> &[f32] {
        self.store.slice(&self.cloud_w)
    }

    /// Resolve any model handle to its buffer.
    pub fn model(&self, r: &ModelRef) -> &[f32] {
        self.store.slice(r)
    }

    pub fn evaluate_model(&self, w: &[f32]) -> Result<(f64, f64)> {
        let art = format!("{}_eval", self.cfg.hfl.dataset.name());
        let out = self.rt.execute(
            &art,
            &[
                HostTensor::f32(vec![self.p], w.to_vec()),
                self.test_x.clone(),
                self.test_y.clone(),
            ],
        )?;
        let correct = out[0].scalar()?;
        let loss = out[1].scalar()?;
        let acc = correct / self.test_x.shape[0] as f64;
        Ok((acc, loss))
    }

    /// Project [cloud; edges] models onto PCA loadings via the artifact.
    pub fn pca_scores(&self, pca: &PcaModel) -> Result<Vec<Vec<f32>>> {
        let m = self.edges();
        let rows = m + 1;
        let mut flat = Vec::with_capacity(rows * self.p);
        flat.extend_from_slice(self.store.slice(&self.cloud_w));
        for e in &self.edge_w {
            flat.extend_from_slice(self.store.slice(e));
        }
        let npca = pca.npca;
        let suffix = crate::agent::ppo::npca_suffix(
            self.rt.manifest.config.npca,
            npca,
        );
        let art =
            format!("{}_pca_project{suffix}", self.cfg.hfl.dataset.name());
        let out = self.rt.execute(
            &art,
            &[
                HostTensor::f32(vec![rows, self.p], flat),
                HostTensor::f32(vec![self.p, npca], pca.loadings.clone()),
            ],
        )?;
        let scores = out
            .into_iter()
            .next()
            .context("pca_project produced no output")?
            .into_f32()?;
        Ok(scores.chunks(npca).map(|c| c.to_vec()).collect())
    }

    /// Stack of current [cloud; edge] models (PCA fitting), resolved to
    /// slices at the boundary.
    pub fn model_stack(&self) -> Vec<&[f32]> {
        let mut v: Vec<&[f32]> = vec![self.store.slice(&self.cloud_w)];
        v.extend(self.edge_w.iter().map(|e| self.store.slice(e)));
        v
    }

    // -----------------------------------------------------------------
    // Shared round primitives. Both this engine's barrier-style
    // `run_round` and `AsyncHflEngine`'s event-driven loop are built from
    // these; they consume the RNG streams in identical order so the two
    // engines agree bit-for-bit in synchronous mode.
    // -----------------------------------------------------------------

    /// Deterministic per-(round, device) training seed.
    pub(crate) fn fork_job_seed(&mut self, device: usize) -> u64 {
        self.rng
            .fork(((self.round as u64) << 20) ^ device as u64)
            .next_u64()
    }

    /// Whether `device` trains this round (mobility + participation mask
    /// + availability window). A lock-step barrier cannot *defer* a
    /// dispatch the way the event loop does, so pace steering here is
    /// selection at the round boundary: an out-of-window device sits the
    /// round out and rejoins when its diurnal window and a later round
    /// line up. Availability is read at the frozen round-start clock, so
    /// every sub-round of one round sees the same answer.
    pub(crate) fn trains_this_round(
        &self,
        device: usize,
        participation: Option<&[bool]>,
    ) -> bool {
        self.mobility.is_active(device)
            && participation.map(|p| p[device]).unwrap_or(true)
            && self
                .avail
                .as_ref()
                .map(|a| a.is_available(device, self.clock.now()))
                .unwrap_or(true)
    }

    /// Edge `j`'s members that train this round, in member order.
    pub(crate) fn edge_participants(
        &self,
        j: usize,
        participation: Option<&[bool]>,
    ) -> Vec<usize> {
        self.topo.edges[j]
            .members
            .iter()
            .copied()
            .filter(|&d| self.trains_this_round(d, participation))
            .collect()
    }

    /// Gather the training jobs of sub-round `sub` in canonical
    /// (edge-major, member-order) sequence; returns (jobs, owning edge per
    /// job). Seed forks happen here, in this exact order.
    // Index loops: the body forks the engine RNG (&mut self), which an
    // iterator borrow over `self.topo` would lock out.
    #[allow(clippy::needless_range_loop)]
    pub(crate) fn gather_jobs(
        &mut self,
        sub: usize,
        gamma1: &[usize],
        gamma2: &[usize],
        participation: Option<&[bool]>,
    ) -> (Vec<TrainJob>, Vec<usize>) {
        let mut jobs = Vec::new();
        let mut job_edges = Vec::new();
        for j in 0..self.topo.edges.len() {
            if sub >= gamma2[j] {
                continue;
            }
            for idx in 0..self.topo.edges[j].members.len() {
                let dev = self.topo.edges[j].members[idx];
                if !self.trains_this_round(dev, participation) {
                    continue;
                }
                jobs.push(TrainJob {
                    device: dev,
                    // The worker pool needs an owned buffer (Send); this
                    // is the one place a training device materializes.
                    w: self.store.slice(&self.device_w[dev]).to_vec(),
                    epochs: gamma1[j],
                    seed: self.fork_job_seed(dev),
                });
                job_edges.push(j);
            }
        }
        (jobs, job_edges)
    }

    /// Run a batch of jobs over the worker pool (results in job order).
    pub(crate) fn train_batch(
        &mut self,
        jobs: Vec<TrainJob>,
    ) -> Result<Vec<TrainResult>> {
        self.pool.train(jobs)
    }

    /// Adopt a trained model for `dev`, keeping its version tag (the
    /// barrier training paths; the event engine instead parks trained
    /// results in the store at dispatch and adopts the handle at the
    /// simulated completion). The device's previous buffer returns to
    /// the pool unless shared.
    pub(crate) fn commit_device(&mut self, dev: usize, w: Vec<f32>) {
        let version = self.device_w[dev].version();
        let r = self.store.insert(w, version);
        self.store.adopt(&mut self.device_w[dev], r);
    }

    /// One rc-share per edge handle, in edge order — the event engine's
    /// cloud-side landed view starts as exactly this.
    pub(crate) fn share_edge_handles(&mut self) -> Vec<ModelRef> {
        let mut v = Vec::with_capacity(self.edge_w.len());
        for e in &self.edge_w {
            v.push(self.store.share(e));
        }
        v
    }

    /// Commit a freshly aggregated cloud model (cloud version advances
    /// by one — the cloud handle's tag counts cloud aggregations).
    pub(crate) fn commit_cloud(&mut self, w: Vec<f32>) {
        let version = self.cloud_w.version() + 1;
        let r = self.store.insert(w, version);
        self.store.adopt(&mut self.cloud_w, r);
    }

    /// Advance the cloud version without a new model (a cloud decision
    /// point where nothing had landed — the window still counts).
    pub(crate) fn bump_cloud_version(&mut self) {
        self.cloud_w.bump_version();
    }

    /// Simulated (time, energy) of `epochs` local epochs on `device`,
    /// advancing the device's CPU state.
    pub(crate) fn simulate_train(
        &mut self,
        device: usize,
        epochs: usize,
    ) -> (f64, f64) {
        let nb = self.rt.manifest.config.nb;
        simulate_device(
            &mut self.topo.cpus[device],
            &self.energy_model,
            nb,
            epochs,
        )
    }

    /// Effective worker count for the parallel *simulation* paths:
    /// `sim.workers`, with 0 meaning all available cores. Distinct from
    /// `cfg.workers` (the real-compute training pool).
    pub(crate) fn sim_workers(&self) -> usize {
        match self.cfg.sim.workers {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            w => w,
        }
    }

    /// Simulated (time, energy) for a batch of `(device, epochs)`
    /// requests, in request order. Bit-identical to calling
    /// [`Self::simulate_train`] once per request — in any order, at any
    /// `sim.workers` — because every `CpuModel` draws from its own RNG
    /// stream, so per-device draw sequences are independent of
    /// scheduling. Devices must be distinct within one batch.
    ///
    /// With an observer attached and `sim.profiler` on, the batch's
    /// wall time lands in `Observer::on_sim_batch` — the wall-clock
    /// read is gated exactly like every other profiler read, so
    /// profiler-on stays bitwise identical to profiler-off.
    pub(crate) fn simulate_train_batch(
        &mut self,
        reqs: &[(usize, usize)],
    ) -> Vec<(f64, f64)> {
        let t0 = if self.obs.is_some() && self.cfg.sim.profiler {
            Some(std::time::Instant::now())
        } else {
            None
        };
        let out = self.simulate_train_batch_inner(reqs);
        if let Some(t0) = t0 {
            if !reqs.is_empty() {
                let workers = self.sim_workers();
                let wall = t0.elapsed().as_nanos() as u64;
                if let Some(obs) = self.obs.as_mut() {
                    obs.on_sim_batch(reqs.len(), workers, wall);
                }
            }
        }
        out
    }

    fn simulate_train_batch_inner(
        &mut self,
        reqs: &[(usize, usize)],
    ) -> Vec<(f64, f64)> {
        let workers = self.sim_workers();
        if workers <= 1 || reqs.len() <= 1 {
            return reqs
                .iter()
                .map(|&(d, e)| self.simulate_train(d, e))
                .collect();
        }
        let nb = self.rt.manifest.config.nb;
        // Request index per device, to pair each `&mut CpuModel` from a
        // single `iter_mut` pass with its output slot.
        let mut req_of: Vec<Option<usize>> =
            vec![None; self.topo.cpus.len()];
        for (i, &(d, _)) in reqs.iter().enumerate() {
            debug_assert!(
                req_of[d].is_none(),
                "duplicate device {d} in simulate_train_batch"
            );
            req_of[d] = Some(i);
        }
        let mut out = vec![(0.0, 0.0); reqs.len()];
        {
            let mut slots: Vec<Option<&mut (f64, f64)>> =
                out.iter_mut().map(Some).collect();
            let energy = &self.energy_model;
            let mut items: Vec<(&mut CpuModel, usize, &mut (f64, f64))> =
                Vec::with_capacity(reqs.len());
            for (d, cpu) in self.topo.cpus.iter_mut().enumerate() {
                if let Some(i) = req_of[d] {
                    items.push((cpu, reqs[i].1, slots[i].take().unwrap()));
                }
            }
            par_for_each(workers, items, |(cpu, epochs, slot)| {
                *slot = simulate_device(cpu, energy, nb, epochs);
            });
        }
        out
    }

    /// Aggregate `devs`' models (data-size weighted, member order) into
    /// edge `j`'s model and sync it to all the edge's devices. The sync
    /// is O(1) per member — every device handle re-points to the shared
    /// edge buffer (rc bump) instead of receiving a p-float memcpy — and
    /// the edge's version tag advances by one.
    pub(crate) fn edge_aggregate_devices(
        &mut self,
        j: usize,
        devs: &[usize],
    ) -> Result<()> {
        let agg = {
            let mut models = Vec::new();
            let mut weights = Vec::new();
            for &dev in devs {
                models.push(self.store.slice(&self.device_w[dev]));
                weights.push(self.topo.shards[dev].n as f32);
            }
            self.aggregate(&models, &weights)?
        };
        let version = self.edge_w[j].version() + 1;
        let r = self.store.insert(agg, version);
        self.store.adopt(&mut self.edge_w[j], r);
        for &dev in &self.topo.edges[j].members {
            self.store.repoint(&mut self.device_w[dev], &self.edge_w[j]);
        }
        Ok(())
    }

    /// Blend device `dev`'s model into edge `j`'s with weight `beta`
    /// (asynchronous staleness-discounted update; paper-external, after
    /// arXiv:2107.11415). Copy-on-write: sharers of the edge buffer —
    /// device handles, in-flight upload payloads, the cloud's landed
    /// view — keep the pre-mix values.
    pub(crate) fn mix_device_into_edge(
        &mut self,
        j: usize,
        dev: usize,
        beta: f32,
    ) {
        self.store.mix_into(&mut self.edge_w[j], &self.device_w[dev], beta);
    }

    /// Total training-data size under edge `j` (all members).
    pub(crate) fn edge_data_weight(&self, j: usize) -> f32 {
        self.topo.edges[j]
            .members
            .iter()
            .map(|&d| self.topo.shards[d].n as f32)
            .sum()
    }

    /// The cloud-aggregation weight of each listed edge: its data size
    /// times an optional extra factor (e.g. a staleness discount). The
    /// single home of the cloud weighting policy — both engines' cloud
    /// aggregations go through this.
    pub(crate) fn cloud_weights(
        &self,
        edges: &[usize],
        factors: Option<&[f32]>,
    ) -> Vec<f32> {
        edges
            .iter()
            .enumerate()
            .map(|(i, &j)| {
                let mut w = self.edge_data_weight(j);
                if let Some(f) = factors {
                    w *= f[i];
                }
                w
            })
            .collect()
    }

    /// Cloud aggregation over the listed edges (data-size weighted, with
    /// optional per-edge extra factors, e.g. staleness discounts). The
    /// cloud version advances by one either way — an empty round still
    /// counts as a decision point.
    pub(crate) fn cloud_aggregate_edges(
        &mut self,
        edges: &[usize],
        factors: Option<&[f32]>,
    ) -> Result<()> {
        if edges.is_empty() {
            self.bump_cloud_version();
            return Ok(());
        }
        let weights = self.cloud_weights(edges, factors);
        let agg = {
            let models: Vec<&[f32]> = edges
                .iter()
                .map(|&j| self.store.slice(&self.edge_w[j]))
                .collect();
            self.aggregate(&models, &weights)?
        };
        self.commit_cloud(agg);
        Ok(())
    }

    /// Broadcast the global model everywhere: every edge and device
    /// handle re-points to the one cloud buffer (rc bumps — O(1) per
    /// receiver, the copy that used to cost O(N·p)). Handles keep their
    /// own version tags: adopting a broadcast is not an aggregation on
    /// the receiving line.
    pub(crate) fn broadcast_cloud(&mut self) {
        for e in self.edge_w.iter_mut() {
            self.store.repoint_keep_version(e, &self.cloud_w);
        }
        for d in self.device_w.iter_mut() {
            self.store.repoint_keep_version(d, &self.cloud_w);
        }
    }

    /// Sample the exclusive-link work (seconds) of one `dir`-direction
    /// model transfer for `region`, from the engine's main RNG stream.
    pub(crate) fn sample_one_way(
        &mut self,
        region: Region,
        dir: Direction,
    ) -> f64 {
        let pbytes = crate::sim::network::model_bytes(self.p);
        let scale = match dir {
            Direction::Up => self.cfg.link.up_bandwidth_scale,
            Direction::Down => self.cfg.link.down_bandwidth_scale,
        };
        self.net.one_way_time(region, pbytes, scale, &mut self.rng)
    }

    /// The barrier round's communication tail through the link layer:
    /// every edge uploads its model when its compute finishes
    /// (`edge_compute[j]`, round-relative), the cloud aggregates when the
    /// *last* upload lands — the degenerate no-overlap case of the
    /// transfer layer — and the downlink broadcast departs then, landing
    /// during the start of the next round (charged to stats, not to the
    /// barrier). Returns the round duration. Both engines call this
    /// helper, consuming identical RNG draws in identical order, which is
    /// what keeps Synchronous mode bit-for-bit equal between them.
    pub(crate) fn sync_comm_phase(
        &mut self,
        edge_compute: &[f64],
        acc: &mut RoundAccumulator,
    ) -> f64 {
        let m = self.edges();
        let pbytes = crate::sim::network::model_bytes(self.p);
        self.links.begin_round();
        let mut up_dur = vec![0.0f64; m];
        let mut t_cloud = 0.0f64;
        for j in 0..m {
            let region = self.topo.edges[j].region;
            let work = self.sample_one_way(region, Direction::Up);
            let (id, resched) =
                self.links
                    .start(j, Direction::Up, pbytes, work, edge_compute[j]);
            // One transfer per per-edge uplink under the barrier: its
            // first prediction is final.
            debug_assert_eq!(resched.len(), 1);
            let finish = resched[0].1;
            let (tr, _) = self
                .links
                .poll(id, finish)
                .expect("uncontended upload lands at its prediction");
            up_dur[j] = tr.finish - tr.start;
            if tr.finish > t_cloud {
                t_cloud = tr.finish;
            }
        }
        for j in 0..m {
            let region = self.topo.edges[j].region;
            let work = self.sample_one_way(region, Direction::Down);
            let (id, resched) =
                self.links.start(j, Direction::Down, pbytes, work, t_cloud);
            debug_assert_eq!(resched.len(), 1);
            let finish = resched[0].1;
            let (tr, _) = self
                .links
                .poll(id, finish)
                .expect("uncontended downlink lands at its prediction");
            acc.record_link(
                j,
                up_dur[j],
                tr.finish - tr.start,
                edge_compute[j],
            );
        }
        t_cloud
    }

    // -----------------------------------------------------------------
    // Membership subsystem (hfl::membership): churn-driven re-clustering.
    // -----------------------------------------------------------------

    /// Live (mobility-active) member count per edge.
    pub(crate) fn live_per_edge(&self) -> Vec<usize> {
        self.topo
            .edges
            .iter()
            .map(|e| {
                e.members
                    .iter()
                    .filter(|&&d| self.mobility.is_active(d))
                    .count()
            })
            .collect()
    }

    /// The drift-relevant live imbalance: worst per-region edge-size
    /// spread (what a region-constrained re-cluster can repair).
    pub(crate) fn membership_imbalance(&self) -> f64 {
        let edge_regions: Vec<Region> =
            self.topo.edges.iter().map(|e| e.region).collect();
        membership::region_imbalance(&self.live_per_edge(), &edge_regions)
    }

    /// Re-profile the live population and apply a region-constrained
    /// balanced re-clustering to the topology. Shared by the barrier path
    /// and the event engine (which layers live migration on top). Does
    /// NOT touch device models — warm-starting is engine-specific.
    /// Returns `None` (drift kept, retried later) when some region has
    /// fewer live devices than edges.
    pub(crate) fn recluster_core(
        &mut self,
        at: f64,
    ) -> Result<Option<ReclusterOutcome>> {
        let live = self.mobility.active_set();
        let edge_regions: Vec<Region> =
            self.topo.edges.iter().map(|e| e.region).collect();
        // Cheap feasibility gate before paying the profiling pass:
        // plan_recluster would decline anyway, and profiling advances
        // every live device's CPU state as a side effect — a failed
        // attempt must not perturb later training times.
        if !membership::plan_is_feasible(
            &live,
            &self.topo.device_regions,
            &edge_regions,
        ) {
            return Ok(None);
        }
        let mut current = vec![0usize; self.cfg.topology.devices];
        for (j, e) in self.topo.edges.iter().enumerate() {
            for &d in &e.members {
                current[d] = j;
            }
        }
        // Fresh profiling pass over the live devices (the paper's §3.1
        // profiling task, advancing each device's CPU state).
        let features: Vec<Vec<f64>> = live
            .iter()
            .map(|&d| {
                crate::cluster::profiling::profile_device(
                    &mut self.topo.cpus[d],
                    &self.energy_model,
                    30,
                )
                .as_vec()
            })
            .collect();
        let Some(plan) = membership::plan_recluster(
            &live,
            &features,
            &self.topo.device_regions,
            &edge_regions,
            &current,
            &mut self.membership.rng,
        ) else {
            return Ok(None);
        };
        self.topo.set_assignment(&plan.assignment);
        self.membership.record_recluster(at, plan.migrated.len());
        Ok(Some(ReclusterOutcome {
            at,
            migrated: plan.migrated,
            live: plan.live,
            mse: plan.mse,
            migration_downlink_time: 0.0,
        }))
    }

    /// Between-cloud-rounds re-clustering for the barrier engine (also
    /// the event engine's synchronous mode — both call this right after
    /// the mobility step, consuming identical RNG draws, which preserves
    /// their bit-for-bit equivalence). Migrated devices warm-start from
    /// their new edge's current model, delivered as downlink transfers
    /// through the link layer; the clock advances by the straggler
    /// landing, each delivery is charged to `acc`'s link stats, and the
    /// caller extends the round's duration by
    /// `ReclusterOutcome::migration_downlink_time`.
    pub(crate) fn maybe_recluster_barrier(
        &mut self,
        acc: &mut RoundAccumulator,
    ) -> Result<Option<ReclusterOutcome>> {
        let now = self.clock.now();
        // O(1) gate first; the imbalance term costs an O(n) membership
        // scan and is only worth computing once drift exists at all.
        if !self.membership.wants_check(now)
            || !self.membership.should_recluster(
                now,
                self.cfg.topology.devices,
                self.membership_imbalance(),
            )
        {
            return Ok(None);
        }
        // Wall-clock is read only when an observer is attached, and only
        // flows into the observer record — never into sim state.
        let t_wall = self.obs.as_ref().map(|_| std::time::Instant::now());
        let Some(mut out) = self.recluster_core(now)? else {
            return Ok(None);
        };
        if let Some(o) = self.obs.as_mut() {
            let wall_ns =
                t_wall.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0);
            o.on_recluster(now, out.migrated.len(), wall_ns);
        }
        let dests: std::collections::BTreeSet<usize> =
            out.migrated.iter().map(|&(_, _, new)| new).collect();
        let pbytes = crate::sim::network::model_bytes(self.p);
        self.links.begin_round();
        let mut t_done = 0.0f64;
        for &j in &dests {
            let region = self.topo.edges[j].region;
            let work = self.sample_one_way(region, Direction::Down);
            let (id, resched) =
                self.links.start(j, Direction::Down, pbytes, work, 0.0);
            // One warm-start broadcast per destination edge's downlink:
            // uncontended, first prediction is final.
            debug_assert_eq!(resched.len(), 1);
            let finish = resched[0].1;
            let (tr, _) = self
                .links
                .poll(id, finish)
                .expect("uncontended migration downlink lands as predicted");
            acc.record_migration_down(j, tr.finish - tr.start);
            if tr.finish > t_done {
                t_done = tr.finish;
            }
        }
        for &(d, _, new) in &out.migrated {
            // Warm start = handle re-point to the destination edge's
            // model (O(1); the downlink above paid the simulated time).
            self.store.repoint(&mut self.device_w[d], &self.edge_w[new]);
        }
        self.clock.advance(t_done);
        out.migration_downlink_time = t_done;
        self.last_recluster = Some(out.clone());
        Ok(Some(out))
    }

    /// Stamp the membership fields of a finished round's stats: per-round
    /// recluster/migration counters (drained from the tracker) plus the
    /// current active-set size and the drift-relevant live imbalance.
    pub(crate) fn finalize_membership_stats(
        &mut self,
        stats: &mut RoundStats,
    ) {
        let (reclusters, migrated) = self.membership.take_round_stats();
        stats.n_reclusters = reclusters;
        stats.migrated_devices = migrated;
        stats.active_devices = self.mobility.active_count();
        stats.edge_size_imbalance = self.membership_imbalance();
    }

    /// Stamp the model-store memory observables of a finished round:
    /// live/peak buffer footprint and the fraction of device handles
    /// that share their buffer (→1.0 right after a broadcast; the
    /// measured side of the O(N·p)→O(M·p) claim).
    pub(crate) fn finalize_memory_stats(&self, stats: &mut RoundStats) {
        stats.live_model_buffers = self.store.live_buffers();
        stats.peak_model_bytes = self.store.peak_model_bytes();
        let n = self.device_w.len();
        let shared = self
            .device_w
            .iter()
            .filter(|r| self.store.is_shared(r))
            .count();
        stats.sharing_ratio = if n == 0 {
            0.0
        } else {
            shared as f64 / n as f64
        };
    }

    /// Mean availability of edge `j`'s members at `now` (1.0 when pace
    /// steering is off — the lifecycle-off baseline the schema-v2 CSV
    /// columns record on every run).
    pub(crate) fn edge_availability(&self, j: usize, now: f64) -> f64 {
        match &self.avail {
            Some(a) => {
                a.fraction_available(&self.topo.edges[j].members, now)
            }
            None => 1.0,
        }
    }

    /// Record the lifecycle observables of a barrier round: zero
    /// abandoned (a barrier waits for every participant — nothing is
    /// ever cut loose mid-flight) and each edge's membership
    /// availability at the round boundary. The event engine records
    /// real abandonment counts through the same accumulator hook, so
    /// both engines emit identical schema-v2 rows. Called at the same
    /// position by `HflEngine::run_round` and the event engine's
    /// synchronous `run_round` — part of their bit-for-bit contract.
    pub(crate) fn record_lifecycle_baseline(
        &self,
        acc: &mut RoundAccumulator,
        now: f64,
    ) {
        for j in 0..self.edges() {
            acc.record_lifecycle(j, 0, self.edge_availability(j, now));
        }
    }

    /// Execute one cloud round under per-edge frequencies.
    /// `participation`: per-device mask (None = all mobility-active devices
    /// train). Devices that skip keep their model and spend nothing.
    pub fn run_round(
        &mut self,
        gamma1: &[usize],
        gamma2: &[usize],
        participation: Option<&[bool]>,
    ) -> Result<RoundStats> {
        let m = self.edges();
        anyhow::ensure!(
            gamma1.len() == m && gamma2.len() == m,
            "need {m} per-edge frequencies"
        );
        let mut acc = RoundAccumulator::new(m);
        let max_gamma2 = gamma2.iter().copied().max().unwrap_or(1).max(1);
        let mut edge_sub_time = vec![0.0f64; m];

        // Edge sub-rounds: all edges advance their own gamma2 schedule in
        // parallel simulated time; real compute batches across edges per
        // sub-round index to keep the worker pool full.
        for sub in 0..max_gamma2 {
            let (jobs, job_edges) =
                self.gather_jobs(sub, gamma1, gamma2, participation);
            if jobs.is_empty() {
                continue;
            }
            // Real compute: parallel local training.
            let results = self.train_batch(jobs)?;
            // Simulated time/energy per device (batched across the
            // sim worker pool — bitwise identical to the serial loop
            // at any `sim.workers`) + apply new weights.
            let reqs: Vec<(usize, usize)> = results
                .iter()
                .map(|res| (res.device, res.losses.len()))
                .collect();
            let sims = self.simulate_train_batch(&reqs);
            let mut sub_slowest = vec![0.0f64; m];
            for ((res, &j), &(t_dev, e_dev)) in
                results.iter().zip(&job_edges).zip(&sims)
            {
                if t_dev > sub_slowest[j] {
                    sub_slowest[j] = t_dev;
                }
                acc.record_train(
                    j,
                    res.device,
                    t_dev,
                    e_dev,
                    res.losses.last().copied(),
                );
            }
            for res in results {
                self.commit_device(res.device, res.w);
            }
            // Edge aggregations for the edges that trained this sub-round.
            for j in 0..m {
                if sub >= gamma2[j] || acc.per_edge[j].active == 0 {
                    continue;
                }
                let devs = self.edge_participants(j, participation);
                if devs.is_empty() {
                    continue;
                }
                self.edge_aggregate_devices(j, &devs)?;
                edge_sub_time[j] += sub_slowest[j];
            }
        }

        // Edge -> cloud communication: in-flight uploads through the link
        // layer; the round closes when the straggler's upload lands.
        let mut round_time = self.sync_comm_phase(&edge_sub_time, &mut acc);

        // Cloud aggregation over edge models, weighted by cluster data.
        let active: Vec<usize> =
            (0..m).filter(|&j| acc.per_edge[j].active > 0).collect();
        self.cloud_aggregate_edges(&active, None)?;
        self.broadcast_cloud();

        self.clock.advance(round_time);
        self.round += 1;
        self.total_energy += acc.round_energy;
        let flips = self.mobility.step();
        self.membership.observe(flips);
        // Between cloud rounds: re-cluster if the active set drifted past
        // the threshold (§3.1 "periodically re-cluster"). The warm-start
        // downlinks extend the round's wall-clock (the clock itself was
        // already advanced inside).
        if let Some(out) = self.maybe_recluster_barrier(&mut acc)? {
            round_time += out.migration_downlink_time;
        }
        self.record_lifecycle_baseline(&mut acc, self.clock.now());

        let (accuracy, test_loss) = self.evaluate()?;
        let mut stats = acc.finish(
            self.round,
            accuracy,
            test_loss,
            round_time,
            self.clock.now(),
            gamma1,
            gamma2,
        );
        self.finalize_membership_stats(&mut stats);
        self.finalize_memory_stats(&mut stats);
        self.emit_round_observation(&stats);
        self.last_round = Some(stats.clone());
        Ok(stats)
    }

    /// Publish a closed round to the attached observer, if any (store
    /// occupancy snapshot + the round itself). Read-only by contract.
    pub(crate) fn emit_round_observation(&mut self, stats: &RoundStats) {
        if let Some(o) = self.obs.as_mut() {
            o.on_store(
                stats.live_model_buffers,
                stats.peak_model_bytes,
                stats.sharing_ratio,
            );
            o.on_round(stats);
        }
    }

    /// Native weighted aggregation — the CPU roofline reference for the
    /// fedavg_reduce kernel (A/B'd in benches/aggregation.rs).
    pub fn aggregate_native_ref(
        &self,
        models: &[&[f32]],
        weights: &[f32],
    ) -> Vec<f32> {
        aggregate_native_auto(models, weights, self.p, self.agg_workers)
    }

    /// Expected duration of edge `j`'s part of a round under (γ1, γ2) —
    /// the time model behind the agent's feasible-action projection (§3.6).
    ///
    /// The communication term follows the transfer layer's overlapped-time
    /// model instead of the old lump `2.0 * mean_comm_time`:
    ///  * **Synchronous** — the barrier closes when the edge's upload
    ///    lands, and the downlink broadcast overlaps the next round's
    ///    dispatch, so only the (asymmetric-bandwidth) uplink mean is on
    ///    the critical path.
    ///  * **SemiSync/Async** — uploads are in flight while the next local
    ///    round trains, so the upload only costs what compute cannot hide
    ///    (`max(compute, up)`), plus the downlink that delivers the next
    ///    global model.
    pub fn predict_edge_time(
        &self,
        j: usize,
        gamma1: usize,
        gamma2: usize,
    ) -> f64 {
        let nb = self.rt.manifest.config.nb;
        let pbytes = crate::sim::network::model_bytes(self.p);
        let edge = &self.topo.edges[j];
        // Slowest member's expected per-batch time.
        let slow = edge
            .members
            .iter()
            .map(|&d| {
                let c = &self.topo.cpus[d];
                c.base_time * c.slowdown()
            })
            .fold(0.0, f64::max);
        let compute = slow * (nb * gamma1 * gamma2) as f64;
        let up = self.net.one_way_mean(
            edge.region,
            pbytes,
            self.cfg.link.up_bandwidth_scale,
        );
        let down = self.net.one_way_mean(
            edge.region,
            pbytes,
            self.cfg.link.down_bandwidth_scale,
        );
        match self.cfg.sync.mode {
            crate::config::SyncModeCfg::Synchronous => compute + up,
            _ => compute.max(up) + down,
        }
    }

    /// Expected duration of a whole round (straggler edge).
    pub fn predict_round_time(
        &self,
        gamma1: &[usize],
        gamma2: &[usize],
    ) -> f64 {
        (0..self.edges())
            .map(|j| self.predict_edge_time(j, gamma1[j], gamma2[j]))
            .fold(0.0, f64::max)
    }
}

/// Core of [`HflEngine::simulate_train`], shared with the parallel batch
/// path: advance one device's CPU state through `epochs` local epochs of
/// `nb` batches, returning the simulated (time, energy). All randomness
/// comes from the device's own `CpuModel` stream.
pub(crate) fn simulate_device(
    cpu: &mut CpuModel,
    energy: &EnergyModel,
    nb: usize,
    epochs: usize,
) -> (f64, f64) {
    let mut t_dev = 0.0;
    let mut e_dev = 0.0;
    for _ in 0..epochs {
        cpu.step_usage();
        for _ in 0..nb {
            let t = cpu.sgd_time();
            t_dev += t;
            e_dev += energy.sgd_energy(cpu, t);
        }
    }
    (t_dev, e_dev)
}
