//! The HFL synchronization executor.

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::pca::PcaModel;
use crate::runtime::{pool::TrainJob, DevicePool, HostTensor, Runtime};
use crate::sim::{EnergyModel, MobilityModel, NetworkModel, SimClock};
use crate::util::rng::Rng;

use super::metrics::{EdgeStats, RoundStats};
use super::topology::{build_topology, Topology};

pub struct HflEngine {
    pub cfg: ExperimentConfig,
    /// Main-thread runtime: eval / aggregate / pca_project artifacts.
    pub rt: Runtime,
    pool: DevicePool,
    pub topo: Topology,
    pub clock: SimClock,
    pub energy_model: EnergyModel,
    pub net: NetworkModel,
    pub mobility: MobilityModel,
    rng: Rng,
    /// Flat model parameter count.
    pub p: usize,
    pub cloud_w: Vec<f32>,
    pub edge_w: Vec<Vec<f32>>,
    pub device_w: Vec<Vec<f32>>,
    init_w: Vec<f32>,
    test_x: HostTensor,
    test_y: HostTensor,
    pub round: usize,
    pub total_energy: f64,
    pub last_round: Option<RoundStats>,
}

impl HflEngine {
    pub fn new(cfg: ExperimentConfig, use_profiling: bool) -> Result<Self> {
        let mut rng = Rng::new(cfg.seed);
        let ds = cfg.hfl.dataset.name();
        let eval_art = format!("{ds}_eval");
        let agg_art = format!("{ds}_aggregate");
        let pca_art = format!("{ds}_pca_project");
        let mut rt = Runtime::load(
            &cfg.artifacts_dir,
            &[eval_art.as_str(), agg_art.as_str(), pca_art.as_str()],
        )?;
        // Pre-compile any n_PCA ablation variants present in the manifest
        // (pca_scores is &self and cannot compile lazily).
        let variants: Vec<String> = rt
            .manifest
            .artifacts
            .keys()
            .filter(|k| k.starts_with(&format!("{pca_art}_npca")))
            .cloned()
            .collect();
        for v in &variants {
            rt.compile(v)?;
        }
        rt.manifest.validate_config(&cfg)?;
        let topo = build_topology(&cfg, use_profiling, &mut rng)?;
        let pool = DevicePool::new(
            cfg.workers,
            &cfg.artifacts_dir,
            ds,
            topo.shards.clone(),
        )?;
        let p = rt.manifest.param_count(ds)?;
        let init_w = rt.load_init_params(ds)?;
        // Test set, shaped for the eval artifact.
        let ts = rt.manifest.config.test_size;
        let (tx, ty) = topo.dataset.test_set(ts, cfg.seed ^ 0x7e57);
        let [h, w_, c] = topo.dataset.shape();
        let test_x = HostTensor::f32(vec![ts, h, w_, c], tx);
        let test_y = HostTensor::i32(vec![ts], ty);
        let m = cfg.topology.edges;
        let n = cfg.topology.devices;
        let energy_model =
            EnergyModel::new(cfg.sim.power_idle, cfg.sim.power_max);
        let net = NetworkModel::from_config(&cfg.sim);
        let mobility = MobilityModel::disabled(n);
        Ok(HflEngine {
            p,
            cloud_w: init_w.clone(),
            edge_w: vec![init_w.clone(); m],
            device_w: vec![init_w.clone(); n],
            init_w,
            test_x,
            test_y,
            rt,
            pool,
            topo,
            clock: SimClock::new(),
            energy_model,
            net,
            mobility,
            rng,
            round: 0,
            total_energy: 0.0,
            last_round: None,
            cfg,
        })
    }

    /// Reset models/clock/energy for a fresh run (new DRL episode or new
    /// scheme comparison) while keeping data, clusters and CPU states.
    pub fn reset(&mut self) {
        self.cloud_w = self.init_w.clone();
        for e in self.edge_w.iter_mut() {
            e.clone_from(&self.init_w);
        }
        for d in self.device_w.iter_mut() {
            d.clone_from(&self.init_w);
        }
        self.clock.reset();
        self.round = 0;
        self.total_energy = 0.0;
        self.last_round = None;
    }

    pub fn edges(&self) -> usize {
        self.cfg.topology.edges
    }

    pub fn remaining_time(&self) -> f64 {
        self.cfg.hfl.threshold_time - self.clock.now()
    }

    /// Weighted aggregation (Eq. 1/2): through the fedavg_reduce Pallas
    /// artifact by default, or natively in rust when
    /// `cfg.native_aggregation` is set (§Perf: interpret-mode Pallas is
    /// emulated on CPU; the native loop is the roofline there).
    pub fn aggregate(
        &self,
        models: &[&[f32]],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        if self.cfg.native_aggregation {
            return Ok(aggregate_native(models, weights, self.p));
        }
        let nmax = self.rt.manifest.config.nmax;
        anyhow::ensure!(
            models.len() <= nmax && models.len() == weights.len(),
            "aggregate: {} models vs nmax {nmax}",
            models.len()
        );
        let mut flat = vec![0.0f32; nmax * self.p];
        for (i, m) in models.iter().enumerate() {
            anyhow::ensure!(m.len() == self.p, "model {i} wrong size");
            flat[i * self.p..(i + 1) * self.p].copy_from_slice(m);
        }
        let mut w = vec![0.0f32; nmax];
        w[..weights.len()].copy_from_slice(weights);
        let art = format!("{}_aggregate", self.cfg.hfl.dataset.name());
        let out = self.rt.execute(
            &art,
            &[
                HostTensor::f32(vec![nmax, self.p], flat),
                HostTensor::f32(vec![nmax], w),
            ],
        )?;
        out.into_iter()
            .next()
            .context("aggregate produced no output")?
            .into_f32()
    }

    /// Evaluate the cloud model on the held-out test set -> (acc, loss).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        self.evaluate_model(&self.cloud_w)
    }

    pub fn evaluate_model(&self, w: &[f32]) -> Result<(f64, f64)> {
        let art = format!("{}_eval", self.cfg.hfl.dataset.name());
        let out = self.rt.execute(
            &art,
            &[
                HostTensor::f32(vec![self.p], w.to_vec()),
                self.test_x.clone(),
                self.test_y.clone(),
            ],
        )?;
        let correct = out[0].scalar()?;
        let loss = out[1].scalar()?;
        let acc = correct / self.test_x.shape[0] as f64;
        Ok((acc, loss))
    }

    /// Project [cloud; edges] models onto PCA loadings via the artifact.
    pub fn pca_scores(&self, pca: &PcaModel) -> Result<Vec<Vec<f32>>> {
        let m = self.edges();
        let rows = m + 1;
        let mut flat = Vec::with_capacity(rows * self.p);
        flat.extend_from_slice(&self.cloud_w);
        for e in &self.edge_w {
            flat.extend_from_slice(e);
        }
        let npca = pca.npca;
        let suffix = crate::agent::ppo::npca_suffix(
            self.rt.manifest.config.npca,
            npca,
        );
        let art =
            format!("{}_pca_project{suffix}", self.cfg.hfl.dataset.name());
        let out = self.rt.execute(
            &art,
            &[
                HostTensor::f32(vec![rows, self.p], flat),
                HostTensor::f32(vec![self.p, npca], pca.loadings.clone()),
            ],
        )?;
        let scores = out
            .into_iter()
            .next()
            .context("pca_project produced no output")?
            .into_f32()?;
        Ok(scores.chunks(npca).map(|c| c.to_vec()).collect())
    }

    /// Stack of current [cloud; edge] models (PCA fitting).
    pub fn model_stack(&self) -> Vec<&[f32]> {
        let mut v: Vec<&[f32]> = vec![&self.cloud_w];
        v.extend(self.edge_w.iter().map(|e| e.as_slice()));
        v
    }

    /// Execute one cloud round under per-edge frequencies.
    /// `participation`: per-device mask (None = all mobility-active devices
    /// train). Devices that skip keep their model and spend nothing.
    pub fn run_round(
        &mut self,
        gamma1: &[usize],
        gamma2: &[usize],
        participation: Option<&[bool]>,
    ) -> Result<RoundStats> {
        let m = self.edges();
        anyhow::ensure!(
            gamma1.len() == m && gamma2.len() == m,
            "need {m} per-edge frequencies"
        );
        let nb = self.rt.manifest.config.nb;
        let mut per_edge = vec![EdgeStats::default(); m];
        let mut round_energy = 0.0;
        let mut train_loss_acc = 0.0;
        let mut train_loss_n = 0.0;
        let mut device_losses: Vec<(usize, f64)> = Vec::new();

        let max_gamma2 = gamma2.iter().copied().max().unwrap_or(1).max(1);
        let mut edge_sub_time = vec![0.0f64; m];

        // Edge sub-rounds: all edges advance their own gamma2 schedule in
        // parallel simulated time; real compute batches across edges per
        // sub-round index to keep the worker pool full.
        for sub in 0..max_gamma2 {
            // Gather jobs for all edges still running sub-rounds.
            let mut jobs = Vec::new();
            let mut job_edges = Vec::new();
            for (j, edge) in self.topo.edges.iter().enumerate() {
                if sub >= gamma2[j] {
                    continue;
                }
                for &dev in &edge.members {
                    if !self.mobility.is_active(dev) {
                        continue;
                    }
                    if let Some(mask) = participation {
                        if !mask[dev] {
                            continue;
                        }
                    }
                    jobs.push(TrainJob {
                        device: dev,
                        w: self.device_w[dev].clone(),
                        epochs: gamma1[j],
                        seed: self
                            .rng
                            .fork(((self.round as u64) << 20) ^ dev as u64)
                            .next_u64(),
                    });
                    job_edges.push(j);
                }
            }
            if jobs.is_empty() {
                continue;
            }
            // Real compute: parallel local training.
            let results = self.pool.train(jobs)?;
            // Simulated time/energy per device + apply new weights.
            let mut sub_slowest = vec![0.0f64; m];
            for (res, &j) in results.iter().zip(&job_edges) {
                let dev = res.device;
                let cpu = &mut self.topo.cpus[dev];
                let mut t_dev = 0.0;
                let mut e_dev = 0.0;
                for _ in 0..res.losses.len() {
                    cpu.step_usage();
                    for _ in 0..nb {
                        let t = cpu.sgd_time();
                        t_dev += t;
                        e_dev += self.energy_model.sgd_energy(cpu, t);
                    }
                }
                per_edge[j].energy += e_dev;
                round_energy += e_dev;
                per_edge[j].active += 1;
                if t_dev > sub_slowest[j] {
                    sub_slowest[j] = t_dev;
                }
                if t_dev > per_edge[j].t_sgd_slowest {
                    per_edge[j].t_sgd_slowest = t_dev;
                }
                if let Some(&loss) = res.losses.last() {
                    train_loss_acc += loss;
                    train_loss_n += 1.0;
                    device_losses.push((dev, loss));
                }
            }
            for res in results {
                self.device_w[res.device] = res.w;
            }
            // Edge aggregations for the edges that trained this sub-round.
            for j in 0..m {
                if sub >= gamma2[j] || per_edge[j].active == 0 {
                    continue;
                }
                let members = &self.topo.edges[j].members;
                let mut models = Vec::new();
                let mut weights = Vec::new();
                for &dev in members {
                    let trained = self.mobility.is_active(dev)
                        && participation.map(|p| p[dev]).unwrap_or(true);
                    if trained {
                        models.push(self.device_w[dev].as_slice());
                        weights.push(self.topo.shards[dev].n as f32);
                    }
                }
                if models.is_empty() {
                    continue;
                }
                let agg = self.aggregate(&models, &weights)?;
                // Broadcast back to the cluster's devices.
                for &dev in members {
                    self.device_w[dev].clone_from(&agg);
                }
                self.edge_w[j] = agg;
                edge_sub_time[j] += sub_slowest[j];
            }
        }

        // Edge -> cloud communication (straggler path per edge).
        let pbytes = crate::sim::network::model_bytes(self.p);
        for (j, edge) in self.topo.edges.iter().enumerate() {
            let t_ec = self.net.comm_time(edge.region, pbytes, &mut self.rng);
            per_edge[j].t_ec = t_ec;
            per_edge[j].total_time = edge_sub_time[j] + t_ec;
        }

        // Cloud aggregation over edge models, weighted by cluster data.
        let mut models = Vec::new();
        let mut weights = Vec::new();
        for (j, edge) in self.topo.edges.iter().enumerate() {
            if per_edge[j].active == 0 {
                continue;
            }
            models.push(self.edge_w[j].as_slice());
            weights.push(
                edge.members
                    .iter()
                    .map(|&d| self.topo.shards[d].n as f32)
                    .sum(),
            );
            let _ = edge;
        }
        if !models.is_empty() {
            self.cloud_w = self.aggregate(&models, &weights)?;
        }
        // Broadcast global model everywhere (next round starts from w(k+1)).
        for e in self.edge_w.iter_mut() {
            e.clone_from(&self.cloud_w);
        }
        for d in self.device_w.iter_mut() {
            d.clone_from(&self.cloud_w);
        }

        let round_time = per_edge
            .iter()
            .map(|e| e.total_time)
            .fold(0.0, f64::max);
        self.clock.advance(round_time);
        self.round += 1;
        self.total_energy += round_energy;
        self.mobility.step();

        let (accuracy, test_loss) = self.evaluate()?;
        let stats = RoundStats {
            k: self.round,
            accuracy,
            test_loss,
            train_loss: if train_loss_n > 0.0 {
                train_loss_acc / train_loss_n
            } else {
                0.0
            },
            round_time,
            sim_now: self.clock.now(),
            per_edge,
            energy: round_energy,
            gamma1: gamma1.to_vec(),
            gamma2: gamma2.to_vec(),
            device_losses,
        };
        self.last_round = Some(stats.clone());
        Ok(stats)
    }

    /// Native weighted aggregation — the CPU roofline reference for the
    /// fedavg_reduce kernel (A/B'd in benches/aggregation.rs).
    pub fn aggregate_native_ref(
        &self,
        models: &[&[f32]],
        weights: &[f32],
    ) -> Vec<f32> {
        aggregate_native(models, weights, self.p)
    }

    /// Expected duration of edge `j`'s part of a round under (γ1, γ2) —
    /// the time model behind the agent's feasible-action projection (§3.6).
    pub fn predict_edge_time(
        &self,
        j: usize,
        gamma1: usize,
        gamma2: usize,
    ) -> f64 {
        let nb = self.rt.manifest.config.nb;
        let pbytes = crate::sim::network::model_bytes(self.p);
        let edge = &self.topo.edges[j];
        // Slowest member's expected per-batch time.
        let slow = edge
            .members
            .iter()
            .map(|&d| {
                let c = &self.topo.cpus[d];
                c.base_time * c.slowdown()
            })
            .fold(0.0, f64::max);
        slow * (nb * gamma1 * gamma2) as f64
            + 2.0 * self.net.mean_comm_time(edge.region, pbytes)
    }

    /// Expected duration of a whole round (straggler edge).
    pub fn predict_round_time(
        &self,
        gamma1: &[usize],
        gamma2: &[usize],
    ) -> f64 {
        (0..self.edges())
            .map(|j| self.predict_edge_time(j, gamma1[j], gamma2[j]))
            .fold(0.0, f64::max)
    }
}

/// sum_i w_i m_i / sum_i w_i over flat models, native rust.
fn aggregate_native(models: &[&[f32]], weights: &[f32], p: usize) -> Vec<f32> {
    let wsum: f32 = weights.iter().sum();
    let mut out = vec![0.0f32; p];
    for (m, &w) in models.iter().zip(weights) {
        if w == 0.0 {
            continue;
        }
        for (o, &x) in out.iter_mut().zip(*m) {
            *o += w * x;
        }
    }
    let inv = 1.0 / wsum;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn native_aggregation_matches_formula() {
        let a = vec![1.0f32; 8];
        let b = vec![5.0f32; 8];
        let out = super::aggregate_native(&[&a, &b], &[1.0, 3.0], 8);
        for v in out {
            assert!((v - 4.0).abs() < 1e-6);
        }
    }

    #[test]
    fn native_aggregation_skips_zero_weights() {
        let a = vec![2.0f32; 4];
        let b = vec![999.0f32; 4];
        let out = super::aggregate_native(&[&a, &b], &[2.0, 0.0], 4);
        for v in out {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }
}
