//! The HFL core: cloud/edge/device hierarchy and the synchronization
//! executor (paper §2.1 Eqs. 1-2, §3.5 workflow).
//!
//! `HflEngine::run_round(gamma1, gamma2, participation)` executes one cloud
//! aggregation round under per-edge frequencies: every active device runs
//! γ1ʲ real local epochs (through the AOT train_epoch artifact, fanned over
//! the worker pool), the edge aggregates after each (fedavg_reduce Pallas
//! kernel), γ2ʲ edge aggregations later the cloud aggregates all edges and
//! evaluates. Simulated time advances by the straggler path; energy is
//! accounted per device from the Fig. 3-calibrated models.

//! `AsyncHflEngine` (hfl/async_engine.rs) is the event-driven counterpart:
//! the same hierarchy executed over the `sim::event` queue in synchronous,
//! K-quorum semi-synchronous, or staleness-discounted asynchronous mode.
//! All edge↔cloud communication — both engines — runs as in-flight
//! transfers through `sim::link` (per-edge uplink/downlink pairs with
//! fair-share contention), so upload time can overlap the next local
//! round and metrics report compute vs in-flight comm time separately.

//! The lifecycle subsystem (`hfl/lifecycle.rs`) adds the production
//! client machinery: over-selection (dispatch ceil(K·factor), close on
//! the first K landings, abandon stragglers through the stale-result
//! void path), availability-aware pace steering, and seeded fault
//! injection (`FaultPlan` → `EdgeOutage`/`Partition`/`CrashStorm`
//! events) — all bitwise deterministic at any worker count.

//! The membership subsystem (`hfl/membership.rs`) keeps the clustered
//! topology aligned with the *live* population: churn drift past
//! `cluster.recluster_threshold` triggers a re-profile + region-constrained
//! balanced re-cluster, and the running topology migrates in place (both
//! engines; the event engine does it live via a `Recluster` event).

//! # Model ownership
//!
//! All model state lives in one [`ModelStore`] per engine
//! (`hfl/model_store.rs`): a reference-counted, version-tagged slab of
//! flat `f32` buffers with a free-list pool. The engines hold
//! [`ModelRef`] handles — `cloud_w`, `edge_w[j]`, `device_w[d]`, the
//! event engine's landed view and its in-flight transfer payloads — and
//! the rules are:
//!
//! * **Who may hold a `ModelRef`:** the engine model lines (cloud, per
//!   edge, per device), the async engine's cloud-side landed view, and
//!   in-flight transfer payloads (upload/downlink/migration snapshots).
//!   Each held handle owns exactly one reference; handles are duplicated
//!   only through `ModelStore::share` and disposed of only through
//!   `ModelStore::release` (they are not `Clone` and have no `Drop`).
//! * **Movement is O(1):** broadcast, edge→device sync, warm-starts,
//!   rejoin resets and transfer landings re-point handles (rc bumps) —
//!   never copy buffers. This is what breaks the old O(N·p) per-device
//!   clone wall: between training bursts, N device handles share M edge
//!   buffers.
//! * **When materialization happens:** (a) dispatching a training job —
//!   the worker pool needs an owned `Vec<f32>`; (b) adopting a trained
//!   result back into the store; (c) copy-on-write — the first mutation
//!   of a shared buffer (`make_mut` / `mix_into`) re-points the writer
//!   to a pooled copy, so sharers and in-flight snapshots never observe
//!   the write; (d) the read-only boundary resolvers (`model_stack`,
//!   `pca_scores`, `evaluate_model`), which borrow slices without
//!   copying.
//! * **Versions are the staleness bookkeeping:** a handle's tag advances
//!   at its line's aggregations (strictly increasing per edge), and the
//!   FedAsync discount, `EdgeStats::staleness` and the out-of-order
//!   landing guards all read version deltas straight off the handles —
//!   there are no parallel staleness counters.
//!
//! `RoundStats` carries the memory observables (`live_model_buffers`,
//! `peak_model_bytes`, `sharing_ratio`) into the history CSVs so the
//! sharing win is measured, not asserted.

pub mod aggregate;
pub mod async_engine;
pub mod engine;
pub mod engine_shard;
pub mod lifecycle;
pub mod membership;
pub mod metrics;
pub mod model_store;
pub mod topology;

pub use async_engine::{AsyncHflEngine, SyncMode};
pub use engine::HflEngine;
pub use engine_shard::{
    EngineLoopSpec, EngineShard, EngineWindowRow, ShardedEngineLoop,
};
pub use lifecycle::{
    frac_to_bits, overselect_count, select_dispatch, storm_hits, FaultPlan,
};
pub use membership::{MembershipTracker, ReclusterOutcome};
pub use metrics::{EdgeStats, RoundAccumulator, RoundStats, RunHistory};
pub use model_store::{
    ModelRef, ModelStore, ShardSlabStats, ShardedModelRef,
    ShardedModelStore, ShardedStoreStats,
};
pub use topology::{build_topology, Edge, Topology};
