//! The HFL core: cloud/edge/device hierarchy and the synchronization
//! executor (paper §2.1 Eqs. 1-2, §3.5 workflow).
//!
//! `HflEngine::run_round(gamma1, gamma2, participation)` executes one cloud
//! aggregation round under per-edge frequencies: every active device runs
//! γ1ʲ real local epochs (through the AOT train_epoch artifact, fanned over
//! the worker pool), the edge aggregates after each (fedavg_reduce Pallas
//! kernel), γ2ʲ edge aggregations later the cloud aggregates all edges and
//! evaluates. Simulated time advances by the straggler path; energy is
//! accounted per device from the Fig. 3-calibrated models.

//! `AsyncHflEngine` (hfl/async_engine.rs) is the event-driven counterpart:
//! the same hierarchy executed over the `sim::event` queue in synchronous,
//! K-quorum semi-synchronous, or staleness-discounted asynchronous mode.
//! All edge↔cloud communication — both engines — runs as in-flight
//! transfers through `sim::link` (per-edge uplink/downlink pairs with
//! fair-share contention), so upload time can overlap the next local
//! round and metrics report compute vs in-flight comm time separately.

//! The membership subsystem (`hfl/membership.rs`) keeps the clustered
//! topology aligned with the *live* population: churn drift past
//! `cluster.recluster_threshold` triggers a re-profile + region-constrained
//! balanced re-cluster, and the running topology migrates in place (both
//! engines; the event engine does it live via a `Recluster` event).

pub mod aggregate;
pub mod async_engine;
pub mod engine;
pub mod membership;
pub mod metrics;
pub mod topology;

pub use async_engine::{AsyncHflEngine, SyncMode};
pub use engine::HflEngine;
pub use membership::{MembershipTracker, ReclusterOutcome};
pub use metrics::{EdgeStats, RoundAccumulator, RoundStats, RunHistory};
pub use topology::{build_topology, Edge, Topology};
