//! Weighted model aggregation shared by both synchronization engines
//! (paper Eqs. 1-2). The Pallas `fedavg_reduce` artifact path stays in the
//! engines (it needs the runtime handle); this module owns the native CPU
//! reference — serial and deterministically parallel — and the staleness
//! weighting used by the asynchronous modes.

/// sum_i w_i m_i / sum_i w_i over flat models, native rust — the CPU
/// roofline reference for the fedavg_reduce kernel (A/B'd in
/// benches/aggregation.rs).
pub fn aggregate_native(
    models: &[&[f32]],
    weights: &[f32],
    p: usize,
) -> Vec<f32> {
    for (i, m) in models.iter().enumerate() {
        assert_eq!(m.len(), p, "model {i} has the wrong size");
    }
    let wsum: f32 = weights.iter().sum();
    let mut out = vec![0.0f32; p];
    for (m, &w) in models.iter().zip(weights) {
        if w == 0.0 {
            continue;
        }
        for (o, &x) in out.iter_mut().zip(*m) {
            *o += w * x;
        }
    }
    let inv = 1.0 / wsum;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

/// Chunk width (elements) of the parallel aggregation grid. Fixed: chunk
/// boundaries depend only on `p`, never on the worker count.
pub const PAR_CHUNK: usize = 1 << 14;

/// Total element count (models × p) below which the serial loop wins
/// (scoped-thread spawn/join overhead dominates small reductions).
const PAR_MIN_ELEMS: usize = 1 << 21;

/// [`aggregate_native`] parallelized over `workers` threads
/// (`util::threadpool::par_for_each`) as deterministic chunked partial
/// sums. The output is cut into the fixed [`PAR_CHUNK`] grid and every
/// chunk accumulates its models in the same order as the serial loop, so
/// each output element sees the exact serial FP operation order: the
/// result is **bit-identical** to [`aggregate_native`] for any worker
/// count or chunk assignment.
pub fn aggregate_native_par(
    models: &[&[f32]],
    weights: &[f32],
    p: usize,
    workers: usize,
) -> Vec<f32> {
    for (i, m) in models.iter().enumerate() {
        assert_eq!(m.len(), p, "model {i} has the wrong size");
    }
    let wsum: f32 = weights.iter().sum();
    let inv = 1.0 / wsum;
    let mut out = vec![0.0f32; p];
    let chunks: Vec<(usize, &mut [f32])> =
        out.chunks_mut(PAR_CHUNK).enumerate().collect();
    crate::util::threadpool::par_for_each(workers, chunks, |(ci, seg)| {
        let lo = ci * PAR_CHUNK;
        let hi = lo + seg.len();
        for (m, &w) in models.iter().zip(weights) {
            if w == 0.0 {
                continue;
            }
            for (o, &x) in seg.iter_mut().zip(&m[lo..hi]) {
                *o += w * x;
            }
        }
        for o in seg.iter_mut() {
            *o *= inv;
        }
    });
    out
}

/// Serial/parallel dispatch: small reductions stay on the serial loop,
/// large ones fan out. Both paths are bit-identical, so the threshold can
/// never change results — only wall-clock.
pub fn aggregate_native_auto(
    models: &[&[f32]],
    weights: &[f32],
    p: usize,
    workers: usize,
) -> Vec<f32> {
    if workers <= 1 || models.len().saturating_mul(p) < PAR_MIN_ELEMS {
        aggregate_native(models, weights, p)
    } else {
        aggregate_native_par(models, weights, p, workers)
    }
}

/// Staleness discount of arXiv:2107.11415 / FedAsync: an update computed
/// against a model `staleness` versions old contributes with multiplier
/// `1 / (1 + s)^alpha`. `alpha = 0` ignores staleness entirely.
pub fn staleness_discount(staleness: u64, alpha: f64) -> f32 {
    (1.0 / (1.0 + staleness as f64).powf(alpha)) as f32
}

/// In-place convex blend `base = (1 - beta) * base + beta * update` — the
/// per-report edge model mix of the fully asynchronous mode.
pub fn mix_into(base: &mut [f32], update: &[f32], beta: f32) {
    debug_assert_eq!(base.len(), update.len());
    let keep = 1.0 - beta;
    for (b, &u) in base.iter_mut().zip(update) {
        *b = keep * *b + beta * u;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_aggregation_matches_formula() {
        let a = vec![1.0f32; 8];
        let b = vec![5.0f32; 8];
        let out = aggregate_native(&[&a, &b], &[1.0, 3.0], 8);
        for v in out {
            assert!((v - 4.0).abs() < 1e-6);
        }
    }

    #[test]
    fn native_aggregation_skips_zero_weights() {
        let a = vec![2.0f32; 4];
        let b = vec![999.0f32; 4];
        let out = aggregate_native(&[&a, &b], &[2.0, 0.0], 4);
        for v in out {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn parallel_aggregation_is_bit_identical_to_serial() {
        // p deliberately not a multiple of PAR_CHUNK, with irrational-ish
        // weights so FP ordering differences would show.
        let p = PAR_CHUNK * 2 + 1234;
        let mut rng = crate::util::rng::Rng::new(42);
        let models: Vec<Vec<f32>> = (0..7)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let weights: Vec<f32> =
            (0..7).map(|i| 0.1 + 0.37 * i as f32).collect();
        let serial = aggregate_native(&refs, &weights, p);
        for workers in [1usize, 2, 3, 8] {
            let par = aggregate_native_par(&refs, &weights, p, workers);
            assert_eq!(par, serial, "workers={workers} diverged bitwise");
        }
        // The auto dispatcher is bit-stable across the threshold too.
        assert_eq!(aggregate_native_auto(&refs, &weights, p, 4), serial);
    }

    #[test]
    fn parallel_aggregation_skips_zero_weights() {
        let p = PAR_CHUNK + 17;
        let a = vec![2.0f32; p];
        let b = vec![999.0f32; p];
        let out = aggregate_native_par(&[&a, &b], &[2.0, 0.0], p, 4);
        for v in out {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn staleness_discount_decays() {
        assert!((staleness_discount(0, 0.5) - 1.0).abs() < 1e-6);
        let d1 = staleness_discount(1, 0.5);
        let d4 = staleness_discount(4, 0.5);
        assert!(d1 < 1.0 && d4 < d1, "{d1} {d4}");
        // alpha = 0 disables the discount.
        assert!((staleness_discount(9, 0.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn mix_into_blends() {
        let mut base = vec![0.0f32; 4];
        let update = vec![2.0f32; 4];
        mix_into(&mut base, &update, 0.25);
        for v in &base {
            assert!((v - 0.5).abs() < 1e-6);
        }
        mix_into(&mut base, &update, 1.0);
        for v in &base {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }
}
