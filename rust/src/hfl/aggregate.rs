//! Weighted model aggregation shared by both synchronization engines
//! (paper Eqs. 1-2). The Pallas `fedavg_reduce` artifact path stays in the
//! engines (it needs the runtime handle); this module owns the native CPU
//! reference and the staleness weighting used by the asynchronous modes.

/// sum_i w_i m_i / sum_i w_i over flat models, native rust — the CPU
/// roofline reference for the fedavg_reduce kernel (A/B'd in
/// benches/aggregation.rs).
pub fn aggregate_native(
    models: &[&[f32]],
    weights: &[f32],
    p: usize,
) -> Vec<f32> {
    let wsum: f32 = weights.iter().sum();
    let mut out = vec![0.0f32; p];
    for (m, &w) in models.iter().zip(weights) {
        if w == 0.0 {
            continue;
        }
        for (o, &x) in out.iter_mut().zip(*m) {
            *o += w * x;
        }
    }
    let inv = 1.0 / wsum;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

/// Staleness discount of arXiv:2107.11415 / FedAsync: an update computed
/// against a model `staleness` versions old contributes with multiplier
/// `1 / (1 + s)^alpha`. `alpha = 0` ignores staleness entirely.
pub fn staleness_discount(staleness: u64, alpha: f64) -> f32 {
    (1.0 / (1.0 + staleness as f64).powf(alpha)) as f32
}

/// In-place convex blend `base = (1 - beta) * base + beta * update` — the
/// per-report edge model mix of the fully asynchronous mode.
pub fn mix_into(base: &mut [f32], update: &[f32], beta: f32) {
    debug_assert_eq!(base.len(), update.len());
    let keep = 1.0 - beta;
    for (b, &u) in base.iter_mut().zip(update) {
        *b = keep * *b + beta * u;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_aggregation_matches_formula() {
        let a = vec![1.0f32; 8];
        let b = vec![5.0f32; 8];
        let out = aggregate_native(&[&a, &b], &[1.0, 3.0], 8);
        for v in out {
            assert!((v - 4.0).abs() < 1e-6);
        }
    }

    #[test]
    fn native_aggregation_skips_zero_weights() {
        let a = vec![2.0f32; 4];
        let b = vec![999.0f32; 4];
        let out = aggregate_native(&[&a, &b], &[2.0, 0.0], 4);
        for v in out {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn staleness_discount_decays() {
        assert!((staleness_discount(0, 0.5) - 1.0).abs() < 1e-6);
        let d1 = staleness_discount(1, 0.5);
        let d4 = staleness_discount(4, 0.5);
        assert!(d1 < 1.0 && d4 < d1, "{d1} {d4}");
        // alpha = 0 disables the discount.
        assert!((staleness_discount(9, 0.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn mix_into_blends() {
        let mut base = vec![0.0f32; 4];
        let update = vec![2.0f32; 4];
        mix_into(&mut base, &update, 0.25);
        for v in &base {
            assert!((v - 0.5).abs() < 1e-6);
        }
        mix_into(&mut base, &update, 1.0);
        for v in &base {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }
}
