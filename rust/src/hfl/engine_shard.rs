//! Per-edge engine shards: the parallel half of `AsyncHflEngine`.
//!
//! The event engine's timeline is **edge-partitioned**: between cloud
//! decision points, a `DeviceTrainDone` / `EdgeAggregate` /
//! `TransferDone` event on edge `j` can read and write only edge-`j`
//! state (its members, its uplink/downlink, its version counters).
//! Every cross-edge coupling — cloud aggregation, mobility flips,
//! re-clustering, fault storms, `set_control` re-arms — is a *ctrl
//! event*, and ctrl events are only ever scheduled at barriers. So the
//! conservative window bound of the sharded engine collapses from the
//! generic `min_s(peek_time_s) + min_link_latency` to simply **the next
//! ctrl event's timestamp**: no shard can be affected by another shard
//! before the next barrier, no speculation, no rollback.
//!
//! # Two-phase windows: advance, then replay
//!
//! An [`EngineShard`] owns, for its edges, everything the *timeline*
//! needs: the event heap, the link pair, the RNG streams (link jitter +
//! job seeds), per-device CPU/lifecycle/availability state, and mirrors
//! of every version counter the handlers branch on. What it does *not*
//! own is model values — those live in the coordinator's `ModelStore`.
//! The timeline never reads a model value (it branches on version
//! counters, data sizes and RNG draws only), which is the invariant
//! that makes the split exact:
//!
//! 1. **Advance** (parallel, `util::threadpool::shard_scope` /
//!    [`ShardPool`]): each shard drains its heap up to the window bound,
//!    appending an ordered [`EngineAction`] log — "train these jobs",
//!    "aggregate these devices with these betas", "this upload landed,
//!    adopt it".
//! 2. **Replay** (serial, fixed shard order): the coordinator applies
//!    the logs — real training, store mutation, accumulator and
//!    observer effects — shard 0 first, then shard 1, … Because model
//!    state is edge-partitioned too, in-order-within-shard is the only
//!    ordering that matters, and shard-major replay reproduces the
//!    single-threaded trajectory bit for bit (f64 accumulation order
//!    included).
//!
//! Shard count is fixed by the topology (`edge % n_shards`, auto
//! `min(edges, 64)`), never by `sim.workers`; a single worker runs the
//! identical structure inline, so worker-count invariance is
//! structural, not tested-for luck. Wall-clock is read only with an
//! observer attached and flows only into observer records.
//!
//! # The training-free timeline harness
//!
//! [`ShardedEngineLoop`] drives the same `EngineShard` machinery with a
//! synthetic population and **no replay phase** (no artifacts, no
//! model store): the action stream is folded into per-window checksums
//! instead of being applied. This is what CI diffs across worker
//! counts and what `benches/event_queue.rs` times at 1M devices — the
//! advance phase is training-free by construction, so the harness
//! exercises exactly the code the real engine parallelizes.

use std::collections::HashMap;
use std::io::Write as _;
use std::sync::Arc;

use crate::config::FaultConfig;
use crate::hfl::aggregate::staleness_discount;
use crate::hfl::async_engine::{
    effective_quorum, quorum_satisfied, SyncMode,
};
use crate::hfl::engine::simulate_device;
use crate::hfl::lifecycle::{
    overselect_count, select_dispatch, storm_hits, FaultPlan,
};
use crate::obs::profiler::ShardProfiler;
use crate::sim::{
    AvailabilityModel, CpuModel, Direction, EnergyModel, Event, EventQueue,
    LinkManager, MobilityModel, NetworkModel, QueueBackend, Region,
};
use crate::util::rng::Rng;
use crate::util::threadpool::ShardPool;

/// Sentinel `mig_seq` of a tombstone: a device that migrated away while
/// a training result was still in flight. The stale `DeviceTrainDone`
/// lands here (voided), then the tombstone is removed.
const TOMBSTONE: u64 = u64::MAX;

/// One training job's timeline-side record. Replay turns it into a real
/// `TrainJob` (slicing the device's current model) and parks the result
/// in the store at `start_version`.
#[derive(Clone, Debug, PartialEq)]
pub struct DispatchJob {
    pub device: usize,
    pub edge: usize,
    /// Worker-pool job seed (drawn from the shard's job stream).
    pub seed: u64,
    pub epochs: usize,
    /// Edge model version at dispatch — the result's staleness anchor.
    pub start_version: u64,
    /// Simulated compute seconds (device CPU stream).
    pub t_dev: f64,
    /// Simulated compute energy, mAh.
    pub e_dev: f64,
    /// Availability lag before compute starts.
    pub lag: f64,
}

/// How a `DeviceTrainDone` resolved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainOutcome {
    /// Adopt the parked result into the device line.
    Landed,
    /// Stale (abandoned / churned / migrated) — energy was spent, the
    /// result is dropped.
    Voided,
    /// The device left the population mid-flight.
    Departed,
}

/// What a landed transfer does to the coordinator's model state. The
/// adopt/release decision is made shard-side from version mirrors, so
/// replay applies it without re-deriving anything.
#[derive(Clone, Debug, PartialEq)]
pub enum Landing {
    Upload { adopt: bool },
    Downlink { adopt: bool },
    Migration { devices: Vec<usize>, seq: u64 },
}

/// The shard→coordinator action protocol: everything a window's
/// timeline decided, in the exact order it decided it. Replay applies
/// these logs in fixed shard order; the harness folds them into
/// checksums instead.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineAction {
    /// One event popped and handled (emitted only while an observer is
    /// attached; wall values flow only into observer records).
    Obs {
        variant: &'static str,
        t: f64,
        lag_ns: u64,
        handler_ns: u64,
    },
    /// A training burst left at `t`: replay runs the real jobs through
    /// the worker pool. `sim_wall_ns` is the shard-side wall cost of
    /// the per-device CPU simulation (profiler-gated, else 0).
    Dispatch {
        t: f64,
        jobs: Vec<DispatchJob>,
        sim_wall_ns: u64,
    },
    /// A `DeviceTrainDone` resolved on `edge`.
    Train {
        edge: usize,
        device: usize,
        outcome: TrainOutcome,
    },
    /// An edge aggregation: empty `mixes` is the semi-sync full
    /// aggregate over `devs`; otherwise the async staleness-discounted
    /// blend, one `(device, beta)` per reporter in order.
    EdgeAgg {
        edge: usize,
        devs: Vec<usize>,
        mixes: Vec<(usize, f32)>,
    },
    /// An upload departed: replay snapshots the edge model as the
    /// payload of shard-local transfer `id`.
    UploadStart { edge: usize, id: usize },
    /// Idle devices re-synced to their edge model (outage recovery,
    /// crash rejoin, churn rejoin).
    Rejoin { edge: usize, devices: Vec<usize> },
    /// A transfer landed; `landing` carries the shard-decided payload
    /// disposition.
    Transfer {
        id: usize,
        edge: usize,
        t: f64,
        dir: &'static str,
        bytes: f64,
        start: f64,
        finish: f64,
        landing: Landing,
    },
}

/// Fold an action slice into a running FNV-1a checksum. Stable across
/// worker counts and queue backends by construction (the action stream
/// is); the harness's per-window CSV checksum and the tests both use it.
pub fn fold_actions(h: &mut u64, acts: &[EngineAction]) {
    #[inline]
    fn mix(h: &mut u64, x: u64) {
        *h ^= x;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
    for a in acts {
        match a {
            // Wall-clock values never enter a checksum.
            EngineAction::Obs { t, .. } => {
                mix(h, 1);
                mix(h, t.to_bits());
            }
            EngineAction::Dispatch { t, jobs, .. } => {
                mix(h, 2);
                mix(h, t.to_bits());
                for j in jobs {
                    mix(h, j.device as u64);
                    mix(h, j.edge as u64);
                    mix(h, j.seed);
                    mix(h, j.epochs as u64);
                    mix(h, j.start_version);
                    mix(h, j.t_dev.to_bits());
                    mix(h, j.e_dev.to_bits());
                    mix(h, j.lag.to_bits());
                }
            }
            EngineAction::Train {
                edge,
                device,
                outcome,
            } => {
                mix(h, 3);
                mix(h, *edge as u64);
                mix(h, *device as u64);
                mix(h, *outcome as u64);
            }
            EngineAction::EdgeAgg { edge, devs, mixes } => {
                mix(h, 4);
                mix(h, *edge as u64);
                for &d in devs {
                    mix(h, d as u64);
                }
                for &(d, b) in mixes {
                    mix(h, d as u64);
                    mix(h, b.to_bits() as u64);
                }
            }
            EngineAction::UploadStart { edge, id } => {
                mix(h, 5);
                mix(h, *edge as u64);
                mix(h, *id as u64);
            }
            EngineAction::Rejoin { edge, devices } => {
                mix(h, 6);
                mix(h, *edge as u64);
                for &d in devices {
                    mix(h, d as u64);
                }
            }
            EngineAction::Transfer {
                id,
                edge,
                t,
                dir,
                bytes,
                start,
                finish,
                landing,
            } => {
                mix(h, 7);
                mix(h, *id as u64);
                mix(h, *edge as u64);
                mix(h, t.to_bits());
                mix(h, dir.len() as u64);
                mix(h, *bytes as u64);
                mix(h, start.to_bits());
                mix(h, finish.to_bits());
                match landing {
                    Landing::Upload { adopt } => mix(h, 10 + *adopt as u64),
                    Landing::Downlink { adopt } => {
                        mix(h, 20 + *adopt as u64)
                    }
                    Landing::Migration { devices, seq } => {
                        mix(h, 30);
                        mix(h, *seq);
                        for &d in devices {
                            mix(h, d as u64);
                        }
                    }
                }
            }
        }
    }
}

/// The physics and policy every shard needs a private copy of. Cheap to
/// clone at `begin_run` (availability windows are the only O(n) part,
/// and only when pace steering is on).
#[derive(Clone)]
pub struct ShardPhysics {
    /// Minibatches per local epoch (drives the CPU simulation).
    pub nb: usize,
    /// Model bytes on the wire.
    pub pbytes: usize,
    pub up_scale: f64,
    pub down_scale: f64,
    pub contention: bool,
    pub net: NetworkModel,
    pub energy: EnergyModel,
    pub avail: Option<AvailabilityModel>,
    /// Per global edge.
    pub regions: Vec<Region>,
    /// Per global device: training-data size (aggregation share).
    pub data_n: Arc<Vec<f32>>,
    pub mode: SyncMode,
    pub overselect: f64,
}

#[derive(Clone, Debug)]
struct PendMeta {
    void: bool,
    start_version: u64,
}

#[derive(Clone, Debug)]
struct DevState {
    edge: usize,
    active: bool,
    /// Device model version mirror (tracks every repoint/adopt replay
    /// will perform).
    version: u64,
    mig_seq: u64,
    pend: Option<PendMeta>,
}

#[derive(Clone, Debug)]
enum TrKind {
    Upload { version: u64 },
    Downlink { version: u64 },
    Migration { version: u64, devices: Vec<usize>, seq: u64 },
}

/// One shard of the engine timeline: the event heap, links, RNG
/// streams, lifecycle state and version mirrors of its edges' world.
/// See the module doc for what it may and may not own.
pub struct EngineShard {
    pub id: usize,
    /// Own edges, ascending global ids (`edge % n_shards == id`).
    pub edges: Vec<usize>,
    queue: EventQueue,
    /// Global-edge-indexed; only own edges' links are ever touched, so
    /// transfer ids are *shard-local* (payloads key on `(shard, id)`).
    links: LinkManager,
    link_rng: Rng,
    job_rng: Rng,
    // Policy knobs, refreshed by the coordinator at window starts.
    mode: SyncMode,
    overselect: f64,
    g1: Vec<usize>,
    alpha: Vec<f64>,
    obs_attached: bool,
    profile: bool,
    pub(crate) draining: bool,
    phys: ShardPhysics,
    // Membership + device state, own edges only.
    members: Vec<Vec<usize>>,
    devs: HashMap<usize, DevState>,
    cpus: HashMap<usize, CpuModel>,
    // Timeline mirrors (global-edge-indexed; own entries meaningful).
    cloud_version: u64,
    edge_version: Vec<u64>,
    landed_version: Vec<u64>,
    adopted_cloud: Vec<u64>,
    pub(crate) edge_last_update: Vec<u64>,
    pub(crate) reported: Vec<Vec<usize>>,
    training_count: Vec<usize>,
    pub(crate) window_landings: Vec<usize>,
    pub(crate) window_edge_aggs: Vec<usize>,
    pub(crate) obs_up: Vec<f64>,
    pub(crate) obs_down: Vec<f64>,
    pub(crate) edge_faulted: Vec<bool>,
    pub(crate) edge_partitioned: Vec<bool>,
    pub(crate) win_abandoned: Vec<usize>,
    pub(crate) win_compute: Vec<f64>,
    pub(crate) win_up: Vec<f64>,
    pub(crate) win_down: Vec<f64>,
    pub(crate) win_comm: Vec<f64>,
    pub(crate) win_overlap: Vec<f64>,
    sweep_t: f64,
    tr_meta: HashMap<usize, TrKind>,
    // The window's action log plus the reusable-buffer pools that keep
    // the steady state allocation-free (the calendar queue's spare-Vec
    // pattern): `recycle` drains replayed actions back into them.
    actions: Vec<EngineAction>,
    scratch: Vec<usize>,
    spare: Vec<Vec<usize>>,
    spare_jobs: Vec<Vec<DispatchJob>>,
    spare_mixes: Vec<Vec<(usize, f32)>>,
    // Read-only profiling (barrier-drained).
    pub(crate) prof: ShardProfiler,
    pub(crate) win_events: u64,
    pub(crate) win_voided: u64,
    pub(crate) win_flips: u64,
    pub(crate) win_outages: u64,
    pub(crate) win_partitions: u64,
    pub(crate) win_crashes: u64,
    pub(crate) queue_peak: usize,
    pub(crate) events_handled: u64,
}

impl EngineShard {
    /// Topology-fixed shard count: `min(edges, 64)`, never derived from
    /// the worker count.
    pub fn auto_shards(edges: usize) -> usize {
        edges.clamp(1, 64)
    }

    /// The shard that owns `edge`.
    pub fn shard_of(edge: usize, n_shards: usize) -> usize {
        edge % n_shards
    }

    pub(crate) fn new(
        id: usize,
        n_shards: usize,
        seed: u64,
        backend: QueueBackend,
        expected_events: usize,
        phys: ShardPhysics,
    ) -> Self {
        let m = phys.regions.len();
        // Canonical per-shard streams: a function of the master seed and
        // the shard index only — identical for any worker count.
        let mut master = Rng::new(seed ^ 0xe551_7a0d ^ ((id as u64) << 20));
        EngineShard {
            id,
            edges: (id..m).step_by(n_shards.max(1)).collect(),
            queue: EventQueue::for_scale(
                master.fork(1).next_u64(),
                expected_events,
                backend,
            ),
            links: LinkManager::new(m, phys.contention),
            link_rng: master.fork(2),
            job_rng: master.fork(3),
            mode: phys.mode,
            overselect: phys.overselect,
            g1: vec![1; m],
            alpha: vec![0.0; m],
            obs_attached: false,
            profile: false,
            draining: false,
            members: vec![Vec::new(); m],
            devs: HashMap::new(),
            cpus: HashMap::new(),
            cloud_version: 0,
            edge_version: vec![0; m],
            landed_version: vec![0; m],
            adopted_cloud: vec![0; m],
            edge_last_update: vec![0; m],
            reported: vec![Vec::new(); m],
            training_count: vec![0; m],
            window_landings: vec![0; m],
            window_edge_aggs: vec![0; m],
            obs_up: vec![0.0; m],
            obs_down: vec![0.0; m],
            edge_faulted: vec![false; m],
            edge_partitioned: vec![false; m],
            win_abandoned: vec![0; m],
            win_compute: vec![0.0; m],
            win_up: vec![0.0; m],
            win_down: vec![0.0; m],
            win_comm: vec![0.0; m],
            win_overlap: vec![0.0; m],
            sweep_t: 0.0,
            tr_meta: HashMap::new(),
            actions: Vec::new(),
            scratch: Vec::new(),
            spare: Vec::new(),
            spare_jobs: Vec::new(),
            spare_mixes: Vec::new(),
            prof: ShardProfiler::default(),
            win_events: 0,
            win_voided: 0,
            win_flips: 0,
            win_outages: 0,
            win_partitions: 0,
            win_crashes: 0,
            queue_peak: 0,
            events_handled: 0,
            phys,
        }
    }

    /// Install (or refresh, after a re-cluster) edge `j`'s member list.
    pub(crate) fn install_edge(&mut self, j: usize, members: Vec<usize>) {
        self.members[j] = members;
    }

    /// Register a device this shard owns.
    pub(crate) fn install_device(
        &mut self,
        d: usize,
        edge: usize,
        active: bool,
        version: u64,
        cpu: CpuModel,
    ) {
        self.devs.insert(
            d,
            DevState {
                edge,
                active,
                version,
                mig_seq: 0,
                pend: None,
            },
        );
        self.cpus.insert(d, cpu);
    }

    /// Refresh the coordinator-owned knobs at a window start (the
    /// `set_control` re-arm path and the observer/profiler flags).
    pub(crate) fn refresh_knobs(
        &mut self,
        g1: &[usize],
        alpha: &[f64],
        obs_attached: bool,
        profile: bool,
        draining: bool,
    ) {
        self.g1.copy_from_slice(g1);
        self.alpha.copy_from_slice(alpha);
        self.obs_attached = obs_attached;
        self.profile = profile;
        self.prof.set_enabled(profile);
        self.draining = draining;
    }

    /// Take the window's action log (replay side), leaving the buffer
    /// behind for reuse.
    pub(crate) fn take_actions(&mut self) -> Vec<EngineAction> {
        std::mem::take(&mut self.actions)
    }

    /// Hand a replayed action log back: inner buffers return to the
    /// spare pools, the log itself becomes the next window's (cleared)
    /// action buffer. This is what keeps the dispatch / landed-view
    /// paths allocation-free in steady state.
    pub(crate) fn recycle(&mut self, mut acts: Vec<EngineAction>) {
        let cap = 2 * self.edges.len() + 4;
        for a in acts.drain(..) {
            match a {
                EngineAction::Dispatch { mut jobs, .. } => {
                    if self.spare_jobs.len() < cap {
                        jobs.clear();
                        self.spare_jobs.push(jobs);
                    }
                }
                EngineAction::EdgeAgg {
                    mut devs,
                    mut mixes,
                    ..
                } => {
                    if self.spare.len() < cap {
                        devs.clear();
                        self.spare.push(devs);
                    }
                    if self.spare_mixes.len() < cap {
                        mixes.clear();
                        self.spare_mixes.push(mixes);
                    }
                }
                EngineAction::Rejoin { devices, .. }
                | EngineAction::Transfer {
                    landing: Landing::Migration { devices, .. },
                    ..
                } => {
                    let mut v = devices;
                    if self.spare.len() < cap {
                        v.clear();
                        self.spare.push(v);
                    }
                }
                _ => {}
            }
        }
        if self.actions.capacity() < acts.capacity() {
            self.actions = acts;
        }
    }

    fn variant(ev: &Event) -> &'static str {
        match ev {
            Event::DeviceTrainDone { .. } => "train_done",
            Event::EdgeAggregate { .. } => "edge_aggregate",
            Event::TransferDone { .. } => "transfer_done",
            _ => "ctrl",
        }
    }

    /// Drain every event with `time <= bound`. Ctrl events never live in
    /// a shard heap, so within the bound this shard's timeline is
    /// completely independent of every other shard (module doc).
    pub(crate) fn advance(&mut self, bound: f64) {
        while let Some(tp) = self.queue.peek_time() {
            if tp > bound {
                break;
            }
            let w0 = if self.obs_attached {
                Some(std::time::Instant::now())
            } else {
                None
            };
            let (t, ev) = self.queue.pop().expect("peeked event vanished");
            if self.prof.enabled() {
                self.prof.sample_queue_depth(self.queue.len());
            }
            self.queue_peak = self.queue_peak.max(self.queue.len() + 1);
            self.sweep(t);
            let w1 = w0.map(|p| (p, std::time::Instant::now()));
            match ev {
                Event::DeviceTrainDone { device, edge } => {
                    self.on_train_done(device, edge, t)
                }
                Event::EdgeAggregate { edge } => {
                    self.on_edge_aggregate(edge, t)
                }
                Event::TransferDone { transfer } => {
                    self.on_transfer_done(transfer, t)
                }
                other => unreachable!("ctrl event {other:?} in shard heap"),
            }
            self.win_events += 1;
            self.events_handled += 1;
            if let Some((p0, p1)) = w1 {
                self.actions.push(EngineAction::Obs {
                    variant: Self::variant(&ev),
                    t,
                    lag_ns: (p1 - p0).as_nanos() as u64,
                    handler_ns: p1.elapsed().as_nanos() as u64,
                });
            }
        }
    }

    /// Busy-time integration since the last sweep (per own edge).
    fn sweep(&mut self, t: f64) {
        let dt = t - self.sweep_t;
        if dt <= 0.0 {
            return;
        }
        for i in 0..self.edges.len() {
            let j = self.edges[i];
            let c = self.training_count[j] > 0;
            let u = self.links.active_count(j, Direction::Up) > 0;
            let d = self.links.active_count(j, Direction::Down) > 0;
            if c {
                self.win_compute[j] += dt;
            }
            if u {
                self.win_up[j] += dt;
            }
            if d {
                self.win_down[j] += dt;
            }
            if u || d {
                self.win_comm[j] += dt;
            }
            if c && (u || d) {
                self.win_overlap[j] += dt;
            }
        }
        self.sweep_t = t;
    }

    /// Barrier entry: integrate busy time up to the barrier instant.
    pub(crate) fn barrier_sweep(&mut self, t: f64) {
        self.sweep(t);
    }

    /// Live member count of an owned edge (quorum denominator; also a
    /// barrier-side ctrl observable).
    pub(crate) fn live_members(&self, j: usize) -> usize {
        self.members[j]
            .iter()
            .filter(|d| self.devs.get(d).map(|s| s.active).unwrap_or(false))
            .count()
    }

    /// Dispatch whatever `scratch` holds, consuming it. Filters mirror
    /// the pre-shard engine: active, idle, not migrating, edge up.
    fn dispatch_scratch(&mut self, now: f64) {
        if self.draining || self.scratch.is_empty() {
            self.scratch.clear();
            return;
        }
        let devs = std::mem::take(&mut self.scratch);
        let mut jobs = self.spare_jobs.pop().unwrap_or_default();
        let w0 = if self.obs_attached && self.profile {
            Some(std::time::Instant::now())
        } else {
            None
        };
        for &d in &devs {
            let (j, ok) = match self.devs.get(&d) {
                Some(st) => (
                    st.edge,
                    st.active && st.pend.is_none() && st.mig_seq == 0,
                ),
                None => (0, false),
            };
            if !ok || self.edge_faulted[j] {
                continue;
            }
            let epochs = self.g1[j];
            let (t_dev, e_dev) = simulate_device(
                self.cpus.get_mut(&d).expect("dispatch without cpu"),
                &self.phys.energy,
                self.phys.nb,
                epochs,
            );
            let seed = self.job_rng.fork(d as u64).next_u64();
            let lag = self
                .phys
                .avail
                .as_ref()
                .map(|a| a.delay_until(d, now))
                .unwrap_or(0.0);
            let start_version = self.edge_version[j];
            let st = self.devs.get_mut(&d).expect("dispatch without state");
            st.pend = Some(PendMeta {
                void: false,
                start_version,
            });
            self.training_count[j] += 1;
            self.queue.schedule(
                now + lag + t_dev,
                Event::DeviceTrainDone { device: d, edge: j },
            );
            jobs.push(DispatchJob {
                device: d,
                edge: j,
                seed,
                epochs,
                start_version,
                t_dev,
                e_dev,
                lag,
            });
        }
        self.scratch = devs;
        self.scratch.clear();
        if jobs.is_empty() {
            self.spare_jobs.push(jobs);
            return;
        }
        let sim_wall_ns = w0
            .map(|p| p.elapsed().as_nanos() as u64)
            .unwrap_or(0);
        self.actions.push(EngineAction::Dispatch {
            t: now,
            jobs,
            sim_wall_ns,
        });
    }

    /// Fill `scratch` with the edge's over-selected cohort (semi-sync
    /// lifecycle path).
    fn cohort_into_scratch(&mut self, j: usize, t: f64) {
        let mut live = self.spare.pop().unwrap_or_default();
        live.clear();
        for i in 0..self.members[j].len() {
            let m = self.members[j][i];
            if self.devs.get(&m).map(|s| s.active).unwrap_or(false) {
                live.push(m);
            }
        }
        let quorum = match self.mode {
            SyncMode::SemiSync { quorum, .. } => quorum,
            _ => 0,
        };
        let k = effective_quorum(quorum, live.len());
        let n = overselect_count(k, self.overselect, live.len());
        let sel = select_dispatch(&live, n, self.phys.avail.as_ref(), t);
        self.scratch.clear();
        self.scratch.extend_from_slice(&sel);
        live.clear();
        self.spare.push(live);
    }

    /// First-window cohort: over-selected per edge in semi-sync with
    /// lifecycle on, every member otherwise.
    pub(crate) fn initial_dispatch(&mut self, t: f64) {
        let overselect = matches!(self.mode, SyncMode::SemiSync { .. })
            && self.overselect > 0.0;
        for i in 0..self.edges.len() {
            let j = self.edges[i];
            if overselect {
                self.cohort_into_scratch(j, t);
            } else {
                self.scratch.clear();
                for k in 0..self.members[j].len() {
                    let m = self.members[j][k];
                    self.scratch.push(m);
                }
            }
            self.dispatch_scratch(t);
        }
    }

    fn on_train_done(&mut self, d: usize, j: usize, t: f64) {
        let Some(st) = self.devs.get_mut(&d) else { return };
        let Some(pend) = st.pend.take() else { return };
        let tombstone = st.mig_seq == TOMBSTONE;
        let active = st.active;
        self.training_count[j] = self.training_count[j].saturating_sub(1);
        if pend.void {
            self.win_voided += 1;
            self.actions.push(EngineAction::Train {
                edge: j,
                device: d,
                outcome: TrainOutcome::Voided,
            });
            if tombstone {
                self.devs.remove(&d);
                return;
            }
            self.scratch.clear();
            self.scratch.push(d);
            self.dispatch_scratch(t);
            return;
        }
        if !active {
            self.actions.push(EngineAction::Train {
                edge: j,
                device: d,
                outcome: TrainOutcome::Departed,
            });
            return;
        }
        self.devs.get_mut(&d).expect("landed device").version =
            pend.start_version;
        self.actions.push(EngineAction::Train {
            edge: j,
            device: d,
            outcome: TrainOutcome::Landed,
        });
        self.reported[j].push(d);
        match self.mode {
            SyncMode::SemiSync { quorum, .. } => {
                if quorum_satisfied(
                    self.reported[j].len(),
                    quorum,
                    self.live_members(j),
                ) {
                    self.queue.schedule(t, Event::EdgeAggregate { edge: j });
                }
            }
            SyncMode::Async { .. } => {
                self.queue.schedule(t, Event::EdgeAggregate { edge: j });
            }
            SyncMode::Synchronous => {
                unreachable!("sync mode never runs on shards")
            }
        }
    }

    /// Void every in-flight member of `j` not already voided (the
    /// over-selection "close at K, cut the stragglers loose" rule).
    fn abandon_stragglers(&mut self, j: usize) {
        let mut dropped = 0;
        for i in 0..self.members[j].len() {
            let m = self.members[j][i];
            if let Some(st) = self.devs.get_mut(&m) {
                if let Some(p) = st.pend.as_mut() {
                    if !p.void {
                        p.void = true;
                        dropped += 1;
                    }
                }
            }
        }
        self.win_abandoned[j] += dropped;
    }

    fn on_edge_aggregate(&mut self, j: usize, t: f64) {
        if self.reported[j].is_empty() {
            return;
        }
        let devs = std::mem::replace(
            &mut self.reported[j],
            self.spare.pop().unwrap_or_default(),
        );
        let overselect = matches!(self.mode, SyncMode::SemiSync { .. })
            && self.overselect > 0.0;
        if overselect {
            self.abandon_stragglers(j);
        }
        let mut mixes = self.spare_mixes.pop().unwrap_or_default();
        match self.mode {
            SyncMode::SemiSync { .. } => {
                // Full aggregate: edge version +1, every member handle
                // re-points to the edge buffer at replay.
                self.edge_version[j] += 1;
                let v = self.edge_version[j];
                for i in 0..self.members[j].len() {
                    let m = self.members[j][i];
                    if let Some(st) = self.devs.get_mut(&m) {
                        st.version = v;
                    }
                }
            }
            SyncMode::Async { .. } => {
                // Staleness-discounted blend: betas are a pure function
                // of data sizes and version mirrors, so the shard can
                // compute them without model values.
                let mut edge_data = 0.0f32;
                for i in 0..self.members[j].len() {
                    edge_data += self.phys.data_n[self.members[j][i]];
                }
                let aj = self.alpha[j];
                for &d in &devs {
                    let s = self.edge_version[j]
                        .saturating_sub(self.devs[&d].version);
                    let share = self.phys.data_n[d] / edge_data;
                    mixes.push((d, share * staleness_discount(s, aj)));
                }
                self.edge_version[j] += 1;
                let v = self.edge_version[j];
                for &d in &devs {
                    self.devs.get_mut(&d).expect("reporter state").version =
                        v;
                }
            }
            SyncMode::Synchronous => {
                unreachable!("sync mode never runs on shards")
            }
        }
        self.window_edge_aggs[j] += 1;
        // Next cohort before `devs` moves into the action.
        if overselect {
            self.cohort_into_scratch(j, t);
        } else {
            self.scratch.clear();
            self.scratch.extend_from_slice(&devs);
        }
        self.actions.push(EngineAction::EdgeAgg {
            edge: j,
            devs,
            mixes,
        });
        self.start_upload(j, t);
        self.dispatch_scratch(t);
    }

    fn start_upload(&mut self, j: usize, t: f64) {
        if self.draining
            || self.edge_faulted[j]
            || self.edge_partitioned[j]
        {
            return;
        }
        let work = self.phys.net.one_way_time(
            self.phys.regions[j],
            self.phys.pbytes,
            self.phys.up_scale,
            &mut self.link_rng,
        );
        let (id, resched) =
            self.links.start(j, Direction::Up, self.phys.pbytes, work, t);
        self.tr_meta.insert(
            id,
            TrKind::Upload {
                version: self.edge_version[j],
            },
        );
        self.actions.push(EngineAction::UploadStart { edge: j, id });
        for (rid, ft) in resched {
            self.queue.schedule(ft, Event::TransferDone { transfer: rid });
        }
    }

    /// Barrier-side downlink start. Returns the shard-local transfer id
    /// so the coordinator can key the cloud-snapshot payload, or `None`
    /// when the edge can't receive (draining / faulted / partitioned).
    pub(crate) fn start_downlink(&mut self, j: usize, t: f64) -> Option<usize> {
        if self.draining
            || self.edge_faulted[j]
            || self.edge_partitioned[j]
        {
            return None;
        }
        let work = self.phys.net.one_way_time(
            self.phys.regions[j],
            self.phys.pbytes,
            self.phys.down_scale,
            &mut self.link_rng,
        );
        let (id, resched) =
            self.links.start(j, Direction::Down, self.phys.pbytes, work, t);
        self.tr_meta.insert(
            id,
            TrKind::Downlink {
                version: self.cloud_version,
            },
        );
        for (rid, ft) in resched {
            self.queue.schedule(ft, Event::TransferDone { transfer: rid });
        }
        Some(id)
    }

    /// Barrier-side migration warm-start downlink on the *destination*
    /// edge. Payload snapshot (the dest edge's model) is taken by the
    /// coordinator against the returned id.
    pub(crate) fn start_migration(
        &mut self,
        j: usize,
        devices: Vec<usize>,
        seq: u64,
        t: f64,
    ) -> Option<usize> {
        if self.draining {
            return None;
        }
        let work = self.phys.net.one_way_time(
            self.phys.regions[j],
            self.phys.pbytes,
            self.phys.down_scale,
            &mut self.link_rng,
        );
        let (id, resched) =
            self.links.start(j, Direction::Down, self.phys.pbytes, work, t);
        self.tr_meta.insert(
            id,
            TrKind::Migration {
                version: self.edge_version[j],
                devices,
                seq,
            },
        );
        for (rid, ft) in resched {
            self.queue.schedule(ft, Event::TransferDone { transfer: rid });
        }
        Some(id)
    }

    fn on_transfer_done(&mut self, id: usize, t: f64) {
        // Stale prediction → the event is dead (link layer re-predicted).
        let Some((tr, resched)) = self.links.poll(id, t) else {
            return;
        };
        for (rid, ft) in resched {
            self.queue.schedule(ft, Event::TransferDone { transfer: rid });
        }
        let meta = self
            .tr_meta
            .remove(&id)
            .expect("live transfer without meta");
        let j = tr.edge;
        let mut migrated = false;
        let landing = match meta {
            TrKind::Upload { version } => {
                self.obs_up[j] = tr.finish - tr.start;
                self.window_landings[j] += 1;
                self.edge_last_update[j] = self.cloud_version;
                let adopt = version > self.landed_version[j];
                if adopt {
                    self.landed_version[j] = version;
                }
                Landing::Upload { adopt }
            }
            TrKind::Downlink { version } => {
                self.obs_down[j] = tr.finish - tr.start;
                let adopt = version > self.adopted_cloud[j];
                if adopt {
                    self.adopted_cloud[j] = version;
                }
                Landing::Downlink { adopt }
            }
            TrKind::Migration {
                version,
                devices,
                seq,
            } => {
                self.obs_down[j] = tr.finish - tr.start;
                migrated = true;
                self.scratch.clear();
                for &d in &devices {
                    if let Some(st) = self.devs.get_mut(&d) {
                        if st.mig_seq == seq {
                            st.mig_seq = 0;
                            st.version = version;
                            self.scratch.push(d);
                        }
                    }
                }
                let mut applied = self.spare.pop().unwrap_or_default();
                applied.clear();
                applied.extend_from_slice(&self.scratch);
                Landing::Migration {
                    devices: applied,
                    seq,
                }
            }
        };
        self.actions.push(EngineAction::Transfer {
            id,
            edge: j,
            t,
            dir: tr.dir.name(),
            bytes: tr.bytes as f64,
            start: tr.start,
            finish: tr.finish,
            landing,
        });
        if migrated {
            // `scratch` still holds the applied devices: resume them.
            self.dispatch_scratch(t);
        }
    }

    /// Flush a pending quorum at a cloud barrier (partial-progress
    /// aggregation). No-op when nothing reported.
    pub(crate) fn flush_edge(&mut self, j: usize, t: f64) {
        if !self.reported[j].is_empty() {
            self.on_edge_aggregate(j, t);
        }
    }

    /// Re-check a semi-sync quorum after membership shrank (flip, crash,
    /// outage): a smaller live set can satisfy a pending quorum.
    pub(crate) fn recheck_quorum(&mut self, j: usize, t: f64) {
        let SyncMode::SemiSync { quorum, .. } = self.mode else {
            return;
        };
        if !self.reported[j].is_empty()
            && quorum_satisfied(
                self.reported[j].len(),
                quorum,
                self.live_members(j),
            )
        {
            self.queue.schedule(t, Event::EdgeAggregate { edge: j });
        }
    }

    /// Apply one mobility flip to an owned device: purge its report,
    /// void any in-flight result, cancel a pending migration, set the
    /// new active state. Rejoin effects (re-point + re-dispatch) go
    /// through [`Self::rejoin_devices`].
    pub(crate) fn apply_flip(&mut self, d: usize, active_now: bool) {
        let Some(st) = self.devs.get_mut(&d) else { return };
        let j = st.edge;
        st.active = active_now;
        if st.mig_seq != TOMBSTONE {
            st.mig_seq = 0;
        }
        if let Some(p) = st.pend.as_mut() {
            p.void = true;
        }
        self.win_flips += 1;
        self.reported[j].retain(|&x| x != d);
    }

    /// Re-sync rejoining devices to their edge model and re-dispatch
    /// them (churn rejoin, crash recovery). Emits one `Rejoin` action
    /// per own edge in edge order.
    pub(crate) fn rejoin_devices(&mut self, devs: &[usize], t: f64) {
        for i in 0..self.edges.len() {
            let j = self.edges[i];
            let mut group = self.spare.pop().unwrap_or_default();
            group.clear();
            for &d in devs {
                let Some(st) = self.devs.get_mut(&d) else { continue };
                if st.edge == j {
                    st.version = self.edge_version[j];
                    group.push(d);
                }
            }
            if group.is_empty() {
                self.spare.push(group);
            } else {
                self.actions.push(EngineAction::Rejoin {
                    edge: j,
                    devices: group,
                });
            }
        }
        let overselect = matches!(self.mode, SyncMode::SemiSync { .. })
            && self.overselect > 0.0;
        if overselect {
            // Lifecycle path: fresh cohorts for the touched edges.
            let mut touched = self.spare.pop().unwrap_or_default();
            touched.clear();
            for &d in devs {
                if let Some(st) = self.devs.get(&d) {
                    if !touched.contains(&st.edge) {
                        touched.push(st.edge);
                    }
                }
            }
            touched.sort_unstable();
            for i in 0..touched.len() {
                let j = touched[i];
                self.cohort_into_scratch(j, t);
                self.dispatch_scratch(t);
            }
            touched.clear();
            self.spare.push(touched);
        } else {
            self.scratch.clear();
            self.scratch.extend_from_slice(devs);
            self.dispatch_scratch(t);
        }
    }

    /// An edge-server outage (`up == false`) or recovery. Returns
    /// whether the event changed state (for fault accounting).
    pub(crate) fn apply_outage(&mut self, j: usize, up: bool, t: f64) -> bool {
        if !up {
            if self.edge_faulted[j] {
                return false;
            }
            self.edge_faulted[j] = true;
            self.win_outages += 1;
            self.reported[j].clear();
            self.abandon_stragglers(j);
            true
        } else {
            if !self.edge_faulted[j] {
                return false;
            }
            self.edge_faulted[j] = false;
            let mut idle = self.spare.pop().unwrap_or_default();
            idle.clear();
            for i in 0..self.members[j].len() {
                let m = self.members[j][i];
                if let Some(st) = self.devs.get(&m) {
                    if st.active && st.pend.is_none() && st.mig_seq == 0 {
                        idle.push(m);
                    }
                }
            }
            self.scratch.clear();
            self.scratch.extend_from_slice(&idle);
            let resume = std::mem::take(&mut self.scratch);
            self.rejoin_devices(&resume, t);
            self.scratch = resume;
            idle.clear();
            self.spare.push(idle);
            true
        }
    }

    /// Sever (`up == false`) or heal the edge↔cloud path of every owned
    /// edge whose bit is set. Returns how many owned edges changed.
    pub(crate) fn apply_partition(&mut self, mask: u64, up: bool) -> usize {
        let mut touched = 0;
        for i in 0..self.edges.len() {
            let j = self.edges[i];
            if (mask >> (j % 64)) & 1 == 1 {
                let sever = !up;
                if self.edge_partitioned[j] != sever {
                    self.edge_partitioned[j] = sever;
                    touched += 1;
                    if sever {
                        self.win_partitions += 1;
                    }
                }
            }
        }
        touched
    }

    /// Crash (`up == false`) or rejoin the storm's deterministic device
    /// subset among owned devices. Returns the devices whose active
    /// state changed, so the coordinator can sync its mobility model.
    pub(crate) fn apply_crash_storm(
        &mut self,
        storm: u64,
        frac_bits: u32,
        up: bool,
        t: f64,
    ) -> Vec<usize> {
        let mut changed = Vec::new();
        if !up {
            for i in 0..self.edges.len() {
                let j = self.edges[i];
                let mut hit_edge = false;
                for k in 0..self.members[j].len() {
                    let m = self.members[j][k];
                    if !storm_hits(storm, m, frac_bits) {
                        continue;
                    }
                    let Some(st) = self.devs.get_mut(&m) else { continue };
                    if !st.active {
                        continue;
                    }
                    st.active = false;
                    if st.mig_seq != TOMBSTONE {
                        st.mig_seq = 0;
                    }
                    if let Some(p) = st.pend.as_mut() {
                        if !p.void {
                            p.void = true;
                            self.win_abandoned[j] += 1;
                        }
                    }
                    self.reported[j].retain(|&x| x != m);
                    changed.push(m);
                    hit_edge = true;
                    self.win_crashes += 1;
                }
                if hit_edge {
                    self.recheck_quorum(j, t);
                }
            }
        } else {
            for i in 0..self.edges.len() {
                let j = self.edges[i];
                for k in 0..self.members[j].len() {
                    let m = self.members[j][k];
                    if !storm_hits(storm, m, frac_bits) {
                        continue;
                    }
                    let Some(st) = self.devs.get_mut(&m) else { continue };
                    if st.active {
                        continue;
                    }
                    st.active = true;
                    changed.push(m);
                }
            }
            if !changed.is_empty() {
                let rejoined = std::mem::take(&mut changed);
                self.rejoin_devices(&rejoined, t);
                changed = rejoined;
            }
        }
        changed
    }

    /// Move a device out (re-cluster migration). If a training result
    /// is still in flight, a voided tombstone stays behind to absorb
    /// the stale `DeviceTrainDone`.
    pub(crate) fn migrate_out(
        &mut self,
        d: usize,
        new_edge: usize,
        seq: u64,
    ) -> Option<(bool, u64, CpuModel)> {
        let st = self.devs.get_mut(&d)?;
        let old_edge = st.edge;
        let active = st.active;
        let version = st.version;
        self.reported[old_edge].retain(|&x| x != d);
        if let Some(p) = st.pend.as_mut() {
            p.void = true;
            // Tombstone: the pending DeviceTrainDone still targets this
            // shard's heap.
            st.active = false;
            st.mig_seq = TOMBSTONE;
        } else {
            self.devs.remove(&d);
        }
        let cpu = self.cpus.remove(&d).expect("device without cpu");
        let _ = new_edge;
        Some((active, version, cpu))
    }

    /// Re-cluster migration within one shard (source and destination
    /// edge share the owner): no tombstone needed — the device entry
    /// moves edges in place, any in-flight result is voided, and the
    /// device parks until warm-start `seq` lands.
    pub(crate) fn migrate_local(
        &mut self,
        d: usize,
        new_edge: usize,
        seq: u64,
    ) -> Option<(bool, u64)> {
        let old_edge = self.devs.get(&d)?.edge;
        self.reported[old_edge].retain(|&x| x != d);
        let st = self.devs.get_mut(&d)?;
        if let Some(p) = st.pend.as_mut() {
            p.void = true;
        }
        st.edge = new_edge;
        st.mig_seq = seq;
        Some((st.active, st.version))
    }

    /// Receive a migrating device; it resumes when the warm-start
    /// downlink tagged `seq` lands.
    pub(crate) fn migrate_in(
        &mut self,
        d: usize,
        edge: usize,
        active: bool,
        version: u64,
        seq: u64,
        cpu: CpuModel,
    ) {
        self.devs.insert(
            d,
            DevState {
                edge,
                active,
                version,
                mig_seq: seq,
                pend: None,
            },
        );
        self.cpus.insert(d, cpu);
    }

    /// Update the cloud-version mirror after a barrier aggregation.
    pub(crate) fn set_cloud_version(&mut self, v: u64) {
        self.cloud_version = v;
    }

    /// Per-edge window observables consumed by the coordinator's
    /// barrier, then reset for the next window.
    pub(crate) fn window_reset_edge(&mut self, j: usize) {
        self.window_landings[j] = 0;
        self.obs_up[j] = 0.0;
        self.obs_down[j] = 0.0;
        self.win_compute[j] = 0.0;
        self.win_up[j] = 0.0;
        self.win_down[j] = 0.0;
        self.win_comm[j] = 0.0;
        self.win_overlap[j] = 0.0;
    }

    /// In-flight uplink count of an owned edge (barrier-side ctrl
    /// observable).
    pub(crate) fn uplink_in_flight(&self, j: usize) -> usize {
        self.links.active_count(j, Direction::Up)
    }

    /// Reported-quorum fill of an owned edge (barrier-side ctrl
    /// observable).
    pub(crate) fn reported_len(&self, j: usize) -> usize {
        self.reported[j].len()
    }

    /// Drain the window's profiler counters into a profile row.
    pub(crate) fn drain_profile(
        &mut self,
    ) -> crate::obs::profiler::ShardWindowProfile {
        let mut p = crate::obs::profiler::ShardWindowProfile {
            shard: self.id,
            events: self.win_events,
            voided: self.win_voided,
            aggregates: self
                .edges
                .iter()
                .map(|&j| self.window_edge_aggs[j] as u64)
                .sum(),
            flips: self.win_flips,
            live_devices: self.devs.values().filter(|s| s.active).count(),
            queue_depth_peak: self.queue_peak,
            queue_len_end: self.queue.len(),
            outages: self.win_outages,
            partitions: self.win_partitions,
            crashes: self.win_crashes,
            ..Default::default()
        };
        self.prof.drain_into(&mut p);
        self.win_events = 0;
        self.win_voided = 0;
        self.win_flips = 0;
        self.win_outages = 0;
        self.win_partitions = 0;
        self.win_crashes = 0;
        self.queue_peak = 0;
        p
    }
}

// ---------------------------------------------------------------------------
// Training-free engine-timeline harness
// ---------------------------------------------------------------------------

/// Spec of a [`ShardedEngineLoop`] run. Everything here is part of the
/// deterministic trajectory **except** `workers` and `backend`, whose
/// invisibility is the point (CI diffs the CSV across both).
#[derive(Clone, Debug)]
pub struct EngineLoopSpec {
    pub devices: usize,
    pub edges: usize,
    /// Cloud windows to run.
    pub windows: usize,
    /// Shard-advance worker threads (0 = all cores).
    pub workers: usize,
    /// 0 = auto (`min(edges, 64)`).
    pub shards: usize,
    pub seed: u64,
    pub backend: QueueBackend,
    /// `false` = semi-sync (quorum below), `true` = fully async.
    pub asynchronous: bool,
    /// Semi-sync quorum (0 = all live members).
    pub quorum: usize,
    pub overselect: f64,
    pub staleness_alpha: f64,
    /// Cloud interval, simulated seconds.
    pub interval: f64,
    /// Local epochs per dispatch (uniform γ1).
    pub epochs: usize,
    /// Minibatches per epoch in the CPU simulation.
    pub nb: usize,
    pub leave_prob: f64,
    pub join_prob: f64,
    pub fault: FaultConfig,
}

impl Default for EngineLoopSpec {
    fn default() -> Self {
        EngineLoopSpec {
            devices: 10_000,
            edges: 64,
            windows: 4,
            workers: 1,
            shards: 0,
            seed: 7,
            backend: QueueBackend::Auto,
            asynchronous: false,
            quorum: 4,
            overselect: 0.0,
            staleness_alpha: 0.5,
            interval: 60.0,
            epochs: 2,
            nb: 4,
            leave_prob: 0.0,
            join_prob: 0.0,
            fault: FaultConfig {
                outages: 0,
                outage_duration: 30.0,
                partitions: 0,
                partition_duration: 30.0,
                crash_storms: 0,
                crash_frac: 0.0,
                rejoin_delay: 30.0,
            },
        }
    }
}

impl EngineLoopSpec {
    pub fn resolved_shards(&self) -> usize {
        if self.shards == 0 {
            EngineShard::auto_shards(self.edges)
        } else {
            self.shards.clamp(1, self.edges.max(1))
        }
    }

    pub fn resolved_workers(&self) -> usize {
        let w = match self.workers {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            w => w,
        };
        w.min(self.resolved_shards())
    }
}

/// One cloud window of the harness trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineWindowRow {
    pub window: usize,
    pub sim_time: f64,
    /// Events handled this window (all shards).
    pub events: u64,
    /// Upload landings this window.
    pub landings: u64,
    /// Edge aggregations this window.
    pub aggregates: u64,
    /// Mobility flips applied this window.
    pub flips: u64,
    /// Fault events applied this window.
    pub faults: u64,
    /// Fold of the full action stream, fixed shard order.
    pub checksum: u64,
}

#[derive(Default)]
struct ShardReport {
    actions: Vec<EngineAction>,
    changed: Vec<usize>,
    events: u64,
}

/// The full `AsyncHflEngine` event loop minus the model math: per-edge
/// [`EngineShard`]s on a [`ShardPool`], barrier-ordered ctrl events
/// (cloud windows, churn flips, seeded faults), and per-window action
/// checksums in fixed shard order instead of a replay phase. No
/// artifacts, no model store — this is what CI diffs across worker
/// counts and the engine-level `threads_speedup` bench times.
pub struct ShardedEngineLoop {
    spec: EngineLoopSpec,
    pool: ShardPool<EngineShard, ShardReport>,
    ctrl: EventQueue,
    mobility: MobilityModel,
    dev_shard: Vec<usize>,
    cloud_version: u64,
    now: f64,
    g1: Vec<usize>,
    alpha: Vec<f64>,
    win_flips: u64,
    win_faults: u64,
    win_events: u64,
    win_landings: u64,
    win_aggs: u64,
    checksum: u64,
    history: Vec<EngineWindowRow>,
    windows_done: usize,
}

impl ShardedEngineLoop {
    pub fn new(spec: &EngineLoopSpec) -> Self {
        let n = spec.devices;
        let m = spec.edges;
        let n_shards = spec.resolved_shards();
        let workers = spec.resolved_workers();
        let sim_cfg = crate::config::ExperimentConfig::mnist().sim;
        let mode = if spec.asynchronous {
            SyncMode::Async {
                staleness_alpha: spec.staleness_alpha,
                cloud_interval: spec.interval,
            }
        } else {
            SyncMode::SemiSync {
                quorum: spec.quorum,
                cloud_interval: spec.interval,
            }
        };
        let regions: Vec<Region> = (0..m)
            .map(|j| if j % 2 == 0 { Region::Us } else { Region::Cn })
            .collect();
        let phys = ShardPhysics {
            nb: spec.nb,
            pbytes: crate::sim::network::model_bytes(7850),
            up_scale: 1.0,
            down_scale: 1.0,
            contention: true,
            net: NetworkModel::from_config(&sim_cfg),
            energy: EnergyModel::new(sim_cfg.power_idle, sim_cfg.power_max),
            avail: None,
            regions,
            data_n: Arc::new(vec![1.0; n]),
            mode,
            overselect: spec.overselect,
        };
        let expected = (n / n_shards.max(1)) * 4 + 64;
        let mut shards: Vec<EngineShard> = (0..n_shards)
            .map(|s| {
                EngineShard::new(
                    s,
                    n_shards,
                    spec.seed,
                    spec.backend,
                    expected,
                    phys.clone(),
                )
            })
            .collect();
        // Canonical population: device d on edge d % m, CPU streams
        // forked in device order from one master stream.
        let mut cpu_rng = Rng::new(spec.seed ^ 0xc4_9u64);
        let mut dev_shard = Vec::with_capacity(n);
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); m];
        for d in 0..n {
            let j = d % m;
            members[j].push(d);
            let s = EngineShard::shard_of(j, n_shards);
            dev_shard.push(s);
            let cpu = CpuModel::new(
                CpuModel::paper_class(d % 5),
                0.05,
                2.0,
                0.1,
                cpu_rng.fork(d as u64),
            );
            shards[s].install_device(d, j, true, 0, cpu);
        }
        for (j, mem) in members.into_iter().enumerate() {
            let s = EngineShard::shard_of(j, n_shards);
            shards[s].install_edge(j, mem);
        }
        let g1 = vec![spec.epochs.max(1); m];
        let alpha = vec![spec.staleness_alpha; m];
        for sh in shards.iter_mut() {
            sh.refresh_knobs(&g1, &alpha, false, false, false);
            sh.initial_dispatch(0.0);
        }
        let mut ctrl =
            EventQueue::for_scale(spec.seed ^ 0xa57c, 64, spec.backend);
        ctrl.schedule(spec.interval, Event::CloudAggregate);
        if spec.leave_prob > 0.0 || spec.join_prob > 0.0 {
            ctrl.schedule(0.5 * spec.interval, Event::MobilityFlip);
        }
        let horizon = spec.windows as f64 * spec.interval;
        let plan = FaultPlan::build(&spec.fault, m, horizon, spec.seed);
        for &(t, ev) in plan.events() {
            ctrl.schedule(t, ev);
        }
        let mut this = ShardedEngineLoop {
            spec: spec.clone(),
            pool: ShardPool::new(workers, shards),
            ctrl,
            mobility: MobilityModel::new(
                n,
                spec.leave_prob,
                spec.join_prob,
                Rng::new(spec.seed ^ 0x0b17),
            ),
            dev_shard,
            cloud_version: 0,
            now: 0.0,
            g1,
            alpha,
            win_flips: 0,
            win_faults: 0,
            win_events: 0,
            win_landings: 0,
            win_aggs: 0,
            checksum: 0xcbf2_9ce4_8422_2325,
            history: Vec::new(),
            windows_done: 0,
        };
        // Fold the initial dispatch burst into window 0's checksum.
        this.collect(this.pool_take_actions());
        this
    }

    fn pool_take_actions(&mut self) -> Vec<ShardReport> {
        self.pool.run(|_, sh| ShardReport {
            actions: sh.take_actions(),
            changed: Vec::new(),
            events: 0,
        })
    }

    /// Fold per-shard reports (already in fixed shard order) into the
    /// window counters and checksum.
    fn collect(&mut self, reports: Vec<ShardReport>) {
        for r in &reports {
            fold_actions(&mut self.checksum, &r.actions);
            self.win_events += r.events;
            for a in &r.actions {
                match a {
                    EngineAction::EdgeAgg { .. } => self.win_aggs += 1,
                    EngineAction::Transfer {
                        landing: Landing::Upload { .. },
                        ..
                    } => self.win_landings += 1,
                    _ => {}
                }
            }
        }
    }

    /// Advance every shard to `bound` (parallel) and fold the action
    /// streams in shard order.
    fn advance_all(&mut self, bound: f64) {
        let reports = self.pool.run(move |_, sh| {
            let before = sh.events_handled;
            sh.advance(bound);
            ShardReport {
                actions: sh.take_actions(),
                changed: Vec::new(),
                events: sh.events_handled - before,
            }
        });
        self.collect(reports);
    }

    /// Run to completion (all configured windows).
    pub fn run(&mut self) {
        while self.windows_done < self.spec.windows {
            let Some(t_ctrl) = self.ctrl.peek_time() else {
                // Ctrl queue drained (no more cloud events): done.
                break;
            };
            self.advance_all(t_ctrl);
            let (t, ev) = self.ctrl.pop().expect("peeked ctrl vanished");
            self.now = t;
            match ev {
                Event::CloudAggregate => self.cloud_barrier(t),
                Event::MobilityFlip => self.flip_barrier(t),
                Event::EdgeOutage { edge, up } => {
                    let reports = self.pool.run(move |_, sh| {
                        let mut rep = ShardReport::default();
                        if sh.edges.contains(&edge)
                            && sh.apply_outage(edge, up, t)
                        {
                            rep.events = 1;
                        }
                        rep.actions = sh.take_actions();
                        rep
                    });
                    self.win_faults +=
                        reports.iter().map(|r| r.events).sum::<u64>();
                    self.collect(reports);
                }
                Event::Partition { mask, up } => {
                    let reports = self.pool.run(move |_, sh| {
                        let touched = sh.apply_partition(mask, up);
                        ShardReport {
                            actions: sh.take_actions(),
                            changed: Vec::new(),
                            events: touched as u64,
                        }
                    });
                    self.win_faults +=
                        reports.iter().map(|r| r.events).sum::<u64>();
                    self.collect(reports);
                }
                Event::CrashStorm {
                    seed,
                    frac_bits,
                    up,
                } => {
                    let reports = self.pool.run(move |_, sh| {
                        let changed =
                            sh.apply_crash_storm(seed, frac_bits, up, t);
                        ShardReport {
                            actions: sh.take_actions(),
                            events: changed.len() as u64,
                            changed,
                        }
                    });
                    for r in &reports {
                        for &d in &r.changed {
                            self.mobility.set_active(d, up);
                        }
                    }
                    self.win_faults +=
                        reports.iter().map(|r| r.events).sum::<u64>();
                    self.collect(reports);
                }
                other => {
                    unreachable!("unexpected ctrl event {other:?}")
                }
            }
        }
    }

    fn cloud_barrier(&mut self, t: f64) {
        self.cloud_version += 1;
        let v = self.cloud_version;
        let reports = self.pool.run(move |_, sh| {
            sh.barrier_sweep(t);
            let mut rep = ShardReport::default();
            for i in 0..sh.edges.len() {
                let j = sh.edges[i];
                sh.flush_edge(j, t);
            }
            sh.set_cloud_version(v);
            for i in 0..sh.edges.len() {
                let j = sh.edges[i];
                let _ = sh.start_downlink(j, t);
                sh.window_edge_aggs[j] = 0;
                sh.window_reset_edge(j);
            }
            rep.actions = sh.take_actions();
            rep
        });
        self.collect(reports);
        self.history.push(EngineWindowRow {
            window: self.windows_done,
            sim_time: t,
            events: std::mem::take(&mut self.win_events),
            landings: std::mem::take(&mut self.win_landings),
            aggregates: std::mem::take(&mut self.win_aggs),
            flips: std::mem::take(&mut self.win_flips),
            faults: std::mem::take(&mut self.win_faults),
            checksum: self.checksum,
        });
        self.windows_done += 1;
        if self.windows_done < self.spec.windows {
            self.ctrl
                .schedule(t + self.spec.interval, Event::CloudAggregate);
        }
    }

    fn flip_barrier(&mut self, t: f64) {
        let flips = self.mobility.step();
        self.win_flips += flips.total() as u64;
        let flipped = self.mobility.flipped().to_vec();
        let n_shards = self.pool.n_shards();
        // Partition flips by owning shard (fixed mapping).
        let mut parts: Vec<Vec<(usize, bool)>> =
            (0..n_shards).map(|_| Vec::new()).collect();
        let mut rejoins: Vec<Vec<usize>> =
            (0..n_shards).map(|_| Vec::new()).collect();
        for &d in &flipped {
            let s = self.dev_shard[d];
            let active = self.mobility.is_active(d);
            parts[s].push((d, active));
            if active {
                rejoins[s].push(d);
            }
        }
        let parts = Arc::new(parts);
        let rejoins = Arc::new(rejoins);
        let reports = self.pool.run(move |idx, sh| {
            for &(d, active) in &parts[idx] {
                sh.apply_flip(d, active);
            }
            if !rejoins[idx].is_empty() {
                sh.rejoin_devices(&rejoins[idx], t);
            }
            // Shrunken quorums may now be satisfiable.
            for i in 0..sh.edges.len() {
                let j = sh.edges[i];
                sh.recheck_quorum(j, t);
            }
            ShardReport {
                actions: sh.take_actions(),
                changed: Vec::new(),
                events: 0,
            }
        });
        self.collect(reports);
        self.ctrl
            .schedule(t + self.spec.interval, Event::MobilityFlip);
    }

    pub fn history(&self) -> &[EngineWindowRow] {
        &self.history
    }

    /// The trajectory as CSV — the exact bytes CI diffs across
    /// `workers` × `backend`.
    pub fn csv_string(&self) -> String {
        let mut out = String::from(
            "window,sim_time,events,landings,aggregates,flips,faults,\
             checksum\n",
        );
        for r in &self.history {
            out.push_str(&format!(
                "{},{:.6},{},{},{},{},{},{:016x}\n",
                r.window,
                r.sim_time,
                r.events,
                r.landings,
                r.aggregates,
                r.flips,
                r.faults,
                r.checksum,
            ));
        }
        out
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.csv_string().as_bytes())
    }

    /// Total events handled across all shards (post-run; tears nothing
    /// down — the pool stays usable).
    pub fn total_events(&self) -> u64 {
        self.history.iter().map(|r| r.events).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hfl::lifecycle::frac_to_bits;

    fn stress_spec(workers: usize, backend: QueueBackend) -> EngineLoopSpec {
        EngineLoopSpec {
            devices: 600,
            edges: 24,
            windows: 4,
            workers,
            seed: 42,
            backend,
            asynchronous: false,
            quorum: 3,
            overselect: 1.5,
            interval: 40.0,
            leave_prob: 0.2,
            join_prob: 0.3,
            fault: FaultConfig {
                outages: 2,
                outage_duration: 15.0,
                partitions: 1,
                partition_duration: 10.0,
                crash_storms: 2,
                crash_frac: 0.2,
                rejoin_delay: 12.0,
            },
            ..EngineLoopSpec::default()
        }
    }

    #[test]
    fn engine_loop_is_bitwise_identical_across_workers_and_backends() {
        let reference = {
            let mut sim =
                ShardedEngineLoop::new(&stress_spec(1, QueueBackend::Binary));
            sim.run();
            sim.csv_string()
        };
        assert!(reference.lines().count() > 4, "no windows ran");
        for workers in [2usize, 3, 8] {
            for backend in [QueueBackend::Binary, QueueBackend::Calendar] {
                let mut sim =
                    ShardedEngineLoop::new(&stress_spec(workers, backend));
                sim.run();
                assert_eq!(
                    sim.csv_string(),
                    reference,
                    "workers={workers} backend={}",
                    backend.name()
                );
            }
        }
    }

    #[test]
    fn engine_loop_async_mode_is_deterministic_too() {
        let spec = EngineLoopSpec {
            asynchronous: true,
            overselect: 0.0,
            ..stress_spec(1, QueueBackend::Binary)
        };
        let reference = {
            let mut sim = ShardedEngineLoop::new(&spec);
            sim.run();
            sim.csv_string()
        };
        let mut par = ShardedEngineLoop::new(&EngineLoopSpec {
            workers: 8,
            backend: QueueBackend::Calendar,
            ..spec
        });
        par.run();
        assert_eq!(par.csv_string(), reference);
    }

    #[test]
    fn engine_loop_faults_actually_fire() {
        let mut sim =
            ShardedEngineLoop::new(&stress_spec(2, QueueBackend::Binary));
        sim.run();
        let faults: u64 = sim.history().iter().map(|r| r.faults).sum();
        assert!(faults > 0, "fault plan injected nothing");
        let flips: u64 = sim.history().iter().map(|r| r.flips).sum();
        assert!(flips > 0, "churn injected nothing");
        assert!(sim.total_events() > 1000);
    }

    #[test]
    fn fold_actions_distinguishes_streams() {
        let a = vec![EngineAction::Train {
            edge: 1,
            device: 2,
            outcome: TrainOutcome::Landed,
        }];
        let b = vec![EngineAction::Train {
            edge: 1,
            device: 2,
            outcome: TrainOutcome::Voided,
        }];
        let (mut ha, mut hb) = (0u64, 0u64);
        fold_actions(&mut ha, &a);
        fold_actions(&mut hb, &b);
        assert_ne!(ha, hb);
        // Wall-clock fields never perturb a checksum.
        let o1 = vec![EngineAction::Obs {
            variant: "train_done",
            t: 1.0,
            lag_ns: 5,
            handler_ns: 9,
        }];
        let o2 = vec![EngineAction::Obs {
            variant: "train_done",
            t: 1.0,
            lag_ns: 77,
            handler_ns: 1,
        }];
        let (mut h1, mut h2) = (0u64, 0u64);
        fold_actions(&mut h1, &o1);
        fold_actions(&mut h2, &o2);
        assert_eq!(h1, h2);
    }

    #[test]
    fn spec_resolves_shard_and_worker_counts() {
        let spec = EngineLoopSpec {
            edges: 100,
            shards: 0,
            workers: 8,
            ..EngineLoopSpec::default()
        };
        assert_eq!(spec.resolved_shards(), 64);
        assert_eq!(spec.resolved_workers(), 8);
        let tiny = EngineLoopSpec {
            edges: 3,
            shards: 0,
            workers: 16,
            ..EngineLoopSpec::default()
        };
        assert_eq!(tiny.resolved_shards(), 3);
        // Workers clamp to the shard count — shards define the
        // trajectory, workers only the speed.
        assert_eq!(tiny.resolved_workers(), 3);
    }

    #[test]
    fn frac_bits_roundtrip_used_by_storms() {
        // Guards the storm predicate the shards rely on.
        let bits = frac_to_bits(0.25);
        let hits = (0..10_000usize)
            .filter(|&d| storm_hits(99, d, bits))
            .count();
        let frac = hits as f64 / 10_000.0;
        assert!((frac - 0.25).abs() < 0.05, "storm fraction {frac}");
    }
}
