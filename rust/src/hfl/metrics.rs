//! Round/run metrics: everything the DRL state (paper Eq. 7-9), the reward
//! (Eq. 11) and the experiment harnesses need.

use crate::util::csv::CsvWriter;
use crate::util::json::Json;

/// Version of the emitted result schemas: the `RunHistory` CSV header
/// comment, the per-round JSON objects and the `/stream` NDJSON frames
/// all carry it so dashboards can evolve without silent breakage. Bump
/// on any backwards-incompatible column/field change.
///
/// v2: client-lifecycle columns (`abandoned`, `mean_availability`,
/// `fault_events`) appended to the history CSV and round JSON.
pub const SCHEMA_VERSION: usize = 2;

/// Per-edge observables h_j(k) of paper Eq. (7), plus bookkeeping.
///
/// Since the transfer layer (`sim::link`) landed, communication fields are
/// *observed* from completed transfers, not resampled: `t_up`/`t_down` are
/// the durations of the last uplink/downlink transfer that landed for this
/// edge, and `t_ec = t_up + t_down` is the observed round trip that feeds
/// the DRL state. The `*_busy` fields split the round into compute vs
/// in-flight communication time so overlap is first-class.
#[derive(Clone, Debug, Default)]
pub struct EdgeStats {
    /// Local SGD time of the slowest device under this edge (T_j^SGD).
    pub t_sgd_slowest: f64,
    /// Edge→cloud communication time (T_j^ec), observed: `t_up + t_down`.
    pub t_ec: f64,
    /// Duration of the edge's last completed uplink transfer.
    pub t_up: f64,
    /// Duration of the edge's last completed downlink transfer.
    pub t_down: f64,
    /// Device energy consumed under this edge this round, mAh (E_j).
    pub energy: f64,
    /// Active devices that trained this round.
    pub active: usize,
    /// Wall (simulated) time this edge needed for the whole round.
    pub total_time: f64,
    /// Seconds of the round with ≥1 member device training.
    pub compute_busy: f64,
    /// Seconds with ≥1 transfer in flight on the edge's uplink.
    pub up_busy: f64,
    /// Seconds with ≥1 transfer in flight on the edge's downlink.
    pub down_busy: f64,
    /// Seconds with ≥1 transfer in flight on *either* of the edge's links
    /// (interval union, ≤ `up_busy + down_busy`).
    pub comm_busy: f64,
    /// Seconds during which compute and communication were both in flight
    /// (0 under the barrier engine: it never overlaps them).
    pub comm_overlap: f64,
    /// Observed staleness of the edge's last landed upload, in cloud
    /// windows: how many cloud aggregations ago the cloud last saw a fresh
    /// model from this edge, measured at the cloud's decision point
    /// (0 under the barrier engine — every round lands every edge).
    pub staleness: f64,
    /// Uploads in flight on the edge's uplink at the cloud decision point.
    pub in_flight_up: usize,
    /// Semi-sync quorum fill at the cloud decision point: outstanding
    /// device reports over the effective (live-clamped) quorum. 0 in the
    /// other modes (async reports aggregate immediately).
    pub quorum_fill: f64,
    /// Over-selected stragglers abandoned (voided after the first-K
    /// close) at this edge this round (`hfl::lifecycle`). 0 with
    /// over-selection off.
    pub abandoned: usize,
    /// Fraction of the edge's members inside their availability window
    /// at the cloud decision point. Engines record 1.0 when pace
    /// steering is off (every device always available).
    pub availability: f64,
}

impl EdgeStats {
    /// (uplink, downlink) busy fraction of a `window`-second round.
    pub fn link_util(&self, window: f64) -> (f64, f64) {
        if window <= 0.0 {
            return (0.0, 0.0);
        }
        (self.up_busy / window, self.down_busy / window)
    }

    /// Fraction of this edge's dispatched work abandoned by the
    /// over-selection close (0 when nothing was dispatched).
    pub fn abandon_rate(&self) -> f64 {
        let total = self.active + self.abandoned;
        if total == 0 {
            0.0
        } else {
            self.abandoned as f64 / total as f64
        }
    }
}

/// One cloud-aggregation round.
#[derive(Clone, Debug)]
pub struct RoundStats {
    pub k: usize,
    /// Test accuracy after the round's cloud aggregation (A_test(k)).
    pub accuracy: f64,
    pub test_loss: f64,
    pub train_loss: f64,
    /// Straggler-path simulated duration of the round (T_use(k)).
    pub round_time: f64,
    /// Simulated clock after the round.
    pub sim_now: f64,
    pub per_edge: Vec<EdgeStats>,
    /// Total device energy this round, mAh (E(k)).
    pub energy: f64,
    /// Frequencies that were executed.
    pub gamma1: Vec<usize>,
    pub gamma2: Vec<usize>,
    /// (device, last-epoch mean loss) for every device that trained.
    pub device_losses: Vec<(usize, f64)>,
    /// Membership subsystem (`hfl::membership`): re-clusterings executed
    /// during this round/window.
    pub n_reclusters: usize,
    /// Devices migrated between edges by those re-clusterings.
    pub migrated_devices: usize,
    /// Mobility-active devices at the end of the round/window.
    pub active_devices: usize,
    /// Live edge-size imbalance at round end: the worst per-region
    /// `(max-min)/mean` spread — the drift signal the re-clustering
    /// threshold is compared against (cross-region skew excluded, since
    /// region-constrained re-clustering cannot repair it).
    pub edge_size_imbalance: f64,
    /// Model-store observables (`hfl::model_store`), stamped by the
    /// engines at round end: distinct model buffers referenced by at
    /// least one handle. With full sharing this is O(M), not O(N).
    pub live_model_buffers: usize,
    /// High-water model memory in bytes: the store's whole slab, pooled
    /// scratch buffers included.
    pub peak_model_bytes: usize,
    /// Fraction of device handles whose buffer is shared (rc > 1) at
    /// round end — →1.0 right after a cloud broadcast, the measured side
    /// of the O(N·p) → O(M·p) claim.
    pub sharing_ratio: f64,
    /// Injected fault events (`hfl::lifecycle::FaultPlan`) applied
    /// during this round/window — outage/partition transitions and
    /// crash/rejoin storms. Stamped by the engines; 0 on fault-free
    /// runs.
    pub fault_events: usize,
}

impl RoundStats {
    /// Fraction of in-flight communication time that overlapped local
    /// training (0 = fully serialized, as in the lump model; →1 = uploads
    /// fully hidden behind compute).
    pub fn comm_overlap_frac(&self) -> f64 {
        let comm: f64 = self.per_edge.iter().map(|e| e.comm_busy).sum();
        if comm <= 0.0 {
            return 0.0;
        }
        self.per_edge.iter().map(|e| e.comm_overlap).sum::<f64>() / comm
    }

    /// Mean observed upload staleness over the edges, in cloud windows
    /// (the per-edge control signal the DRL state feeds on; 0 under the
    /// barrier engine).
    pub fn mean_staleness(&self) -> f64 {
        if self.per_edge.is_empty() {
            return 0.0;
        }
        let s: f64 = self.per_edge.iter().map(|e| e.staleness).sum();
        s / self.per_edge.len() as f64
    }

    /// Total over-selected stragglers abandoned this round.
    pub fn total_abandoned(&self) -> usize {
        self.per_edge.iter().map(|e| e.abandoned).sum()
    }

    /// Fraction of dispatched work abandoned by over-selection closes
    /// this round (0 with over-selection off — nothing is abandoned).
    pub fn abandon_rate(&self) -> f64 {
        let active: usize = self.per_edge.iter().map(|e| e.active).sum();
        let abandoned = self.total_abandoned();
        let total = active + abandoned;
        if total == 0 {
            0.0
        } else {
            abandoned as f64 / total as f64
        }
    }

    /// Mean member availability over the edges at the decision point
    /// (1.0 with pace steering off).
    pub fn mean_availability(&self) -> f64 {
        if self.per_edge.is_empty() {
            return 1.0;
        }
        let s: f64 = self.per_edge.iter().map(|e| e.availability).sum();
        s / self.per_edge.len() as f64
    }

    /// Mean busy fraction over all 2M directed links for the round.
    pub fn mean_link_util(&self) -> f64 {
        if self.round_time <= 0.0 || self.per_edge.is_empty() {
            return 0.0;
        }
        let busy: f64 =
            self.per_edge.iter().map(|e| e.up_busy + e.down_busy).sum();
        busy / (2.0 * self.per_edge.len() as f64 * self.round_time)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("k", Json::num(self.k as f64)),
            ("accuracy", Json::num(self.accuracy)),
            ("test_loss", Json::num(self.test_loss)),
            ("train_loss", Json::num(self.train_loss)),
            ("round_time", Json::num(self.round_time)),
            ("sim_now", Json::num(self.sim_now)),
            ("energy", Json::num(self.energy)),
            ("comm_overlap_frac", Json::num(self.comm_overlap_frac())),
            ("mean_link_util", Json::num(self.mean_link_util())),
            ("mean_staleness", Json::num(self.mean_staleness())),
            ("n_reclusters", Json::num(self.n_reclusters as f64)),
            ("migrated_devices", Json::num(self.migrated_devices as f64)),
            ("active_devices", Json::num(self.active_devices as f64)),
            ("edge_size_imbalance", Json::num(self.edge_size_imbalance)),
            ("live_model_buffers", Json::num(self.live_model_buffers as f64)),
            ("peak_model_bytes", Json::num(self.peak_model_bytes as f64)),
            ("sharing_ratio", Json::num(self.sharing_ratio)),
            ("abandoned", Json::num(self.total_abandoned() as f64)),
            ("mean_availability", Json::num(self.mean_availability())),
            ("fault_events", Json::num(self.fault_events as f64)),
            (
                "gamma1",
                Json::arr_f64(
                    &self.gamma1.iter().map(|&g| g as f64).collect::<Vec<_>>(),
                ),
            ),
            (
                "gamma2",
                Json::arr_f64(
                    &self.gamma2.iter().map(|&g| g as f64).collect::<Vec<_>>(),
                ),
            ),
        ])
    }
}

/// Streaming builder for one round's [`RoundStats`], shared by the
/// synchronous and event-driven engines so both account identically: the
/// same record calls in the same order produce bit-identical stats.
#[derive(Clone, Debug)]
pub struct RoundAccumulator {
    pub per_edge: Vec<EdgeStats>,
    pub round_energy: f64,
    train_loss_acc: f64,
    train_loss_n: f64,
    device_losses: Vec<(usize, f64)>,
}

impl RoundAccumulator {
    pub fn new(m: usize) -> Self {
        RoundAccumulator {
            per_edge: vec![EdgeStats::default(); m],
            round_energy: 0.0,
            train_loss_acc: 0.0,
            train_loss_n: 0.0,
            device_losses: Vec::new(),
        }
    }

    /// One device finished local training under `edge`, spending simulated
    /// `t` seconds and `energy` mAh.
    pub fn record_train(
        &mut self,
        edge: usize,
        device: usize,
        t: f64,
        energy: f64,
        last_loss: Option<f64>,
    ) {
        let e = &mut self.per_edge[edge];
        e.energy += energy;
        self.round_energy += energy;
        e.active += 1;
        if t > e.t_sgd_slowest {
            e.t_sgd_slowest = t;
        }
        if let Some(loss) = last_loss {
            self.train_loss_acc += loss;
            self.train_loss_n += 1.0;
            self.device_losses.push((device, loss));
        }
    }

    /// Close an edge's barrier round from observed link-layer transfers:
    /// `compute_time` simulated seconds of local training, then an `up`
    /// upload (on the round's critical path — the barrier closes when the
    /// last upload lands) and a `down` broadcast that overlaps the start
    /// of the next round and is charged to stats only.
    pub fn record_link(
        &mut self,
        edge: usize,
        up: f64,
        down: f64,
        compute_time: f64,
    ) {
        let e = &mut self.per_edge[edge];
        e.t_up = up;
        e.t_down = down;
        e.t_ec = up + down;
        e.compute_busy = compute_time;
        e.up_busy = up;
        e.down_busy = down;
        e.comm_busy = up + down; // serialized: the intervals are disjoint
        e.comm_overlap = 0.0;
        e.total_time = compute_time + up;
    }

    /// Account a between-rounds migration warm-start downlink on `edge`
    /// (the barrier engine's re-clustering path): it runs after the
    /// round's own comm phase, extending the edge's wall-clock and
    /// downlink busy time, and becomes the last observed downlink
    /// duration. (The event engine's migration downlinks are real
    /// in-flight transfers and are swept into the window stats instead.)
    pub fn record_migration_down(&mut self, edge: usize, down: f64) {
        let e = &mut self.per_edge[edge];
        e.t_down = down;
        e.t_ec = e.t_up + down;
        e.down_busy += down;
        e.comm_busy += down;
        e.total_time += down;
    }

    /// Close an edge's timer window (event-driven modes) from the busy
    /// intervals swept over the window. `t_up`/`t_down` are the last
    /// *observed* transfer durations (possibly from an earlier window if
    /// nothing landed in this one; 0.0 until anything ever lands).
    #[allow(clippy::too_many_arguments)]
    pub fn record_window(
        &mut self,
        edge: usize,
        t_up: f64,
        t_down: f64,
        compute_busy: f64,
        up_busy: f64,
        down_busy: f64,
        comm_busy: f64,
        overlap: f64,
    ) {
        let e = &mut self.per_edge[edge];
        e.t_up = t_up;
        e.t_down = t_down;
        e.t_ec = t_up + t_down;
        e.compute_busy = compute_busy;
        e.up_busy = up_busy;
        e.down_busy = down_busy;
        e.comm_busy = comm_busy;
        e.comm_overlap = overlap;
        // Busy union: the wall-clock this edge spent doing *anything*
        // (inclusion-exclusion over the compute and comm interval sets).
        e.total_time = compute_busy + comm_busy - overlap;
    }

    /// Record an edge's control observables at the cloud's decision point
    /// (event-driven modes; the barrier engine leaves the defaults — it
    /// never runs stale, holds reports, or keeps uploads in flight across
    /// a decision point).
    pub fn record_ctrl(
        &mut self,
        edge: usize,
        staleness: f64,
        in_flight_up: usize,
        quorum_fill: f64,
    ) {
        let e = &mut self.per_edge[edge];
        e.staleness = staleness;
        e.in_flight_up = in_flight_up;
        e.quorum_fill = quorum_fill;
    }

    /// Record an edge's client-lifecycle observables at the decision
    /// point: stragglers abandoned by the over-selection close and the
    /// member availability fraction (`hfl::lifecycle`). Engines call
    /// this unconditionally — with the lifecycle off it records
    /// `(0, 1.0)`, the "everyone landed, everyone available" baseline.
    pub fn record_lifecycle(
        &mut self,
        edge: usize,
        abandoned: usize,
        availability: f64,
    ) {
        let e = &mut self.per_edge[edge];
        e.abandoned = abandoned;
        e.availability = availability;
    }

    /// Straggler-path duration: max per-edge total time.
    pub fn round_time(&self) -> f64 {
        self.per_edge
            .iter()
            .map(|e| e.total_time)
            .fold(0.0, f64::max)
    }

    pub fn finish(
        self,
        k: usize,
        accuracy: f64,
        test_loss: f64,
        round_time: f64,
        sim_now: f64,
        gamma1: &[usize],
        gamma2: &[usize],
    ) -> RoundStats {
        RoundStats {
            k,
            accuracy,
            test_loss,
            train_loss: if self.train_loss_n > 0.0 {
                self.train_loss_acc / self.train_loss_n
            } else {
                0.0
            },
            round_time,
            sim_now,
            per_edge: self.per_edge,
            energy: self.round_energy,
            gamma1: gamma1.to_vec(),
            gamma2: gamma2.to_vec(),
            device_losses: self.device_losses,
            // Membership and model-store fields are stamped by the
            // engines after `finish` (`finalize_membership_stats` /
            // `finalize_memory_stats`): the accumulator only sees
            // training/communication records.
            n_reclusters: 0,
            migrated_devices: 0,
            active_devices: 0,
            edge_size_imbalance: 0.0,
            live_model_buffers: 0,
            peak_model_bytes: 0,
            sharing_ratio: 0.0,
            fault_events: 0,
        }
    }
}

/// A whole training run (one scheme, one threshold time).
#[derive(Clone, Debug, Default)]
pub struct RunHistory {
    pub rounds: Vec<RoundStats>,
}

impl RunHistory {
    pub fn push(&mut self, r: RoundStats) {
        self.rounds.push(r);
    }

    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map(|r| r.accuracy).unwrap_or(0.0)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.accuracy)
            .fold(0.0, f64::max)
    }

    pub fn total_energy(&self) -> f64 {
        self.rounds.iter().map(|r| r.energy).sum()
    }

    pub fn total_time(&self) -> f64 {
        self.rounds.last().map(|r| r.sim_now).unwrap_or(0.0)
    }

    /// Accuracy and cumulative energy at simulated time `t` (the state at
    /// the last round completing before `t`). Lets one long run serve every
    /// threshold-time column of Fig. 9 / Table 1.
    pub fn at_time(&self, t: f64) -> (f64, f64) {
        let mut acc = 0.0;
        let mut energy = 0.0;
        for r in &self.rounds {
            if r.sim_now > t {
                break;
            }
            acc = r.accuracy;
            energy += r.energy;
        }
        (acc, energy)
    }

    /// First simulated time at which accuracy reached `target` (None if
    /// never).
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.rounds
            .iter()
            .find(|r| r.accuracy >= target)
            .map(|r| r.sim_now)
    }

    /// Mean (comm_overlap_frac, mean_link_util) over the rounds completed
    /// by simulated time `t` — the fig9/table summary companion of
    /// [`RunHistory::at_time`].
    pub fn comm_stats_at(&self, t: f64) -> (f64, f64) {
        let mut overlap = 0.0;
        let mut util = 0.0;
        let mut n = 0.0;
        for r in &self.rounds {
            if r.sim_now > t {
                break;
            }
            overlap += r.comm_overlap_frac();
            util += r.mean_link_util();
            n += 1.0;
        }
        if n > 0.0 {
            (overlap / n, util / n)
        } else {
            (0.0, 0.0)
        }
    }

    /// Accuracy and simulated time at cumulative device energy `e` mAh
    /// (the state at the last round whose running energy total stays
    /// within `e`). Lets one long run serve every energy-budget column of
    /// the async head-to-head comparison.
    pub fn at_energy(&self, e: f64) -> (f64, f64) {
        let mut acc = 0.0;
        let mut t = 0.0;
        let mut cum = 0.0;
        for r in &self.rounds {
            cum += r.energy;
            if cum > e {
                break;
            }
            acc = r.accuracy;
            t = r.sim_now;
        }
        (acc, t)
    }

    /// Mean per-round upload staleness over the rounds completed by
    /// simulated time `t` — the control-signal companion of
    /// [`RunHistory::comm_stats_at`].
    pub fn mean_staleness_at(&self, t: f64) -> f64 {
        let mut s = 0.0;
        let mut n = 0.0;
        for r in &self.rounds {
            if r.sim_now > t {
                break;
            }
            s += r.mean_staleness();
            n += 1.0;
        }
        if n > 0.0 {
            s / n
        } else {
            0.0
        }
    }

    /// Client-lifecycle summary over the rounds completed by simulated
    /// time `t`: cumulative abandoned stragglers, mean member
    /// availability, and cumulative injected fault events — the
    /// lifecycle companion of [`RunHistory::at_time`].
    pub fn lifecycle_stats_at(&self, t: f64) -> (usize, f64, usize) {
        let mut abandoned = 0;
        let mut avail = 0.0;
        let mut faults = 0;
        let mut n = 0.0;
        for r in &self.rounds {
            if r.sim_now > t {
                break;
            }
            abandoned += r.total_abandoned();
            avail += r.mean_availability();
            faults += r.fault_events;
            n += 1.0;
        }
        (abandoned, if n > 0.0 { avail / n } else { 1.0 }, faults)
    }

    /// Cumulative (re-clusterings, migrated devices) over the rounds
    /// completed by simulated time `t` — the membership companion of
    /// [`RunHistory::at_time`] for the fig9/table summaries.
    pub fn membership_stats_at(&self, t: f64) -> (usize, usize) {
        let mut reclusters = 0;
        let mut migrated = 0;
        for r in &self.rounds {
            if r.sim_now > t {
                break;
            }
            reclusters += r.n_reclusters;
            migrated += r.migrated_devices;
        }
        (reclusters, migrated)
    }

    /// Write the (time, accuracy, energy, link, membership) series to CSV.
    pub fn write_csv(&self, path: &str, label: &str) -> std::io::Result<()> {
        let mut w = CsvWriter::create_with_comment(
            path,
            Some(&format!("schema_version={SCHEMA_VERSION}")),
            &["scheme", "k", "sim_time", "accuracy", "round_energy",
              "cum_energy", "train_loss", "comm_overlap_frac",
              "mean_link_util", "mean_staleness", "n_reclusters",
              "migrated_devices", "active_devices", "edge_size_imbalance",
              "live_model_buffers", "peak_model_bytes", "sharing_ratio",
              "abandoned", "mean_availability", "fault_events"],
        )?;
        let mut cum = 0.0;
        for r in &self.rounds {
            cum += r.energy;
            w.row(&[
                label.to_string(),
                r.k.to_string(),
                format!("{:.2}", r.sim_now),
                format!("{:.4}", r.accuracy),
                format!("{:.3}", r.energy),
                format!("{cum:.3}"),
                format!("{:.4}", r.train_loss),
                format!("{:.4}", r.comm_overlap_frac()),
                format!("{:.4}", r.mean_link_util()),
                format!("{:.4}", r.mean_staleness()),
                r.n_reclusters.to_string(),
                r.migrated_devices.to_string(),
                r.active_devices.to_string(),
                format!("{:.4}", r.edge_size_imbalance),
                r.live_model_buffers.to_string(),
                r.peak_model_bytes.to_string(),
                format!("{:.4}", r.sharing_ratio),
                r.total_abandoned().to_string(),
                format!("{:.4}", r.mean_availability()),
                r.fault_events.to_string(),
            ])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(k: usize, acc: f64, t: f64, e: f64) -> RoundStats {
        RoundStats {
            k,
            accuracy: acc,
            test_loss: 1.0,
            train_loss: 1.0,
            round_time: t,
            sim_now: t * k as f64,
            per_edge: vec![],
            energy: e,
            gamma1: vec![5],
            gamma2: vec![4],
            device_losses: vec![],
            n_reclusters: 0,
            migrated_devices: 0,
            active_devices: 0,
            edge_size_imbalance: 0.0,
            live_model_buffers: 0,
            peak_model_bytes: 0,
            sharing_ratio: 0.0,
            fault_events: 0,
        }
    }

    #[test]
    fn history_aggregates() {
        let mut h = RunHistory::default();
        h.push(round(1, 0.3, 100.0, 10.0));
        h.push(round(2, 0.6, 100.0, 12.0));
        h.push(round(3, 0.55, 100.0, 9.0));
        assert_eq!(h.final_accuracy(), 0.55);
        assert_eq!(h.best_accuracy(), 0.6);
        assert!((h.total_energy() - 31.0).abs() < 1e-12);
        assert_eq!(h.time_to_accuracy(0.5), Some(200.0));
        assert_eq!(h.time_to_accuracy(0.9), None);
    }

    #[test]
    fn accumulator_builds_round_stats() {
        let mut acc = RoundAccumulator::new(2);
        acc.record_train(0, 3, 10.0, 1.5, Some(0.8));
        acc.record_train(0, 4, 12.0, 2.5, Some(0.6));
        acc.record_train(1, 7, 20.0, 4.0, None);
        // Barrier round: uploads on the critical path, downlinks charged
        // to stats only.
        acc.record_link(0, 3.0, 1.0, 12.0);
        acc.record_link(1, 5.0, 2.0, 20.0);
        assert!((acc.round_time() - 25.0).abs() < 1e-12);
        let s = acc.finish(1, 0.5, 1.0, 25.0, 25.0, &[2, 2], &[1, 1]);
        assert_eq!(s.per_edge[0].active, 2);
        assert!((s.per_edge[0].t_sgd_slowest - 12.0).abs() < 1e-12);
        assert!((s.per_edge[0].t_ec - 4.0).abs() < 1e-12, "t_ec = up+down");
        assert!((s.per_edge[0].t_up - 3.0).abs() < 1e-12);
        assert!((s.per_edge[0].t_down - 1.0).abs() < 1e-12);
        assert_eq!(s.per_edge[0].comm_overlap, 0.0, "barrier never overlaps");
        assert!((s.energy - 8.0).abs() < 1e-12);
        assert!((s.train_loss - 0.7).abs() < 1e-12);
        assert_eq!(s.device_losses, vec![(3, 0.8), (4, 0.6)]);
        assert_eq!(s.comm_overlap_frac(), 0.0);
        // busy fractions: (3+1+5+2) link-busy seconds over 2*2*25.
        assert!((s.mean_link_util() - 11.0 / 100.0).abs() < 1e-12);
    }

    #[test]
    fn migration_downlink_accounting_extends_the_round() {
        let mut acc = RoundAccumulator::new(2);
        acc.record_train(0, 1, 10.0, 1.0, None);
        acc.record_link(0, 3.0, 1.0, 10.0);
        acc.record_link(1, 2.0, 1.0, 0.0);
        acc.record_migration_down(0, 4.0);
        let s = acc.finish(1, 0.5, 1.0, 17.0, 17.0, &[1, 1], &[1, 1]);
        assert!((s.per_edge[0].t_down - 4.0).abs() < 1e-12);
        assert!((s.per_edge[0].t_ec - 7.0).abs() < 1e-12, "up 3 + down 4");
        assert!((s.per_edge[0].down_busy - 5.0).abs() < 1e-12);
        assert!((s.per_edge[0].comm_busy - 8.0).abs() < 1e-12);
        assert!((s.per_edge[0].total_time - 17.0).abs() < 1e-12);
        // The edge without migrants keeps its barrier accounting.
        assert!((s.per_edge[1].down_busy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn window_recording_reports_overlap() {
        let mut acc = RoundAccumulator::new(2);
        acc.record_train(0, 0, 30.0, 1.0, Some(0.5));
        acc.record_train(1, 2, 40.0, 1.0, None);
        // Edge 0: 60s compute, 20s comm of which 15s overlapped training.
        acc.record_window(0, 8.0, 2.0, 60.0, 18.0, 2.0, 20.0, 15.0);
        // Edge 1: fully serialized window.
        acc.record_window(1, 6.0, 2.0, 50.0, 8.0, 2.0, 10.0, 0.0);
        let s = acc.finish(1, 0.5, 1.0, 100.0, 100.0, &[2, 2], &[1, 1]);
        assert!((s.per_edge[0].total_time - 65.0).abs() < 1e-12);
        assert!((s.per_edge[1].total_time - 60.0).abs() < 1e-12);
        assert!((s.per_edge[0].t_ec - 10.0).abs() < 1e-12);
        // 15 overlapped of 30 comm-busy seconds.
        assert!((s.comm_overlap_frac() - 0.5).abs() < 1e-12);
        let (up, down) = s.per_edge[0].link_util(100.0);
        assert!((up - 0.18).abs() < 1e-12);
        assert!((down - 0.02).abs() < 1e-12);
    }

    #[test]
    fn ctrl_recording_feeds_mean_staleness() {
        let mut acc = RoundAccumulator::new(3);
        acc.record_ctrl(0, 2.0, 1, 0.5);
        acc.record_ctrl(1, 1.0, 0, 1.0);
        // Edge 2 untouched: barrier defaults (never stale).
        let s = acc.finish(1, 0.5, 1.0, 10.0, 10.0, &[1; 3], &[1; 3]);
        assert!((s.per_edge[0].staleness - 2.0).abs() < 1e-12);
        assert_eq!(s.per_edge[0].in_flight_up, 1);
        assert!((s.per_edge[1].quorum_fill - 1.0).abs() < 1e-12);
        assert_eq!(s.per_edge[2].staleness, 0.0);
        assert!((s.mean_staleness() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lifecycle_recording_feeds_abandonment_and_availability() {
        let mut acc = RoundAccumulator::new(3);
        acc.record_train(0, 1, 10.0, 1.0, None);
        acc.record_train(0, 2, 11.0, 1.0, None);
        acc.record_train(1, 5, 12.0, 1.0, None);
        // Edge 0 over-selected: 2 landed, 1 abandoned; 60% available.
        acc.record_lifecycle(0, 1, 0.6);
        acc.record_lifecycle(1, 0, 1.0);
        acc.record_lifecycle(2, 0, 1.0);
        let s = acc.finish(1, 0.5, 1.0, 12.0, 12.0, &[1; 3], &[1; 3]);
        assert_eq!(s.per_edge[0].abandoned, 1);
        assert!((s.per_edge[0].abandon_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.per_edge[1].abandon_rate(), 0.0);
        assert_eq!(s.total_abandoned(), 1);
        assert!((s.abandon_rate() - 0.25).abs() < 1e-12, "1 of 4 dispatched");
        assert!((s.mean_availability() - 2.6 / 3.0).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("abandoned").unwrap().as_usize().unwrap(), 1);
        assert!(j.get("mean_availability").is_some());
        assert!(j.get("fault_events").is_some());
    }

    #[test]
    fn history_indexes_by_energy_budget() {
        let mut h = RunHistory::default();
        h.push(round(1, 0.3, 100.0, 10.0)); // cum 10, sim_now 100
        h.push(round(2, 0.6, 100.0, 12.0)); // cum 22, sim_now 200
        h.push(round(3, 0.7, 100.0, 9.0)); // cum 31, sim_now 300
        assert_eq!(h.at_energy(5.0), (0.0, 0.0));
        assert_eq!(h.at_energy(10.0), (0.3, 100.0));
        assert_eq!(h.at_energy(25.0), (0.6, 200.0));
        assert_eq!(h.at_energy(1e9), (0.7, 300.0));
    }

    #[test]
    fn round_json_has_fields() {
        let j = round(2, 0.5, 10.0, 1.0).to_json();
        assert_eq!(
            j.get("schema_version").unwrap().as_usize().unwrap(),
            SCHEMA_VERSION
        );
        assert_eq!(j.get("k").unwrap().as_usize().unwrap(), 2);
        assert!(j.get("gamma1").unwrap().as_arr().is_some());
        assert!(j.get("n_reclusters").is_some());
        assert!(j.get("active_devices").is_some());
        assert!(j.get("mean_staleness").is_some());
        assert!(j.get("live_model_buffers").is_some());
        assert!(j.get("peak_model_bytes").is_some());
        assert!(j.get("sharing_ratio").is_some());
    }

    #[test]
    fn staleness_averages_by_time() {
        let mut h = RunHistory::default();
        let mut r1 = round(1, 0.3, 100.0, 10.0);
        r1.per_edge = vec![EdgeStats { staleness: 2.0, ..Default::default() }];
        let mut r2 = round(2, 0.4, 100.0, 10.0);
        r2.per_edge = vec![EdgeStats { staleness: 4.0, ..Default::default() }];
        h.push(r1);
        h.push(r2);
        assert!((h.mean_staleness_at(150.0) - 2.0).abs() < 1e-12);
        assert!((h.mean_staleness_at(1e9) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn membership_stats_accumulate_by_time() {
        let mut h = RunHistory::default();
        let mut r1 = round(1, 0.3, 100.0, 10.0); // sim_now 100
        r1.n_reclusters = 1;
        r1.migrated_devices = 4;
        let mut r2 = round(2, 0.4, 100.0, 10.0); // sim_now 200
        r2.n_reclusters = 0;
        r2.migrated_devices = 0;
        let mut r3 = round(3, 0.5, 100.0, 10.0); // sim_now 300
        r3.n_reclusters = 2;
        r3.migrated_devices = 3;
        h.push(r1);
        h.push(r2);
        h.push(r3);
        assert_eq!(h.membership_stats_at(50.0), (0, 0));
        assert_eq!(h.membership_stats_at(250.0), (1, 4));
        assert_eq!(h.membership_stats_at(1e9), (3, 7));
    }

    #[test]
    fn lifecycle_stats_accumulate_by_time() {
        let mut h = RunHistory::default();
        let mut r1 = round(1, 0.3, 100.0, 10.0); // sim_now 100
        r1.per_edge = vec![EdgeStats {
            abandoned: 2,
            availability: 0.5,
            ..Default::default()
        }];
        r1.fault_events = 1;
        let mut r2 = round(2, 0.4, 100.0, 10.0); // sim_now 200
        r2.per_edge = vec![EdgeStats {
            abandoned: 1,
            availability: 1.0,
            ..Default::default()
        }];
        r2.fault_events = 3;
        h.push(r1);
        h.push(r2);
        // Before any round: the "everyone available" baseline.
        assert_eq!(h.lifecycle_stats_at(50.0), (0, 1.0, 0));
        let (ab, av, fe) = h.lifecycle_stats_at(150.0);
        assert_eq!((ab, fe), (2, 1));
        assert!((av - 0.5).abs() < 1e-12);
        let (ab, av, fe) = h.lifecycle_stats_at(1e9);
        assert_eq!((ab, fe), (3, 4));
        assert!((av - 0.75).abs() < 1e-12);
    }
}
