//! Round/run metrics: everything the DRL state (paper Eq. 7-9), the reward
//! (Eq. 11) and the experiment harnesses need.

use crate::util::csv::CsvWriter;
use crate::util::json::Json;

/// Per-edge observables h_j(k) of paper Eq. (7), plus bookkeeping.
#[derive(Clone, Debug, Default)]
pub struct EdgeStats {
    /// Local SGD time of the slowest device under this edge (T_j^SGD).
    pub t_sgd_slowest: f64,
    /// Edge→cloud communication time (T_j^ec).
    pub t_ec: f64,
    /// Device energy consumed under this edge this round, mAh (E_j).
    pub energy: f64,
    /// Active devices that trained this round.
    pub active: usize,
    /// Wall (simulated) time this edge needed for the whole round.
    pub total_time: f64,
}

/// One cloud-aggregation round.
#[derive(Clone, Debug)]
pub struct RoundStats {
    pub k: usize,
    /// Test accuracy after the round's cloud aggregation (A_test(k)).
    pub accuracy: f64,
    pub test_loss: f64,
    pub train_loss: f64,
    /// Straggler-path simulated duration of the round (T_use(k)).
    pub round_time: f64,
    /// Simulated clock after the round.
    pub sim_now: f64,
    pub per_edge: Vec<EdgeStats>,
    /// Total device energy this round, mAh (E(k)).
    pub energy: f64,
    /// Frequencies that were executed.
    pub gamma1: Vec<usize>,
    pub gamma2: Vec<usize>,
    /// (device, last-epoch mean loss) for every device that trained.
    pub device_losses: Vec<(usize, f64)>,
}

impl RoundStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("k", Json::num(self.k as f64)),
            ("accuracy", Json::num(self.accuracy)),
            ("test_loss", Json::num(self.test_loss)),
            ("train_loss", Json::num(self.train_loss)),
            ("round_time", Json::num(self.round_time)),
            ("sim_now", Json::num(self.sim_now)),
            ("energy", Json::num(self.energy)),
            (
                "gamma1",
                Json::arr_f64(
                    &self.gamma1.iter().map(|&g| g as f64).collect::<Vec<_>>(),
                ),
            ),
            (
                "gamma2",
                Json::arr_f64(
                    &self.gamma2.iter().map(|&g| g as f64).collect::<Vec<_>>(),
                ),
            ),
        ])
    }
}

/// A whole training run (one scheme, one threshold time).
#[derive(Clone, Debug, Default)]
pub struct RunHistory {
    pub rounds: Vec<RoundStats>,
}

impl RunHistory {
    pub fn push(&mut self, r: RoundStats) {
        self.rounds.push(r);
    }

    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map(|r| r.accuracy).unwrap_or(0.0)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.accuracy)
            .fold(0.0, f64::max)
    }

    pub fn total_energy(&self) -> f64 {
        self.rounds.iter().map(|r| r.energy).sum()
    }

    pub fn total_time(&self) -> f64 {
        self.rounds.last().map(|r| r.sim_now).unwrap_or(0.0)
    }

    /// Accuracy and cumulative energy at simulated time `t` (the state at
    /// the last round completing before `t`). Lets one long run serve every
    /// threshold-time column of Fig. 9 / Table 1.
    pub fn at_time(&self, t: f64) -> (f64, f64) {
        let mut acc = 0.0;
        let mut energy = 0.0;
        for r in &self.rounds {
            if r.sim_now > t {
                break;
            }
            acc = r.accuracy;
            energy += r.energy;
        }
        (acc, energy)
    }

    /// First simulated time at which accuracy reached `target` (None if
    /// never).
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.rounds
            .iter()
            .find(|r| r.accuracy >= target)
            .map(|r| r.sim_now)
    }

    /// Write the (time, accuracy, energy) series to CSV.
    pub fn write_csv(&self, path: &str, label: &str) -> std::io::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["scheme", "k", "sim_time", "accuracy", "round_energy",
              "cum_energy", "train_loss"],
        )?;
        let mut cum = 0.0;
        for r in &self.rounds {
            cum += r.energy;
            w.row(&[
                label.to_string(),
                r.k.to_string(),
                format!("{:.2}", r.sim_now),
                format!("{:.4}", r.accuracy),
                format!("{:.3}", r.energy),
                format!("{cum:.3}"),
                format!("{:.4}", r.train_loss),
            ])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(k: usize, acc: f64, t: f64, e: f64) -> RoundStats {
        RoundStats {
            k,
            accuracy: acc,
            test_loss: 1.0,
            train_loss: 1.0,
            round_time: t,
            sim_now: t * k as f64,
            per_edge: vec![],
            energy: e,
            gamma1: vec![5],
            gamma2: vec![4],
            device_losses: vec![],
        }
    }

    #[test]
    fn history_aggregates() {
        let mut h = RunHistory::default();
        h.push(round(1, 0.3, 100.0, 10.0));
        h.push(round(2, 0.6, 100.0, 12.0));
        h.push(round(3, 0.55, 100.0, 9.0));
        assert_eq!(h.final_accuracy(), 0.55);
        assert_eq!(h.best_accuracy(), 0.6);
        assert!((h.total_energy() - 31.0).abs() < 1e-12);
        assert_eq!(h.time_to_accuracy(0.5), Some(200.0));
        assert_eq!(h.time_to_accuracy(0.9), None);
    }

    #[test]
    fn round_json_has_fields() {
        let j = round(2, 0.5, 10.0, 1.0).to_json();
        assert_eq!(j.get("k").unwrap().as_usize().unwrap(), 2);
        assert!(j.get("gamma1").unwrap().as_arr().is_some());
    }
}
