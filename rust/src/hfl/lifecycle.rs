//! Production client lifecycle: over-selection, pace steering, and
//! deterministic failure injection ("Towards Federated Learning at
//! Scale", arXiv:1902.01046 — the machinery a real million-device HFL
//! deployment runs under).
//!
//! # Determinism rules
//!
//! Faults are **scheduled events, never ambient state**. A seeded
//! [`FaultPlan`] is expanded once, up front, into a time-sorted list of
//! [`Event::EdgeOutage`] / [`Event::Partition`] / [`Event::CrashStorm`]
//! entries; engines schedule them into the same [`EventQueue`] as every
//! other event and mutate lifecycle state only inside the handler. No
//! clock reads, no per-handler draws, no thread-local coin flips — so a
//! chaos run replays bitwise at any worker count and with either queue
//! backend, and the worker-count byte-equality CI gate extends to
//! fault-injected runs unchanged.
//!
//! Three corollaries, each load-bearing:
//!
//! * **Plan expansion draws from a dedicated stream** (`seed ^
//!   0xfa0175`), the same isolation discipline as mobility and
//!   availability. A zero-count [`FaultPlan`] is *empty*: nothing is
//!   scheduled, no tie-break draws are consumed, and a run with the
//!   fault layer compiled-in-but-disabled is bitwise identical to one
//!   that predates it (the sixth no-op guarantee, tested in
//!   `tests/integration.rs`).
//! * **Crash membership is a pure predicate.** A storm carries a seed
//!   and a fixed-point fraction; device `d` is hit iff
//!   [`storm_hits`]`(seed, d, frac_bits)`. The crash set and the rejoin
//!   set are computed, not sampled — identical by construction, and
//!   independent of which shard or worker evaluates them.
//! * **Over-selection closes on landing order, which is total.** The
//!   queue's `(time, tie, seq)` order is backend- and worker-invariant,
//!   so "the first K of N dispatched" is a deterministic set per seed
//!   (tested against both queue backends below).
//!
//! Pace steering *defers* dispatches by
//! [`AvailabilityModel::delay_until`](crate::sim::AvailabilityModel);
//! it never filters a device out entirely — a fully-skipped member
//! would leave its edge with no future event to close the round.
//!
//! Under the sharded engine loop (`hfl::engine_shard`) fault events
//! ride the serial ctrl queue and are handled as shard barriers: an
//! outage/partition touches exactly one shard's edges, while a crash
//! storm fans out across all shards in parallel — sound precisely
//! because [`storm_hits`] is a pure predicate of `(seed, device,
//! frac)`, independent of which shard evaluates it.

use crate::config::FaultConfig;
use crate::sim::event::Event;
use crate::sim::AvailabilityModel;
use crate::util::rng::Rng;

/// A seeded, pre-expanded schedule of fault events. Built once per run;
/// engines drain it into their event queue (event engine) or apply
/// entries at round boundaries (barrier engine).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    events: Vec<(f64, Event)>,
}

impl FaultPlan {
    /// Expand `fault.*` knobs into a time-sorted event list over
    /// `[0, horizon)`. Injection times draw from `seed ^ 0xfa0175`;
    /// with all counts zero the plan is empty and **no RNG state is
    /// consumed** — the disabled fault layer is bitwise invisible.
    pub fn build(
        cfg: &FaultConfig,
        edges: usize,
        horizon: f64,
        seed: u64,
    ) -> Self {
        let mut events = Vec::new();
        if cfg.outages + cfg.partitions + cfg.crash_storms == 0
            || edges == 0
            || !(horizon.is_finite() && horizon > 0.0)
        {
            return FaultPlan { events };
        }
        let mut rng = Rng::new(seed ^ 0xfa0175);
        // Keep injections inside the first 80% of the horizon so the
        // matching recovery usually lands before the run ends (a
        // recovery past the horizon is legal — it just never fires).
        let window = horizon * 0.8;
        for _ in 0..cfg.outages {
            let t = rng.uniform() * window;
            let edge = rng.below(edges);
            events.push((t, Event::EdgeOutage { edge, up: false }));
            events.push((
                t + cfg.outage_duration,
                Event::EdgeOutage { edge, up: true },
            ));
        }
        let edge_mask = if edges >= 64 {
            u64::MAX
        } else {
            (1u64 << edges) - 1
        };
        for _ in 0..cfg.partitions {
            let t = rng.uniform() * window;
            // AND of two draws severs ~25% of edges; rejection keeps
            // the mask non-empty (deterministic: pure function of the
            // stream position).
            let mut mask = rng.next_u64() & rng.next_u64() & edge_mask;
            while mask == 0 {
                mask = rng.next_u64() & edge_mask;
            }
            events.push((t, Event::Partition { mask, up: false }));
            events.push((
                t + cfg.partition_duration,
                Event::Partition { mask, up: true },
            ));
        }
        let frac_bits = frac_to_bits(cfg.crash_frac);
        for _ in 0..cfg.crash_storms {
            let t = rng.uniform() * window;
            let storm = rng.next_u64();
            events.push((
                t,
                Event::CrashStorm { seed: storm, frac_bits, up: false },
            ));
            events.push((
                t + cfg.rejoin_delay,
                Event::CrashStorm { seed: storm, frac_bits, up: true },
            ));
        }
        // Stable sort: simultaneous faults keep expansion order, so the
        // plan itself is a total order before the queue ever sees it.
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        FaultPlan { events }
    }

    pub fn events(&self) -> &[(f64, Event)] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }
}

/// Map a crash fraction in `[0,1]` to the fixed-point threshold carried
/// by [`Event::CrashStorm`] (`Event` is `Eq`, so no `f64` payloads).
/// `0.0` hits nobody; `1.0` hits all but a 2^-32 sliver.
pub fn frac_to_bits(frac: f64) -> u32 {
    (frac.clamp(0.0, 1.0) * u32::MAX as f64) as u32
}

/// Is `device` in the storm's crash set? Pure splitmix64-style integer
/// hash of `(seed, device)` against the fixed-point threshold — the
/// rejoin handler recomputes the identical set, on any worker.
pub fn storm_hits(seed: u64, device: usize, frac_bits: u32) -> bool {
    let mut z = seed
        .wrapping_add((device as u64).wrapping_mul(0x9e3779b97f4a7c15));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^= z >> 31;
    ((z >> 32) as u32) < frac_bits
}

/// How many devices to dispatch for a round that closes on `k`
/// landings. `factor <= 0` disables over-selection (dispatch the whole
/// pool, the pre-lifecycle behavior); an enabled factor dispatches
/// `ceil(k * factor)`, clamped so we never dispatch fewer than the
/// quorum needs nor more than the pool holds.
pub fn overselect_count(k: usize, factor: f64, pool: usize) -> usize {
    if factor <= 0.0 || pool == 0 {
        return pool;
    }
    let want = (k as f64 * factor).ceil() as usize;
    want.clamp(k.min(pool), pool)
}

/// Pick `n` members to dispatch, preferring devices currently inside
/// their availability window; order within each class follows `members`
/// (canonical member order), so the selection is a pure function of
/// `(members, availability, now)`.
pub fn select_dispatch(
    members: &[usize],
    n: usize,
    avail: Option<&AvailabilityModel>,
    now: f64,
) -> Vec<usize> {
    let n = n.min(members.len());
    let Some(am) = avail else {
        return members[..n].to_vec();
    };
    let mut picked = Vec::with_capacity(n);
    for &d in members {
        if picked.len() == n {
            return picked;
        }
        if am.is_available(d, now) {
            picked.push(d);
        }
    }
    for &d in members {
        if picked.len() == n {
            break;
        }
        if !am.is_available(d, now) {
            picked.push(d);
        }
    }
    picked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::event::{EventQueue, QueueBackend};

    fn chaos_cfg() -> FaultConfig {
        FaultConfig {
            outages: 3,
            outage_duration: 50.0,
            partitions: 2,
            partition_duration: 80.0,
            crash_storms: 2,
            crash_frac: 0.3,
            rejoin_delay: 40.0,
        }
    }

    #[test]
    fn zero_count_plan_is_empty() {
        let plan = FaultPlan::build(&FaultConfig::default(), 8, 1000.0, 7);
        assert!(plan.is_empty());
        assert_eq!(plan.len(), 0);
    }

    #[test]
    fn plan_is_reproducible_and_seed_sensitive() {
        let a = FaultPlan::build(&chaos_cfg(), 8, 1000.0, 7);
        let b = FaultPlan::build(&chaos_cfg(), 8, 1000.0, 7);
        let c = FaultPlan::build(&chaos_cfg(), 8, 1000.0, 8);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.events().iter().zip(b.events()) {
            assert_eq!(x.0.to_bits(), y.0.to_bits());
            assert_eq!(x.1, y.1);
        }
        assert!(
            a.events().iter().zip(c.events()).any(|(x, y)| x.1 != y.1
                || x.0.to_bits() != y.0.to_bits()),
            "different seeds must produce different plans"
        );
    }

    #[test]
    fn plan_is_sorted_and_faults_pair_up() {
        let plan = FaultPlan::build(&chaos_cfg(), 8, 1000.0, 11);
        assert_eq!(plan.len(), 2 * (3 + 2 + 2));
        let ev = plan.events();
        for w in ev.windows(2) {
            assert!(w[0].0 <= w[1].0, "plan must be time-sorted");
        }
        // Every down has a matching up at the configured offset.
        for &(t, e) in ev {
            match e {
                Event::EdgeOutage { edge, up: false } => {
                    assert!(ev.iter().any(|&(t2, e2)| e2
                        == Event::EdgeOutage { edge, up: true }
                        && (t2 - t - 50.0).abs() < 1e-9));
                }
                Event::Partition { mask, up: false } => {
                    assert_ne!(mask, 0, "partition mask must be non-empty");
                    assert!(ev.iter().any(|&(t2, e2)| e2
                        == Event::Partition { mask, up: true }
                        && (t2 - t - 80.0).abs() < 1e-9));
                }
                Event::CrashStorm { seed, frac_bits, up: false } => {
                    assert!(ev.iter().any(|&(t2, e2)| e2
                        == Event::CrashStorm { seed, frac_bits, up: true }
                        && (t2 - t - 40.0).abs() < 1e-9));
                }
                _ => {}
            }
        }
    }

    #[test]
    fn partition_masks_stay_inside_the_edge_set() {
        let cfg = FaultConfig { partitions: 20, ..chaos_cfg() };
        let plan = FaultPlan::build(&cfg, 5, 1000.0, 3);
        for &(_, e) in plan.events() {
            if let Event::Partition { mask, .. } = e {
                assert_eq!(mask & !0b11111, 0, "mask {mask:b} beyond edge 4");
            }
        }
    }

    #[test]
    fn storm_predicate_is_pure_and_hits_the_fraction() {
        let bits = frac_to_bits(0.3);
        let n = 100_000usize;
        let hits = (0..n).filter(|&d| storm_hits(42, d, bits)).count();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.02, "storm hit fraction {frac}");
        for d in 0..1000 {
            assert_eq!(
                storm_hits(42, d, bits),
                storm_hits(42, d, bits),
                "predicate must be pure (crash set == rejoin set)"
            );
        }
        assert_eq!(frac_to_bits(0.0), 0);
        assert!((0..1000).all(|d| !storm_hits(7, d, 0)));
    }

    #[test]
    fn overselect_count_bounds() {
        // Disabled → whole pool (pre-lifecycle behavior).
        assert_eq!(overselect_count(5, 0.0, 20), 20);
        // Google's 130%: close on 10, dispatch 13.
        assert_eq!(overselect_count(10, 1.3, 20), 13);
        // Never below the quorum, never above the pool.
        assert_eq!(overselect_count(10, 1.0, 20), 10);
        assert_eq!(overselect_count(10, 5.0, 12), 12);
        assert_eq!(overselect_count(10, 1.3, 8), 8);
        assert_eq!(overselect_count(0, 1.3, 20), 0);
    }

    #[test]
    fn select_dispatch_prefers_available_members() {
        let am = AvailabilityModel::new(40, 1000.0, 0.5, 9);
        let members: Vec<usize> = (0..40).collect();
        let t = 333.0;
        let picked = select_dispatch(&members, 10, Some(&am), t);
        assert_eq!(picked.len(), 10);
        let n_avail =
            members.iter().filter(|&&d| am.is_available(d, t)).count();
        let picked_avail =
            picked.iter().filter(|&&d| am.is_available(d, t)).count();
        assert_eq!(
            picked_avail,
            n_avail.min(10),
            "available members must be taken first"
        );
        // No model → canonical prefix.
        assert_eq!(select_dispatch(&members, 3, None, t), vec![0, 1, 2]);
        // Deterministic.
        assert_eq!(picked, select_dispatch(&members, 10, Some(&am), t));
    }

    /// Satellite: the first-K-of-N landing set is deterministic per
    /// seed and identical under both queue backends — the property the
    /// over-selection close relies on.
    #[test]
    fn first_k_landings_deterministic_across_backends() {
        let landings = |backend: QueueBackend| -> Vec<(u64, usize)> {
            let mut q = EventQueue::for_scale(77, 64, backend);
            let mut rng = Rng::new(99);
            // Dispatch N = 13, close on K = 10 (overselect 1.3).
            for d in 0..13usize {
                let dur = 10.0 + 40.0 * rng.uniform();
                q.schedule(
                    dur,
                    Event::DeviceTrainDone { device: d, edge: 0 },
                );
            }
            let mut landed = Vec::new();
            while landed.len() < 10 {
                let (t, e) = q.pop().expect("13 scheduled, 10 popped");
                if let Event::DeviceTrainDone { device, .. } = e {
                    landed.push((t.to_bits(), device));
                }
            }
            landed
        };
        let heap = landings(QueueBackend::Binary);
        let cal = landings(QueueBackend::Calendar);
        assert_eq!(
            heap, cal,
            "landing order (and thus the abandoned straggler set) must \
             be queue-backend invariant"
        );
        assert_eq!(heap, landings(QueueBackend::Binary), "and seed-stable");
        let set: std::collections::BTreeSet<usize> =
            heap.iter().map(|&(_, d)| d).collect();
        assert_eq!(set.len(), 10, "10 distinct first landings");
    }
}
