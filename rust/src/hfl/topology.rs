//! Topology construction: regions, CPU heterogeneity classes, data shards,
//! and the device→edge map (profiled/clustered or naive round-robin for
//! the Table 1 ablation).

use std::sync::Arc;

use anyhow::Result;

use crate::cluster::{profile_devices, profiling::profile_device};
use crate::config::ExperimentConfig;
use crate::data::{partition_labels, synthetic::DeviceShard, SyntheticDataset};
use crate::sim::{CpuModel, EnergyModel, Region};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Edge {
    pub id: usize,
    pub region: Region,
    pub members: Vec<usize>,
}

pub struct Topology {
    pub edges: Vec<Edge>,
    pub device_regions: Vec<Region>,
    pub cpus: Vec<CpuModel>,
    pub shards: Arc<Vec<DeviceShard>>,
    pub dataset: SyntheticDataset,
    /// Whether the profiling module (clustering) was used.
    pub profiled: bool,
}

impl Topology {
    pub fn edge_of(&self, device: usize) -> usize {
        self.edges
            .iter()
            .position(|e| e.members.contains(&device))
            .expect("device not in any edge")
    }

    /// Re-assign `device -> edge` mapping (used by Share and re-clustering).
    pub fn set_assignment(&mut self, assignment: &[usize]) {
        for e in self.edges.iter_mut() {
            e.members.clear();
        }
        for (dev, &edge) in assignment.iter().enumerate() {
            self.edges[edge].members.push(dev);
        }
    }
}

/// Build the full device population per the experiment config.
/// `use_profiling = false` keeps the naive (round-robin within region)
/// assignment — the Table 1 "non-Cluster" ablation.
pub fn build_topology(
    cfg: &ExperimentConfig,
    use_profiling: bool,
    rng: &mut Rng,
) -> Result<Topology> {
    let n = cfg.topology.devices;
    let m = cfg.topology.edges;
    let n_cn_edges = ((m as f64) * cfg.topology.cn_fraction).round() as usize;
    let edge_regions: Vec<Region> = (0..m)
        .map(|j| if j < n_cn_edges { Region::Cn } else { Region::Us })
        .collect();
    // Devices proportionally split by region, preserving equal edge sizes.
    let per_edge = n / m;
    let mut device_regions = Vec::with_capacity(n);
    for j in 0..m {
        for _ in 0..per_edge {
            device_regions.push(edge_regions[j]);
        }
    }

    // CPU heterogeneity: paper classes 10%..50%, n/5 devices per class,
    // placed randomly across the population (shuffled so class membership
    // is independent of region / naive edge striping).
    let energy = EnergyModel::new(cfg.sim.power_idle, cfg.sim.power_max);
    let mut classes: Vec<usize> = (0..n).map(|i| i % 5).collect();
    rng.shuffle(&mut classes);
    let mut cpus: Vec<CpuModel> = (0..n)
        .map(|i| {
            CpuModel::new(
                CpuModel::paper_class(classes[i]),
                cfg.sim.sgd_base_time,
                cfg.sim.cpu_kappa,
                cfg.sim.time_jitter,
                rng.fork(0x0c9 + i as u64),
            )
        })
        .collect();

    // Data shards.
    let dataset = SyntheticDataset::new(cfg.hfl.dataset, cfg.seed);
    let parts = partition_labels(
        cfg.hfl.partition,
        n,
        cfg.hfl.samples_per_device,
        dataset.classes,
        rng,
    );
    let shards: Vec<DeviceShard> = parts
        .iter()
        .enumerate()
        .map(|(i, labels)| {
            DeviceShard::build(
                &dataset,
                labels,
                &mut rng.fork(0xda7a + i as u64),
            )
        })
        .collect();

    // Device -> edge assignment.
    let assignment: Vec<usize> = if use_profiling {
        let profiles: Vec<_> = cpus
            .iter_mut()
            .map(|c| profile_device(c, &energy, 30))
            .collect();
        let out =
            profile_devices(profiles, &device_regions, &edge_regions, rng);
        out.assignment
    } else {
        // Naive: round-robin across the region's edges.
        let mut next: std::collections::HashMap<Region, usize> =
            Default::default();
        (0..n)
            .map(|i| {
                let r = device_regions[i];
                let region_edges: Vec<usize> = (0..m)
                    .filter(|&j| edge_regions[j] == r)
                    .collect();
                let k = next.entry(r).or_insert(0);
                let e = region_edges[*k % region_edges.len()];
                *k += 1;
                e
            })
            .collect()
    };

    let mut edges: Vec<Edge> = (0..m)
        .map(|j| Edge {
            id: j,
            region: edge_regions[j],
            members: Vec::new(),
        })
        .collect();
    for (dev, &e) in assignment.iter().enumerate() {
        edges[e].members.push(dev);
    }
    for e in &edges {
        anyhow::ensure!(
            !e.members.is_empty(),
            "edge {} ended up empty",
            e.id
        );
        anyhow::ensure!(
            e.members.len() <= cfg.topology.nmax,
            "edge {} has {} members > nmax {}",
            e.id,
            e.members.len(),
            cfg.topology.nmax
        );
    }

    Ok(Topology {
        edges,
        device_regions,
        cpus,
        shards: Arc::new(shards),
        dataset,
        profiled: use_profiling,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::mnist();
        cfg.topology.devices = 20;
        cfg.topology.edges = 5;
        cfg.hfl.samples_per_device = 16;
        cfg
    }

    #[test]
    fn builds_valid_topology_with_profiling() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        let t = build_topology(&cfg, true, &mut rng).unwrap();
        let total: usize = t.edges.iter().map(|e| e.members.len()).sum();
        assert_eq!(total, 20);
        // Region constraint: every member's region matches its edge's.
        for e in &t.edges {
            for &d in &e.members {
                assert_eq!(t.device_regions[d], e.region);
            }
        }
    }

    #[test]
    fn builds_valid_topology_naive() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(2);
        let t = build_topology(&cfg, false, &mut rng).unwrap();
        for e in &t.edges {
            assert_eq!(e.members.len(), 4); // perfectly balanced
        }
    }

    #[test]
    fn profiled_clusters_group_similar_speeds() {
        // With 5 interference classes and 5 same-region edges, profiling
        // should produce edges with lower within-edge usage spread than the
        // naive striping (which mixes all classes into every edge).
        let mut cfg = tiny_cfg();
        cfg.topology.devices = 50;
        cfg.topology.edges = 5;
        cfg.topology.cn_fraction = 1.0; // single region isolates clustering
        let mut rng = Rng::new(3);
        let spread = |t: &Topology| -> f64 {
            t.edges
                .iter()
                .map(|e| {
                    let us: Vec<f64> = e
                        .members
                        .iter()
                        .map(|&d| t.cpus[d].base_usage)
                        .collect();
                    crate::util::stats::std(&us)
                })
                .sum::<f64>()
                / t.edges.len() as f64
        };
        let prof = build_topology(&cfg, true, &mut rng).unwrap();
        let naive = build_topology(&cfg, false, &mut rng).unwrap();
        assert!(
            spread(&prof) < spread(&naive) * 0.8,
            "profiled {} vs naive {}",
            spread(&prof),
            spread(&naive)
        );
    }

    #[test]
    fn set_assignment_moves_devices() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(4);
        let mut t = build_topology(&cfg, false, &mut rng).unwrap();
        let n = cfg.topology.devices;
        let assignment: Vec<usize> = (0..n).map(|i| i % 5).collect();
        t.set_assignment(&assignment);
        for (dev, &e) in assignment.iter().enumerate() {
            assert!(t.edges[e].members.contains(&dev));
        }
    }
}
