//! Synthetic datasets + non-IID partitioners.
//!
//! The build environment is offline, so MNIST/CIFAR-10 are replaced by
//! deterministic class-conditional synthetic sets with identical tensor
//! shapes (see DESIGN.md §3). Samples are `prototype[class] + noise`, with
//! smoothed random-field prototypes — learnable by the paper's CNNs but far
//! from trivially separable, so accuracy climbs over training exactly like
//! the real sets (relative scheme orderings are preserved, absolute
//! accuracies differ).

pub mod partition;
pub mod synthetic;

pub use partition::{partition_labels, DeviceLabels};
pub use synthetic::SyntheticDataset;
