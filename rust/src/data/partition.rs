//! Non-IID partitioners (paper §4.1 and §4.5 / Fig. 10).
//!
//! Produces per-device label multisets under three regimes:
//!  * IID — uniform class mixture everywhere;
//!  * label-skew — each device holds k distinct classes (paper default
//!    k = 2, "each device has 2 classes with an equal amount of data");
//!  * Dirichlet(alpha) — per-device class mixture drawn from a Dirichlet.

use crate::config::Partition;
use crate::util::rng::Rng;

/// The labels each device will hold (length = samples_per_device).
pub type DeviceLabels = Vec<Vec<usize>>;

pub fn partition_labels(
    scheme: Partition,
    devices: usize,
    samples_per_device: usize,
    classes: usize,
    rng: &mut Rng,
) -> DeviceLabels {
    match scheme {
        Partition::Iid => iid(devices, samples_per_device, classes, rng),
        Partition::LabelSkew { labels } => {
            label_skew(devices, samples_per_device, classes, labels, rng)
        }
        Partition::Dirichlet { alpha } => {
            dirichlet(devices, samples_per_device, classes, alpha, rng)
        }
    }
}

fn iid(
    devices: usize,
    spd: usize,
    classes: usize,
    rng: &mut Rng,
) -> DeviceLabels {
    (0..devices)
        .map(|_| (0..spd).map(|_| rng.below(classes)).collect())
        .collect()
}

fn label_skew(
    devices: usize,
    spd: usize,
    classes: usize,
    k: usize,
    rng: &mut Rng,
) -> DeviceLabels {
    let k = k.clamp(1, classes);
    (0..devices)
        .map(|_| {
            let own = rng.sample_indices(classes, k);
            let per = spd / k;
            let mut labels = Vec::with_capacity(spd);
            for (j, &cls) in own.iter().enumerate() {
                let cnt = if j == k - 1 { spd - per * (k - 1) } else { per };
                labels.extend(std::iter::repeat(cls).take(cnt));
            }
            rng.shuffle(&mut labels);
            labels
        })
        .collect()
}

fn dirichlet(
    devices: usize,
    spd: usize,
    classes: usize,
    alpha: f64,
    rng: &mut Rng,
) -> DeviceLabels {
    (0..devices)
        .map(|_| {
            let mix = rng.dirichlet(alpha, classes);
            let mut labels: Vec<usize> =
                (0..spd).map(|_| rng.weighted(&mix)).collect();
            rng.shuffle(&mut labels);
            labels
        })
        .collect()
}

/// Device x class count matrix (Fig. 10 visualization / Share baseline).
pub fn distribution_matrix(
    parts: &DeviceLabels,
    classes: usize,
) -> Vec<Vec<usize>> {
    parts
        .iter()
        .map(|labels| {
            let mut h = vec![0usize; classes];
            for &l in labels {
                h[l] += 1;
            }
            h
        })
        .collect()
}

/// Mean per-device label entropy in bits — a scalar non-IID'ness measure
/// (IID -> log2(classes); 1-label devices -> 0).
pub fn mean_label_entropy(parts: &DeviceLabels, classes: usize) -> f64 {
    let mat = distribution_matrix(parts, classes);
    let mut total = 0.0;
    for row in &mat {
        let n: usize = row.iter().sum();
        if n == 0 {
            continue;
        }
        let mut h = 0.0;
        for &c in row {
            if c > 0 {
                let p = c as f64 / n as f64;
                h -= p * p.log2();
            }
        }
        total += h;
    }
    total / parts.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    fn scheme_of(g: &mut Gen) -> Partition {
        match g.usize_in(0, 2) {
            0 => Partition::Iid,
            1 => Partition::LabelSkew {
                labels: g.usize_in(1, 5),
            },
            _ => Partition::Dirichlet {
                alpha: g.f64_in(0.1, 5.0),
            },
        }
    }

    #[test]
    fn prop_every_scheme_yields_full_shards() {
        check(
            "partition-shapes",
            60,
            |g| {
                let devices = g.usize_in(1, 30);
                let spd = g.usize_in(1, 64);
                (scheme_of(g), devices, spd, g.rng.next_u64())
            },
            |&(scheme, devices, spd, seed)| {
                let mut rng = Rng::new(seed);
                let parts =
                    partition_labels(scheme, devices, spd, 10, &mut rng);
                if parts.len() != devices {
                    return Err("wrong device count".into());
                }
                for p in &parts {
                    if p.len() != spd {
                        return Err("wrong shard size".into());
                    }
                    if p.iter().any(|&l| l >= 10) {
                        return Err("label out of range".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn label_skew_has_exactly_k_classes() {
        let mut rng = Rng::new(3);
        let parts = partition_labels(
            Partition::LabelSkew { labels: 2 },
            50,
            120,
            10,
            &mut rng,
        );
        for p in &parts {
            let mut classes: Vec<usize> = p.clone();
            classes.sort_unstable();
            classes.dedup();
            assert_eq!(classes.len(), 2);
        }
    }

    #[test]
    fn entropy_ordering_iid_gt_dirichlet_gt_label2() {
        let mut rng = Rng::new(4);
        let iid = partition_labels(Partition::Iid, 50, 200, 10, &mut rng);
        let dir = partition_labels(
            Partition::Dirichlet { alpha: 0.5 },
            50,
            200,
            10,
            &mut rng,
        );
        let lab = partition_labels(
            Partition::LabelSkew { labels: 2 },
            50,
            200,
            10,
            &mut rng,
        );
        let (ei, ed, el) = (
            mean_label_entropy(&iid, 10),
            mean_label_entropy(&dir, 10),
            mean_label_entropy(&lab, 10),
        );
        assert!(ei > ed, "iid {ei} <= dirichlet {ed}");
        assert!(ed > el, "dirichlet {ed} <= label2 {el}");
        assert!(ei > 3.2, "iid entropy should approach log2(10)={ei}");
        assert!(el <= 1.0 + 1e-9, "2-label entropy must be <= 1 bit: {el}");
    }

    #[test]
    fn distribution_matrix_row_sums() {
        let mut rng = Rng::new(5);
        let parts = partition_labels(Partition::Iid, 10, 40, 10, &mut rng);
        let mat = distribution_matrix(&parts, 10);
        for row in mat {
            assert_eq!(row.iter().sum::<usize>(), 40);
        }
    }
}
