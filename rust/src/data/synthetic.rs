//! Class-conditional synthetic image generator.

use crate::config::Dataset;
use crate::util::rng::Rng;

/// A generated dataset: per-class prototypes plus sampling machinery.
pub struct SyntheticDataset {
    pub dataset: Dataset,
    pub classes: usize,
    shape: [usize; 3],
    /// classes x (H*W*C) smoothed prototype images.
    prototypes: Vec<Vec<f32>>,
    /// Noise scale relative to prototype energy. CIFAR-shape gets noisier
    /// (harder task, mirroring the real difficulty gap).
    noise: f32,
}

impl SyntheticDataset {
    pub fn new(dataset: Dataset, seed: u64) -> Self {
        let shape = dataset.input_shape();
        let classes = dataset.classes();
        let mut rng = Rng::new(seed ^ 0xda7a_5e7);
        let noise = match dataset {
            Dataset::Mnist => 0.9,
            Dataset::Cifar => 1.4,
        };
        let prototypes = (0..classes)
            .map(|c| Self::make_prototype(&mut rng.fork(c as u64), shape))
            .collect();
        SyntheticDataset {
            dataset,
            classes,
            shape,
            prototypes,
            noise,
        }
    }

    pub fn sample_len(&self) -> usize {
        self.shape[0] * self.shape[1] * self.shape[2]
    }

    pub fn shape(&self) -> [usize; 3] {
        self.shape
    }

    /// Smoothed random field: white noise box-blurred twice, normalized.
    fn make_prototype(rng: &mut Rng, shape: [usize; 3]) -> Vec<f32> {
        let [h, w, c] = shape;
        let mut img: Vec<f32> =
            (0..h * w * c).map(|_| rng.normal() as f32).collect();
        for _ in 0..2 {
            img = box_blur(&img, h, w, c);
        }
        // Normalize to unit std so noise scale is comparable across shapes.
        let mean = img.iter().sum::<f32>() / img.len() as f32;
        let var = img
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f32>()
            / img.len() as f32;
        let s = var.sqrt().max(1e-6);
        for x in img.iter_mut() {
            *x = (*x - mean) / s;
        }
        img
    }

    /// One sample of class `label`, written into `out` (len = sample_len).
    pub fn sample_into(&self, label: usize, rng: &mut Rng, out: &mut [f32]) {
        let proto = &self.prototypes[label];
        debug_assert_eq!(out.len(), proto.len());
        // Small random translation (±2 px) + additive noise.
        let [h, w, c] = self.shape;
        let dy = rng.below(5) as isize - 2;
        let dx = rng.below(5) as isize - 2;
        for y in 0..h {
            for x in 0..w {
                let sy = (y as isize + dy).clamp(0, h as isize - 1) as usize;
                let sx = (x as isize + dx).clamp(0, w as isize - 1) as usize;
                for ch in 0..c {
                    let v = proto[(sy * w + sx) * c + ch]
                        + self.noise * rng.normal() as f32;
                    out[(y * w + x) * c + ch] = v;
                }
            }
        }
    }

    /// Generate `n` samples with the given labels; returns flat [n, H*W*C].
    pub fn generate(&self, labels: &[usize], rng: &mut Rng) -> Vec<f32> {
        let sl = self.sample_len();
        let mut out = vec![0.0f32; labels.len() * sl];
        for (i, &lab) in labels.iter().enumerate() {
            self.sample_into(lab, rng, &mut out[i * sl..(i + 1) * sl]);
        }
        out
    }

    /// Uniform-label test set: (flat images, labels).
    pub fn test_set(&self, n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed ^ 0x7e57_5e7);
        let labels: Vec<usize> = (0..n).map(|i| i % self.classes).collect();
        let x = self.generate(&labels, &mut rng);
        (x, labels.iter().map(|&l| l as i32).collect())
    }
}

fn box_blur(img: &[f32], h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; img.len()];
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                let mut acc = 0.0;
                let mut cnt = 0.0;
                for dy in -1isize..=1 {
                    for dx in -1isize..=1 {
                        let sy = y as isize + dy;
                        let sx = x as isize + dx;
                        if sy >= 0
                            && sy < h as isize
                            && sx >= 0
                            && sx < w as isize
                        {
                            acc += img
                                [(sy as usize * w + sx as usize) * c + ch];
                            cnt += 1.0;
                        }
                    }
                }
                out[(y * w + x) * c + ch] = acc / cnt;
            }
        }
    }
    out
}

/// Per-device training shard, laid out for the `train_epoch` artifact.
pub struct DeviceShard {
    /// All samples, flat [n, sample_len].
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub sample_len: usize,
}

impl DeviceShard {
    pub fn build(
        ds: &SyntheticDataset,
        labels: &[usize],
        rng: &mut Rng,
    ) -> Self {
        DeviceShard {
            x: ds.generate(labels, rng),
            y: labels.iter().map(|&l| l as i32).collect(),
            n: labels.len(),
            sample_len: ds.sample_len(),
        }
    }

    /// Epoch tensor pair ([nb*batch*sample_len], [nb*batch]) with a fresh
    /// shuffle of the shard each call (order: scan batches).
    pub fn epoch_tensors(
        &self,
        nb: usize,
        batch: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<i32>) {
        let need = nb * batch;
        let mut order: Vec<usize> = (0..self.n).collect();
        rng.shuffle(&mut order);
        // If the shard is smaller than an epoch's worth, wrap around.
        let mut x = Vec::with_capacity(need * self.sample_len);
        let mut y = Vec::with_capacity(need);
        for k in 0..need {
            let i = order[k % self.n];
            x.extend_from_slice(
                &self.x[i * self.sample_len..(i + 1) * self.sample_len],
            );
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// Class histogram (for Fig. 10 and the Share baseline).
    pub fn class_histogram(&self, classes: usize) -> Vec<usize> {
        let mut h = vec![0usize; classes];
        for &l in &self.y {
            h[l as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let a = SyntheticDataset::new(Dataset::Mnist, 1);
        let b = SyntheticDataset::new(Dataset::Mnist, 1);
        let mut ra = Rng::new(2);
        let mut rb = Rng::new(2);
        let xa = a.generate(&[0, 5, 9], &mut ra);
        let xb = b.generate(&[0, 5, 9], &mut rb);
        assert_eq!(xa, xb);
    }

    #[test]
    fn shapes_match_dataset() {
        let m = SyntheticDataset::new(Dataset::Mnist, 3);
        assert_eq!(m.sample_len(), 28 * 28);
        let c = SyntheticDataset::new(Dataset::Cifar, 3);
        assert_eq!(c.sample_len(), 32 * 32 * 3);
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean inter-class L2 distance must exceed intra-class sample noise
        // spread by a visible margin (the learnability precondition).
        let ds = SyntheticDataset::new(Dataset::Mnist, 7);
        let mut rng = Rng::new(11);
        let sl = ds.sample_len();
        let a = ds.generate(&[0; 32], &mut rng);
        let b = ds.generate(&[1; 32], &mut rng);
        let mean = |v: &[f32]| -> Vec<f32> {
            let n = v.len() / sl;
            let mut m = vec![0.0f32; sl];
            for i in 0..n {
                for j in 0..sl {
                    m[j] += v[i * sl + j] / n as f32;
                }
            }
            m
        };
        let ma = mean(&a);
        let mb = mean(&b);
        let inter: f32 = ma
            .iter()
            .zip(&mb)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f32>()
            .sqrt();
        assert!(inter > 5.0, "inter-class distance too small: {inter}");
    }

    #[test]
    fn shard_epoch_tensors_sized_and_wrapping() {
        let ds = SyntheticDataset::new(Dataset::Mnist, 5);
        let mut rng = Rng::new(6);
        let shard = DeviceShard::build(&ds, &[1, 2, 3], &mut rng);
        let (x, y) = shard.epoch_tensors(2, 4, &mut rng); // needs 8 > 3
        assert_eq!(x.len(), 8 * ds.sample_len());
        assert_eq!(y.len(), 8);
        for lab in y {
            assert!([1, 2, 3].contains(&lab));
        }
    }

    #[test]
    fn test_set_label_coverage() {
        let ds = SyntheticDataset::new(Dataset::Mnist, 5);
        let (_, y) = ds.test_set(100, 1);
        for cls in 0..10 {
            assert!(y.iter().filter(|&&l| l == cls).count() == 10);
        }
    }

    #[test]
    fn histogram_counts_labels() {
        let ds = SyntheticDataset::new(Dataset::Mnist, 5);
        let mut rng = Rng::new(6);
        let shard = DeviceShard::build(&ds, &[0, 0, 1, 9], &mut rng);
        let h = shard.class_histogram(10);
        assert_eq!(h[0], 2);
        assert_eq!(h[1], 1);
        assert_eq!(h[9], 1);
    }
}
