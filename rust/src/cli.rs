//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! Subcommands:
//!   run         one scheme to the time threshold, printing the round log
//!   train-agent PPO training (Algorithm 1), saving episode logs
//!   experiment  regenerate a paper table/figure (see `list`)
//!   profile     run the profiling module and print the clustering
//!   list        show artifacts, experiments and presets

use anyhow::{bail, Context, Result};

use crate::agent::{run_policy_on, train_arena, train_arena_on, ArenaOptions};
use crate::baselines;
use crate::config::{ExperimentConfig, SyncModeCfg};
use crate::exp;
use crate::hfl::{AsyncHflEngine, HflEngine};
use crate::obs::{ObsState, Observer, RunObserver, TelemetryServer};

const USAGE: &str = "\
arena — learning-based synchronization for hierarchical federated learning

USAGE:
  arena run [--preset mnist|cifar] [--scheme NAME] [--set key=value ...]
            [--serve ADDR] [--trace-out PATH]
  arena train-agent [--preset ...] [--episodes N] [--hwamei] [--set ...]
  arena experiment <ID> [--preset ...] [--set ...]    (fig2..fig12, table1, table2, all)
  arena profile [--preset ...] [--set ...]
  arena list

SCHEMES: vanilla-fl vanilla-hfl var-freq-a var-freq-b favor share arena hwamei
         semi-sync async-greedy arena-async
         (the last three pick their sync.mode themselves; tune them with
         --set sync.quorum=K, sync.staleness_alpha=A, sync.cloud_interval=S;
         --set sim.leave_prob=P / sim.join_prob=P enables device churn)

LEARNED: arena-async trains the DRL agent ON the event engine (sets
         sync.learned): the action re-arms per-edge local-epoch counts
         gamma1_j and staleness exponents alpha_j at every cloud decision
         point, fed by the per-edge staleness/in-flight/quorum state rows.
         Bound the alpha decode with --set sync.alpha_min=A /
         sync.alpha_max=B; needs the _ctrl artifacts (make artifacts).
         train-agent with --set sync.learned=true (and an event
         sync.mode) trains the same controller standalone.
         The fig_async_headtohead experiment compares it against fixed
         semi-sync K and fixed-alpha async at matched energy budgets.

LINKS:   every edge<->cloud transfer is an in-flight event on a per-edge
         uplink/downlink pair; tune with
         --set link.up_bandwidth_scale=S / link.down_bandwidth_scale=S
         (multiples of the region bandwidth) and
         --set link.contention=true|false (fair-share when transfers
         overlap on one link)

CHURN:   with sim.leave_prob/join_prob enabled, the membership subsystem
         can re-cluster the live population when the active set drifts:
         --set cluster.recluster_threshold=F (drift fraction; 0 = off,
         try 0.1-0.3) and --set cluster.recluster_min_interval=S
         (simulated seconds between re-clusterings). Migrated devices
         warm-start from their new edge's model over its downlink.

FAULTS:  deterministic failure injection (hfl::lifecycle): faults are
         *scheduled events*, expanded once from the experiment seed, so
         every fault run is reproducible and bitwise identical at any
         sim.workers / queue backend.
         --set fault.outages=N / fault.outage_duration=S        edge-
         aggregator outages (reports die, members idle, warm rejoin);
         --set fault.partitions=N / fault.partition_duration=S  edge<->
         cloud partitions (local training continues, uploads dropped);
         --set fault.crash_storms=N / fault.crash_frac=F /
         fault.rejoin_delay=S        mass device crashes + delayed rejoin.
         Counters surface as the arena_fault_* series in /metrics.

LIFECYCLE: production client-lifecycle knobs (event modes):
         --set lifecycle.overselect=F dispatches ceil(K*F) devices per
         semi-sync edge round and abandons the stragglers once the
         first K land (the classic 130% over-selection is F=1.3);
         --set lifecycle.pace_day=S / lifecycle.avail_frac=F give every
         device a seeded diurnal availability window: the event engine
         *defers* dispatches to the window's edge (pace steering — never
         skips), the barrier engine selects by availability at round
         boundaries. Abandonment rate and availability feed the history
         CSV (schema v2) and the extended DRL state; the fig_lifecycle
         experiment compares learned vs fixed policies under a fault
         storm at matched energy.

SCALE:   --set sim.workers=W runs the simulation layers (per-device
         time/energy draws, sharded event shards, AND the full
         AsyncHflEngine event loop in the timer modes) on W threads
         (0 = all cores); --set sim.queue_backend=auto|binary|calendar
         picks the event-queue backend (auto switches to the calendar
         queue above ~1M expected events). Both are execution details:
         any W and any backend produce bitwise identical trajectories,
         so neither is part of the run identity (config digest). The
         engine loop itself is sharded by edge — each shard owns the
         event heap, links, RNG streams and lifecycle state for its
         edges and advances in parallel to the next ctrl event (cloud
         window / churn flip / recluster / seeded fault), where shard
         action logs replay in fixed shard order — so semi-sync and
         async runs (arena run --set sync.mode=semi_sync, figures,
         agent training, fault campaigns) scale with cores. The
         sharded 1M+ device paths are exercised by
         examples/sharded_scale.rs (synthetic device sim) and
         examples/engine_scale.rs (engine event loop; same flags plus
         --quorum/--overselect/--async and fault.* switches).

OBSERVE: run --serve 127.0.0.1:9898 attaches a read-only observer and
         serves GET / (a self-contained live dashboard: round progress,
         per-edge staleness bars, shard-imbalance and barrier-stall
         sparklines — plain HTML+JS, no external assets), /healthz,
         /metrics (Prometheus text, incl. the arena_shard_* /
         arena_pool_* parallel-runtime series), /stream (NDJSON: one
         \"round\" frame per closed cloud round plus one \"shard_window\"
         frame per sharded barrier) and /trace (the current
         chrome://tracing JSON) while the run progresses; the server
         stays up after the run until ctrl-c. --trace-out PATH writes
         the same timeline to a file (one track per edge, plus shard/N
         and worker/N tracks when the sharded runtime is profiled).
         Observation never perturbs the run: profiler-on is bitwise
         identical to profiler-off at any worker count (turn the
         per-shard profiler off with --set sim.profiler=false).
         Without the compiled artifacts, --serve falls back to a
         sim-only demo feed — a profiled sharded run, a sharded-store
         walkthrough, then synthetic rounds — so every endpoint serves
         genuine data (CI does exactly that).
";

pub struct Args {
    pub positional: Vec<String>,
    pub flags: std::collections::BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub sets: Vec<(String, String)>,
}

pub fn parse_args(argv: &[String]) -> Result<Args> {
    let mut a = Args {
        positional: vec![],
        flags: Default::default(),
        switches: vec![],
        sets: vec![],
    };
    let mut i = 0;
    while i < argv.len() {
        let arg = &argv[i];
        if arg == "--set" {
            let kv = argv
                .get(i + 1)
                .context("--set needs key=value")?;
            let (k, v) = kv
                .split_once('=')
                .context("--set needs key=value")?;
            a.sets.push((k.to_string(), v.to_string()));
            i += 2;
        } else if let Some(name) = arg.strip_prefix("--") {
            // Value-taking flag if next token isn't a flag; else a switch.
            match argv.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    a.flags.insert(name.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    a.switches.push(name.to_string());
                    i += 1;
                }
            }
        } else {
            a.positional.push(arg.clone());
            i += 1;
        }
    }
    Ok(a)
}

/// Build the config from preset/--config plus --set overrides, without
/// validating — cmd_run adjusts scheme-driven knobs before validation.
fn config_from_raw(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.flags.get("config") {
        ExperimentConfig::load(path)?
    } else {
        let preset = args
            .flags
            .get("preset")
            .map(|s| s.as_str())
            .unwrap_or("mnist");
        ExperimentConfig::preset(preset)?
    };
    for (k, v) in &args.sets {
        cfg.apply_override(k, v)?;
    }
    Ok(cfg)
}

pub fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let cfg = config_from_raw(args)?;
    cfg.validate()?;
    Ok(cfg)
}

pub fn run(argv: Vec<String>) -> Result<()> {
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = parse_args(&argv[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "train-agent" => cmd_train_agent(&args),
        "experiment" => cmd_experiment(&args),
        "profile" => cmd_profile(&args),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let scheme = args
        .flags
        .get("scheme")
        .map(|s| s.as_str())
        .unwrap_or("vanilla-hfl");
    let mut cfg = config_from_raw(args)?;
    // arena-async picks an event mode itself; flip it before validation
    // so an explicit --set sync.learned=true isn't bounced by the
    // learned+synchronous check this scheme would have satisfied anyway.
    if scheme == "arena-async" && cfg.sync.mode == SyncModeCfg::Synchronous {
        cfg.sync.mode = SyncModeCfg::Async;
    }
    cfg.validate()?;
    println!(
        "running {scheme} on {} (T={}s, {} devices / {} edges)",
        cfg.hfl.dataset.name(),
        cfg.hfl.threshold_time,
        cfg.topology.devices,
        cfg.topology.edges
    );
    // A set-but-ignored learned flag must not end up in run provenance:
    // only arena-async actually drives the learned controller.
    anyhow::ensure!(
        !cfg.sync.learned || scheme == "arena-async",
        "sync.learned is the arena-async scheme's knob; '{scheme}' runs \
         fixed knobs — drop the flag or use --scheme arena-async"
    );
    // Telemetry (`obs`): --serve starts the scrape/stream server,
    // --trace-out dumps a Chrome-trace timeline after the run. Both ride
    // the read-only Observer, so the simulated run is bit-for-bit the
    // same with or without them.
    let serve = args.flags.get("serve");
    let trace_out = args.flags.get("trace-out");
    let mut server = None;
    if let Some(addr) = serve {
        let srv = TelemetryServer::bind(addr)?;
        println!(
            "telemetry: /healthz /metrics /stream on http://{}",
            srv.local_addr()
        );
        server = Some(srv);
    }
    let mut observer = if server.is_some() || trace_out.is_some() {
        Some(match &server {
            Some(s) => RunObserver::with_sink(s.sink()),
            None => RunObserver::new(),
        })
    } else {
        None
    };
    let obs_state = observer.as_ref().map(|o| o.state());
    // No compiled artifacts — no engine. When observing, fall back to a
    // sim-only demo feed so the endpoints still serve real exposition and
    // frames (the CI smoke path); otherwise fail as before.
    if observer.is_some() && !artifacts_present() {
        println!(
            "artifacts missing (run `make artifacts` for a real run): \
             serving a sim-only telemetry demo instead"
        );
        run_telemetry_demo(observer.take().unwrap(), 6, &cfg);
        return finish_observation(obs_state, trace_out, server);
    }
    let hist = match scheme {
        // Event-driven schemes run on the async engine.
        "semi-sync" => {
            let mut c = cfg.clone();
            c.sync.mode = SyncModeCfg::SemiSync;
            let mut engine = AsyncHflEngine::new(c, true)?;
            if let Some(o) = observer.take() {
                engine.attach_observer(Box::new(o));
            }
            engine.run_to_threshold()?
        }
        "async-greedy" => {
            let mut c = cfg.clone();
            c.sync.mode = SyncModeCfg::Async;
            let mut engine = AsyncHflEngine::new(c, true)?;
            if let Some(o) = observer.take() {
                engine.attach_observer(Box::new(o));
            }
            baselines::async_greedy::async_greedy(&mut engine)?
        }
        "arena-async" => {
            // Learned per-edge (γ1_j, α_j) control of the event engine
            // (the mode was already flipped to an event one above).
            let mut c = cfg.clone();
            c.sync.learned = true;
            let mut engine = AsyncHflEngine::new(c.clone(), true)?;
            let opts = ArenaOptions {
                verbose: true,
                ..ArenaOptions::arena(c.agent.episodes)
            };
            let (agent, sb, _) = train_arena_on(&mut engine, &opts)?;
            // Roll out on a fresh engine: training advanced the churn
            // process on the old one, and the reported run should be a
            // pure function of the seed. The observer watches the
            // reported rollout, not the training episodes.
            let mut engine = AsyncHflEngine::new(c, true)?;
            if let Some(o) = observer.take() {
                engine.attach_observer(Box::new(o));
            }
            run_policy_on(&mut engine, &agent, &sb, true)?
        }
        _ => {
            let mut engine = HflEngine::new(cfg.clone(), true)?;
            if let Some(o) = observer.take() {
                engine.attach_observer(Box::new(o));
            }
            match scheme {
                "vanilla-fl" => baselines::vanilla_fl(&mut engine, 0.6)?,
                "vanilla-hfl" => baselines::vanilla_hfl(&mut engine)?,
                "var-freq-a" => baselines::var_freq::var_freq_a(&mut engine)?,
                "var-freq-b" => baselines::var_freq::var_freq_b(&mut engine)?,
                "favor" => baselines::favor::favor(
                    &mut engine,
                    &baselines::favor::FavorOptions::default(),
                )?,
                "share" => baselines::share::share(&mut engine)?,
                "arena" | "hwamei" => {
                    let opts = if scheme == "arena" {
                        ArenaOptions {
                            verbose: true,
                            ..ArenaOptions::arena(cfg.agent.episodes)
                        }
                    } else {
                        ArenaOptions {
                            verbose: true,
                            ..ArenaOptions::hwamei(cfg.agent.episodes)
                        }
                    };
                    let (agent, sb, _) = train_arena(&mut engine, &opts)?;
                    crate::agent::arena::run_arena_policy(
                        &mut engine,
                        &agent,
                        &sb,
                        opts.nearest_solution,
                    )?
                }
                other => bail!("unknown scheme '{other}'"),
            }
        }
    };
    for r in &hist.rounds {
        println!(
            "k={:<3} t={:>8.1}s acc={:.3} loss={:.3} E={:>8.2}mAh g1={:?} g2={:?}",
            r.k, r.sim_now, r.accuracy, r.train_loss, r.energy,
            r.gamma1, r.gamma2
        );
    }
    println!(
        "final: acc {:.3}, total energy {:.1} mAh ({:.1}/device)",
        hist.final_accuracy(),
        hist.total_energy(),
        hist.total_energy() / cfg.topology.devices as f64
    );
    finish_observation(obs_state, trace_out, server)
}

/// True when the AOT artifact directory (env `ARENA_ARTIFACTS`, default
/// `artifacts/`) holds a manifest — without one no engine can be built.
fn artifacts_present() -> bool {
    let dir = std::env::var("ARENA_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    std::path::Path::new(&dir).join("manifest.json").exists()
}

/// End-of-run observability epilogue: write the Chrome trace if asked,
/// refresh the server's scrape text one last time, and — when serving —
/// hold the process so late scrapers still get answers (ctrl-c to exit).
fn finish_observation(
    state: Option<std::sync::Arc<std::sync::Mutex<ObsState>>>,
    trace_out: Option<&String>,
    server: Option<TelemetryServer>,
) -> Result<()> {
    let Some(state) = state else { return Ok(()) };
    let st = state.lock().unwrap();
    if let Some(path) = trace_out {
        st.trace.write_chrome_json(path)?;
        println!(
            "trace: wrote {} spans to {path} (load at chrome://tracing)",
            st.trace.len()
        );
    }
    if let Some(srv) = &server {
        // Cover runs whose last rounds closed after the final sink
        // publish (or that never had a sink-publishing round at all).
        srv.sink().set_metrics(st.registry.render_prometheus());
        srv.sink().set_trace(st.trace.to_chrome_json());
        drop(st);
        println!("run complete; telemetry stays up (ctrl-c to exit)");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    Ok(())
}

/// Sim-only telemetry feed for hosts without compiled artifacts: a real
/// profiled sharded run, a sharded-store walkthrough, then a seeded event
/// schedule — all through the real observer/exporter stack, so `--serve`
/// answers with genuine exposition text, shard_window frames, a live
/// trace and round frames. The synthetic rounds run last so the stream's
/// replay latch holds a "round" frame for late subscribers (CI). The
/// sharded phase is seed-deterministic; the synthetic rounds are a pure
/// function of the loop indices — no wall-clock in the data (wall-clock
/// feeds only the handler-cost and profiler histograms, exactly as in a
/// real observed run).
fn run_telemetry_demo(
    obs: RunObserver,
    rounds: usize,
    cfg: &ExperimentConfig,
) {
    use crate::hfl::{RoundAccumulator, ShardedModelStore};
    use crate::sim::{Event, EventQueue, ShardSpec, ShardedDeviceSim};

    // Phase 1 — the parallel runtime, for real: a small churny sharded
    // sim under the configured worker count/backend, profiler feeding
    // arena_shard_*/arena_pool_* series and shard/worker trace tracks.
    // The fault plan (seeded, scheduled events) makes the arena_fault_*
    // series carry real injections — the CI smoke greps for them.
    let spec = ShardSpec {
        devices: 96,
        edges: 8,
        shards: 8,
        p: 16,
        windows: 4,
        workers: cfg.sim.workers,
        backend: cfg.sim.queue_backend,
        outages: 2,
        outage_duration: 30.0,
        partitions: 1,
        partition_duration: 40.0,
        crash_storms: 1,
        rejoin_delay: 25.0,
        ..Default::default()
    };
    let mut sim = ShardedDeviceSim::new(&spec);
    sim.set_profiler(cfg.sim.profiler);
    sim.attach_observer(Box::new(obs));
    sim.run();
    let mut obs = sim.detach_observer().expect("observer was attached");

    // Phase 2 — sharded-store observables: replicate a cloud model to
    // every shard, adopt one trained result across a shard boundary,
    // and snapshot the traffic/sharing gauges.
    let mut store = ShardedModelStore::new(16, 4);
    let cloud = store.insert(0, vec![1.0; 16], 1);
    let replicas = store.replicate_at_barrier(&cloud);
    let mut dev = store.insert(3, vec![0.0; 16], 0);
    let head = store.share(&replicas[3]);
    let trained = store.insert(1, vec![2.0; 16], 2);
    store.adopt_across(&mut dev, trained);
    obs.on_sharded_store(&store.stats());
    store.release(head);
    store.release(dev);
    for r in replicas {
        store.release(r);
    }
    store.release(cloud);

    // Phase 3 — synthetic cloud rounds (as before).
    let m = 4; // edges
    let per_edge = 3; // devices per edge
    let interval = 60.0; // cloud window, sim seconds
    let mut now = 0.0;
    for k in 1..=rounds {
        let mut q = EventQueue::new(0x0b5 ^ k as u64);
        let mut acc = RoundAccumulator::new(m);
        for j in 0..m {
            for i in 0..per_edge {
                let d = j * per_edge + i;
                let t_dev = 5.0 + ((k + 2 * j + 3 * i) % 7) as f64;
                q.schedule(
                    now + t_dev,
                    Event::DeviceTrainDone { device: d, edge: j },
                );
                obs.on_span(crate::obs::Span {
                    track: format!("edge/{j}"),
                    name: format!("train d{d}"),
                    t0_sim: now,
                    t1_sim: now + t_dev,
                    wall_ns: 0,
                });
            }
            q.schedule(now + 15.0, Event::EdgeAggregate { edge: j });
        }
        q.schedule(now + interval, Event::CloudAggregate);
        while let Some((t, ev)) = q.pop() {
            let t0 = std::time::Instant::now();
            let variant = match &ev {
                Event::DeviceTrainDone { device, edge } => {
                    acc.record_train(
                        *edge,
                        *device,
                        t - now,
                        0.4,
                        Some(1.0 / k as f64),
                    );
                    "train_done"
                }
                Event::EdgeAggregate { edge } => {
                    let up = 2.0 + (*edge % 3) as f64;
                    obs.on_transfer(*edge, "up", 1.0e6, t, t + up);
                    "edge_aggregate"
                }
                Event::CloudAggregate => "cloud_aggregate",
                _ => "other",
            };
            obs.on_event_handled(
                variant,
                t,
                0,
                t0.elapsed().as_nanos() as u64,
            );
        }
        for j in 0..m {
            acc.record_window(j, 2.5, 1.5, 11.0, 2.5, 1.5, 4.0, 1.0);
        }
        now += interval;
        let g = vec![1usize; m];
        let a = 0.3 + 0.6 * (k as f64 / rounds as f64);
        let mut stats =
            acc.finish(k, a, 1.0 - a, interval, now, &g, &g);
        stats.active_devices = m * per_edge;
        obs.on_store(m + 1, 1 << 20, 1.0);
        obs.on_round(&stats);
    }
}

fn cmd_train_agent(args: &Args) -> Result<()> {
    let mut cfg = config_from(args)?;
    if let Some(ep) = args.flags.get("episodes") {
        cfg.agent.episodes = ep.parse()?;
    }
    let hwamei = args.switches.iter().any(|s| s == "hwamei");
    let mut opts = if hwamei {
        ArenaOptions::hwamei(cfg.agent.episodes)
    } else {
        ArenaOptions::arena(cfg.agent.episodes)
    };
    opts.verbose = true;
    // sync.learned trains the per-edge (γ1_j, α_j) controller on the
    // event engine; otherwise the paper's barrier agent.
    let logs = if cfg.sync.learned {
        let mut engine = AsyncHflEngine::new(cfg, true)?;
        let (_, _, logs) = train_arena_on(&mut engine, &opts)?;
        logs
    } else {
        let mut engine = HflEngine::new(cfg, true)?;
        let (_, _, logs) = train_arena(&mut engine, &opts)?;
        logs
    };
    let avg_last: f64 = logs
        .iter()
        .rev()
        .take(5)
        .map(|l| l.reward)
        .sum::<f64>()
        / logs.len().min(5) as f64;
    println!(
        "done: {} episodes, mean reward of last 5 = {avg_last:.3}",
        logs.len()
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .context("experiment id required (fig2..fig12, table1, table2, all)")?;
    let cfg = config_from(args)?;
    if id == "all" {
        for name in exp::EXPERIMENTS {
            println!("=== {name} ===");
            exp::run_experiment(name, &cfg)?;
        }
        Ok(())
    } else {
        exp::run_experiment(id, &cfg)
    }
}

fn cmd_profile(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let mut rng = crate::util::rng::Rng::new(cfg.seed);
    let topo = crate::hfl::build_topology(&cfg, true, &mut rng)?;
    println!(
        "profiling-module clustering ({} devices -> {} edges):",
        cfg.topology.devices, cfg.topology.edges
    );
    for e in &topo.edges {
        let usages: Vec<f64> = e
            .members
            .iter()
            .map(|&d| topo.cpus[d].base_usage)
            .collect();
        println!(
            "  edge {} [{}]: {} devices, mean interference {:.2}, spread {:.3}",
            e.id,
            e.region.name(),
            e.members.len(),
            crate::util::stats::mean(&usages),
            crate::util::stats::std(&usages),
        );
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("presets: mnist cifar");
    println!(
        "schemes: vanilla-fl vanilla-hfl var-freq-a var-freq-b favor share arena hwamei semi-sync async-greedy arena-async"
    );
    println!("experiments:");
    for e in exp::EXPERIMENTS {
        println!("  {e}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flags_switches_sets() {
        let argv: Vec<String> = [
            "--preset", "cifar", "--hwamei", "--set", "seed=7",
            "fig8", "--episodes", "3",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let a = parse_args(&argv).unwrap();
        assert_eq!(a.flags.get("preset").unwrap(), "cifar");
        assert_eq!(a.flags.get("episodes").unwrap(), "3");
        assert!(a.switches.contains(&"hwamei".to_string()));
        assert_eq!(a.sets, vec![("seed".to_string(), "7".to_string())]);
        assert_eq!(a.positional, vec!["fig8"]);
    }

    #[test]
    fn config_from_applies_sets() {
        let argv: Vec<String> = ["--preset", "mnist", "--set", "hfl.gamma1=7"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = parse_args(&argv).unwrap();
        let cfg = config_from(&a).unwrap();
        assert_eq!(cfg.hfl.gamma1, 7);
    }
}
