//! Experiment harnesses: one per paper table/figure (DESIGN.md §5).
//!
//! Each harness prints the rows/series the paper reports and writes CSVs
//! under `results/<id>/`. Default scales are reduced for the 1-core CI box
//! (fewer devices, shorter simulated budgets, fewer DRL episodes) —
//! EXPERIMENTS.md records the per-experiment scaling; `--set` overrides
//! restore paper scale. Trained policies are cached under
//! `results/agents/` so figures sharing an agent don't retrain.

use anyhow::{bail, Result};

use crate::agent::{
    arena::{agent_for, run_arena_policy},
    run_policy_on, train_arena, train_arena_on, ArenaOptions,
    ControlledEngine, PpoAgent, StateBuilder,
};
use crate::baselines::{self, favor::FavorOptions};
use crate::config::{Dataset, ExperimentConfig, Partition, SyncModeCfg};
use crate::hfl::{AsyncHflEngine, HflEngine, RunHistory};
use crate::runtime::Runtime;
use crate::sim::{CpuModel, EnergyModel, NetworkModel, Region};
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::util::stats;

pub const EXPERIMENTS: &[&str] = &[
    "fig2", "fig3", "fig4", "fig7", "fig8", "fig9", "fig10", "fig11",
    "fig12", "table1", "table2", "fig_async_headtohead", "fig_lifecycle",
];

pub fn run_experiment(name: &str, cfg: &ExperimentConfig) -> Result<()> {
    let t0 = std::time::Instant::now();
    let res = dispatch_experiment(name, cfg);
    // Per-figure wall time goes through the shared metrics registry so it
    // lands in the same exposition format as engine telemetry: one gauge
    // per figure plus a cross-figure histogram, re-rendered to
    // results/harness_metrics.prom after every experiment.
    let wall = t0.elapsed().as_secs_f64();
    let mut reg = crate::obs::harness_registry().lock().unwrap();
    reg.set_gauge(
        &format!(
            "arena_harness_{}_wall_seconds",
            crate::obs::metric_fragment(name)
        ),
        wall,
    );
    reg.observe("arena_harness_phase_wall_seconds", wall);
    let write = std::fs::create_dir_all("results").and_then(|()| {
        std::fs::write(
            "results/harness_metrics.prom",
            reg.render_prometheus(),
        )
    });
    if let Err(e) = write {
        eprintln!("warn: could not write harness metrics: {e}");
    }
    res
}

fn dispatch_experiment(name: &str, cfg: &ExperimentConfig) -> Result<()> {
    match name {
        "fig2" => fig2(cfg),
        "fig3" => fig3(cfg),
        "fig4" => fig4(cfg),
        "fig7" => fig7(cfg),
        "fig8" => fig8(cfg),
        "fig9" => fig9(cfg),
        "fig10" => fig10(cfg),
        "fig11" => fig11(cfg),
        "fig12" => fig12(cfg),
        "table1" => table1(cfg),
        "table2" => table2(cfg),
        "fig_async_headtohead" => fig_async_headtohead(cfg),
        "fig_lifecycle" => fig_lifecycle(cfg),
        other => bail!("unknown experiment '{other}' (try `arena list`)"),
    }
}

/// Harness default scale: 10 devices / half the simulated budget unless the
/// user overrode topology or ARENA_SCALE=paper is set.
fn scaled(cfg: &ExperimentConfig) -> ExperimentConfig {
    let mut c = cfg.clone();
    if std::env::var("ARENA_SCALE").as_deref() == Ok("paper") {
        c.topology.devices = 50;
        return c;
    }
    if c.topology.devices == 20 {
        // untouched preset -> shrink for wall-clock
        c.topology.devices = 10;
        c.hfl.threshold_time *= 0.5;
        c.agent.episodes = c.agent.episodes.min(6);
    }
    c
}

fn out_dir(id: &str) -> String {
    format!("results/{id}")
}

// ---------------------------------------------------------------------
// Agent cache
// ---------------------------------------------------------------------

struct TrainedAgent {
    agent: PpoAgent,
    sb: StateBuilder,
    logs: Vec<crate::agent::EpisodeLog>,
}

/// Train (or restore) the agent matching `engine`'s layout — the
/// barrier policy or the event engine's `_ctrl` controller, keyed by
/// `agent_cache_key`. On a cache hit, agent_for rebuilds the exact
/// training-time layout/normalization and the bootstrap interval refits
/// the PCA; otherwise train and save.
fn trained_on<E: ControlledEngine>(
    engine: &mut E,
    opts: &ArenaOptions,
    tag: &str,
) -> Result<TrainedAgent> {
    let key = agent_cache_key(
        tag,
        &engine.base().cfg,
        opts,
        engine.base().topo.profiled,
    );
    let path = std::path::PathBuf::from(format!("results/agents/{key}.bin"));
    if path.exists() {
        let cfg = engine.base().cfg.clone();
        let rt = Runtime::load(&cfg.artifacts_dir, &[])?;
        let (mut agent, mut sb) = agent_for(engine, &rt)?;
        engine.begin_episode()?;
        sb.fit_pca(engine.base());
        agent.restore(&path)?;
        println!("  [agent cache hit: {key}]");
        return Ok(TrainedAgent {
            agent,
            sb,
            logs: vec![],
        });
    }
    let (agent, sb, logs) = train_arena_on(engine, opts)?;
    agent.save(&path)?;
    Ok(TrainedAgent { agent, sb, logs })
}

/// Cache key for results/agents: human-readable dimensions plus an
/// FNV-1a digest of the complete config provenance (`cfg.to_json` — link
/// bandwidths, churn probabilities, every sync/sim knob), so ANY config
/// change that alters the environment, the action decode, or the derived
/// state normalization invalidates the cache instead of silently
/// restoring a mismatched policy. The `sd` segment versions the
/// derived-scales normalization era; `ctrl` in the tag distinguishes the
/// event-engine controller.
fn agent_cache_key(
    tag: &str,
    cfg: &ExperimentConfig,
    opts: &ArenaOptions,
    profiled: bool,
) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in cfg.to_json().to_string().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!(
        "{}_sd_{}_{}_d{}_t{}_np{}_{}{}_{:016x}",
        tag,
        cfg.hfl.dataset.name(),
        cfg.hfl.partition.describe(),
        cfg.topology.devices,
        cfg.hfl.threshold_time as u64,
        cfg.agent.npca,
        if opts.use_gae { "arena" } else { "hwamei" },
        if profiled { "" } else { "_noprof" },
        h,
    )
}

fn scheme_history(
    name: &str,
    cfg: &ExperimentConfig,
) -> Result<RunHistory> {
    // Every scheme here runs fixed knobs; a set-but-ignored learned flag
    // would record provenance claiming control that never executed (the
    // learned controller runs in fig_async_headtohead).
    anyhow::ensure!(
        !cfg.sync.learned,
        "sync.learned has no effect on the '{name}' scheme — drop the \
         flag (the learned controller runs in fig_async_headtohead)"
    );
    match name {
        "vanilla-fl" => {
            let mut e = HflEngine::new(cfg.clone(), false)?;
            baselines::vanilla_fl(&mut e, 0.6)
        }
        "vanilla-hfl" => {
            let mut e = HflEngine::new(cfg.clone(), false)?;
            baselines::vanilla_hfl(&mut e)
        }
        "var-freq-a" => {
            let mut e = HflEngine::new(cfg.clone(), true)?;
            baselines::var_freq::var_freq_a(&mut e)
        }
        "var-freq-b" => {
            let mut e = HflEngine::new(cfg.clone(), true)?;
            baselines::var_freq::var_freq_b(&mut e)
        }
        "favor" => {
            let mut e = HflEngine::new(cfg.clone(), false)?;
            baselines::favor::favor(&mut e, &FavorOptions::default())
        }
        "share" => {
            let mut e = HflEngine::new(cfg.clone(), true)?;
            baselines::share::share(&mut e)
        }
        "semi-sync" => {
            let mut c = cfg.clone();
            c.sync.mode = SyncModeCfg::SemiSync;
            // Profiled topology like every other scheme in the
            // head-to-head, so the comparison isolates the sync mode.
            let mut e = AsyncHflEngine::new(c, true)?;
            e.run_to_threshold()
        }
        "async-greedy" => {
            let mut c = cfg.clone();
            c.sync.mode = SyncModeCfg::Async;
            let mut e = AsyncHflEngine::new(c, true)?;
            baselines::async_greedy::async_greedy(&mut e)
        }
        "arena" | "hwamei" => {
            let opts = if name == "arena" {
                ArenaOptions::arena(cfg.agent.episodes)
            } else {
                ArenaOptions::hwamei(cfg.agent.episodes)
            };
            let mut e = HflEngine::new(cfg.clone(), true)?;
            let t = trained_on(&mut e, &opts, "shared")?;
            run_arena_policy(&mut e, &t.agent, &t.sb, opts.nearest_solution)
        }
        other => bail!("unknown scheme {other}"),
    }
}

// ---------------------------------------------------------------------
// Fig. 2 — motivation: accuracy & energy across schemes
// ---------------------------------------------------------------------

fn fig2(cfg: &ExperimentConfig) -> Result<()> {
    let cfg = scaled(cfg);
    let dir = out_dir("fig2");
    let mut w = CsvWriter::create(
        format!("{dir}/{}.csv", cfg.hfl.dataset.name()),
        &["scheme", "accuracy", "energy_per_device_mah"],
    )?;
    println!(
        "Fig.2 ({}, T={}s): termination accuracy and per-device energy",
        cfg.hfl.dataset.name(),
        cfg.hfl.threshold_time
    );
    for scheme in ["vanilla-fl", "vanilla-hfl", "var-freq-a", "var-freq-b"] {
        let h = scheme_history(scheme, &cfg)?;
        let e_dev = h.total_energy() / cfg.topology.devices as f64;
        println!(
            "  {scheme:<12} acc {:.3}  energy/device {:.1} mAh",
            h.final_accuracy(),
            e_dev
        );
        w.row_mixed(scheme, &[h.final_accuracy(), e_dev])?;
        h.write_csv(&format!("{dir}/{scheme}_history.csv"), scheme)?;
    }
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// Fig. 3 — SGD time/energy vs CPU usage (pure simulation sweep)
// ---------------------------------------------------------------------

fn fig3(cfg: &ExperimentConfig) -> Result<()> {
    let dir = out_dir("fig3");
    let mut w = CsvWriter::create(
        format!("{dir}/sweep.csv"),
        &["cpu_usage", "time_mean_s", "time_std_s", "energy_mean_mah",
          "energy_std_mah"],
    )?;
    let energy = EnergyModel::new(cfg.sim.power_idle, cfg.sim.power_max);
    println!("Fig.3: single-SGD time/energy vs available-CPU interference");
    let mut u = 0.05;
    while u <= 0.951 {
        let mut cpu = CpuModel::new(
            u,
            cfg.sim.sgd_base_time,
            cfg.sim.cpu_kappa,
            cfg.sim.time_jitter,
            Rng::new(1234 + (u * 100.0) as u64),
        );
        let mut ts = Vec::new();
        let mut es = Vec::new();
        for _ in 0..200 {
            cpu.step_usage();
            let t = cpu.sgd_time();
            ts.push(t);
            es.push(energy.sgd_energy(&cpu, t));
        }
        println!(
            "  u={u:.2}: time {:.2}±{:.2}s  energy {:.3}±{:.3} mAh",
            stats::mean(&ts),
            stats::std(&ts),
            stats::mean(&es),
            stats::std(&es)
        );
        w.row_mixed(
            &format!("{u:.2}"),
            &[stats::mean(&ts), stats::std(&ts), stats::mean(&es),
              stats::std(&es)],
        )?;
        u += 0.10;
    }
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// Fig. 4 — edge-to-cloud communication time vs model size & region
// ---------------------------------------------------------------------

fn fig4(cfg: &ExperimentConfig) -> Result<()> {
    let dir = out_dir("fig4");
    let mut w = CsvWriter::create(
        format!("{dir}/comm.csv"),
        &["params", "region", "mean_s", "std_s"],
    )?;
    let net = NetworkModel::from_config(&cfg.sim);
    let mut rng = Rng::new(99);
    println!("Fig.4: edge->cloud round-trip time");
    for &params in &[21_840usize, 100_000, 453_845, 1_000_000] {
        for region in [Region::Cn, Region::Us] {
            let bytes = crate::sim::network::model_bytes(params);
            let xs: Vec<f64> = (0..200)
                .map(|_| net.comm_time(region, bytes, &mut rng))
                .collect();
            println!(
                "  {params:>8} params  {:<2}  {:.2}±{:.2}s",
                region.name(),
                stats::mean(&xs),
                stats::std(&xs)
            );
            w.row(&[
                params.to_string(),
                region.name().to_string(),
                format!("{:.4}", stats::mean(&xs)),
                format!("{:.4}", stats::std(&xs)),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// Fig. 7 — DRL training curves (+ Theorem 1 diagnostics)
// ---------------------------------------------------------------------

fn fig7(cfg: &ExperimentConfig) -> Result<()> {
    let cfg = scaled(cfg);
    let dir = out_dir("fig7");
    let mut engine = HflEngine::new(cfg.clone(), true)?;
    let opts = ArenaOptions {
        verbose: true,
        ..ArenaOptions::arena(cfg.agent.episodes)
    };
    let (agent, _sb, logs) = train_arena(&mut engine, &opts)?;
    // Save under trained_on's exact key so fig2/fig8/table2 restore
    // this training run instead of retraining.
    let key =
        agent_cache_key("shared", &engine.cfg, &opts, engine.topo.profiled);
    agent.save(&std::path::PathBuf::from(format!(
        "results/agents/{key}.bin"
    )))?;
    let mut w = CsvWriter::create(
        format!("{dir}/{}.csv", cfg.hfl.dataset.name()),
        &["episode", "reward", "accuracy", "energy_per_device_mah",
          "rounds", "policy_loss", "value_loss", "entropy"],
    )?;
    for l in &logs {
        w.row_mixed(
            &l.episode.to_string(),
            &[l.reward, l.final_accuracy, l.avg_energy,
              l.rounds as f64, l.policy_loss, l.value_loss, l.entropy],
        )?;
    }
    w.flush()?;
    let rewards: Vec<f64> = logs.iter().map(|l| l.reward).collect();
    let accs: Vec<f64> = logs.iter().map(|l| l.final_accuracy).collect();
    println!(
        "Fig.7 summary ({}): reward first->last {:.2} -> {:.2} (ema), acc {:.3} -> {:.3}",
        cfg.hfl.dataset.name(),
        rewards.first().copied().unwrap_or(0.0),
        stats::ema(&rewards, 0.3).last().copied().unwrap_or(0.0),
        accs.first().copied().unwrap_or(0.0),
        accs.last().copied().unwrap_or(0.0),
    );
    // Theorem 1 diagnostic: bound of the executed frequency extremes, at
    // the same constants the per-edge decode gate clamps with.
    let b = crate::agent::convergence_bound(
        &crate::agent::bound::BoundParams::diagnostic(&cfg),
    );
    println!(
        "  Theorem-1 one-round bound at (γ̃1,γ̃2)=({},{}): {b:.5} (<0 ⇒ descent)",
        cfg.hfl.gamma1_max, cfg.hfl.gamma2_max
    );
    Ok(())
}

// ---------------------------------------------------------------------
// Fig. 8 — time-to-accuracy across all schemes
// ---------------------------------------------------------------------

const FIG8_SCHEMES: &[&str] = &[
    "vanilla-fl", "vanilla-hfl", "favor", "share", "semi-sync",
    "async-greedy", "hwamei", "arena",
];

fn fig8(cfg: &ExperimentConfig) -> Result<()> {
    let cfg = scaled(cfg);
    let dir = out_dir("fig8");
    println!(
        "Fig.8 ({}): time-accuracy curves, T={}s",
        cfg.hfl.dataset.name(),
        cfg.hfl.threshold_time
    );
    let mut results = Vec::new();
    for scheme in FIG8_SCHEMES {
        let h = scheme_history(scheme, &cfg)?;
        h.write_csv(&format!("{dir}/{scheme}.csv"), scheme)?;
        println!(
            "  {scheme:<12} final acc {:.3} at t={:.0}s",
            h.final_accuracy(),
            h.total_time()
        );
        results.push((scheme.to_string(), h));
    }
    // Time-to-target: target = 95% of Arena's best accuracy.
    let arena_best = results
        .iter()
        .find(|(s, _)| s == "arena")
        .map(|(_, h)| h.best_accuracy())
        .unwrap_or(0.5);
    let target = 0.95 * arena_best;
    println!("  time to reach {target:.3} accuracy:");
    let arena_t = results
        .iter()
        .find(|(s, _)| s == "arena")
        .and_then(|(_, h)| h.time_to_accuracy(target));
    for (s, h) in &results {
        match h.time_to_accuracy(target) {
            Some(t) => {
                let saving = arena_t
                    .map(|at| format!(" (arena saves {:.1}%)",
                                      100.0 * (1.0 - at / t)))
                    .unwrap_or_default();
                println!("    {s:<12} {t:>8.0}s{saving}");
            }
            None => println!("    {s:<12} never"),
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Fig. 9 — accuracy & energy at different threshold times
// ---------------------------------------------------------------------

fn fig9(cfg: &ExperimentConfig) -> Result<()> {
    let cfg = scaled(cfg);
    let dir = out_dir("fig9");
    let fracs = [0.7, 0.8, 0.9, 1.0];
    let mut w = CsvWriter::create(
        format!("{dir}/{}.csv", cfg.hfl.dataset.name()),
        &["scheme", "threshold_s", "accuracy", "energy_per_device_mah",
          "comm_overlap_frac", "mean_link_util", "n_reclusters",
          "migrated_devices"],
    )?;
    println!(
        "Fig.9 ({}): accuracy/energy at threshold times",
        cfg.hfl.dataset.name()
    );
    for scheme in FIG8_SCHEMES {
        let h = scheme_history(scheme, &cfg)?;
        for &f in &fracs {
            let t = f * cfg.hfl.threshold_time;
            let (acc, energy) = h.at_time(t);
            let e_dev = energy / cfg.topology.devices as f64;
            // Transfer-layer columns for the async-baselines head-to-head:
            // how much comm the scheme hid behind compute, and how loaded
            // its links ran.
            let (overlap, util) = h.comm_stats_at(t);
            // Membership columns: under churn + an enabled
            // cluster.recluster_threshold these report how much the
            // topology moved by time t (0 under the default quiescent
            // setup).
            let (reclusters, migrated) = h.membership_stats_at(t);
            println!(
                "  {scheme:<12} T={t:>6.0}s  acc {acc:.3}  energy/dev {e_dev:.1} mAh  overlap {overlap:.2}  util {util:.2}"
            );
            w.row(&[
                scheme.to_string(),
                format!("{t:.0}"),
                format!("{acc:.4}"),
                format!("{e_dev:.2}"),
                format!("{overlap:.4}"),
                format!("{util:.4}"),
                reclusters.to_string(),
                migrated.to_string(),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// Fig. 10 — non-IID distribution visualizations
// ---------------------------------------------------------------------

fn fig10(cfg: &ExperimentConfig) -> Result<()> {
    let dir = out_dir("fig10");
    let mut rng = Rng::new(cfg.seed);
    println!("Fig.10: per-device class distributions");
    for (name, scheme) in [
        ("label2", Partition::LabelSkew { labels: 2 }),
        ("label5", Partition::LabelSkew { labels: 5 }),
        ("dirichlet0.5", Partition::Dirichlet { alpha: 0.5 }),
        ("iid", Partition::Iid),
    ] {
        let parts = crate::data::partition_labels(
            scheme,
            cfg.topology.devices,
            cfg.hfl.samples_per_device,
            10,
            &mut rng,
        );
        let mat = crate::data::partition::distribution_matrix(&parts, 10);
        let mut w = CsvWriter::create(
            format!("{dir}/{name}.csv"),
            &["device", "c0", "c1", "c2", "c3", "c4", "c5", "c6", "c7",
              "c8", "c9"],
        )?;
        for (d, row) in mat.iter().enumerate() {
            let nums: Vec<f64> = row.iter().map(|&c| c as f64).collect();
            w.row_mixed(&d.to_string(), &nums)?;
        }
        w.flush()?;
        let ent = crate::data::partition::mean_label_entropy(&parts, 10);
        println!("  {name:<13} mean label entropy {ent:.2} bits");
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Fig. 11 — accuracy & energy under different non-IID levels
// ---------------------------------------------------------------------

fn fig11(cfg: &ExperimentConfig) -> Result<()> {
    let base = scaled(cfg);
    let dir = out_dir("fig11");
    let mut w = CsvWriter::create(
        format!("{dir}/{}.csv", base.hfl.dataset.name()),
        &["partition", "scheme", "accuracy", "energy_per_device_mah"],
    )?;
    println!(
        "Fig.11 ({}): schemes under IID / label / Dirichlet non-IID",
        base.hfl.dataset.name()
    );
    for (pname, part) in [
        ("iid", Partition::Iid),
        ("label2", Partition::LabelSkew { labels: 2 }),
        ("dirichlet0.5", Partition::Dirichlet { alpha: 0.5 }),
    ] {
        let mut cfg = base.clone();
        cfg.hfl.partition = part;
        for scheme in ["vanilla-fl", "vanilla-hfl", "share", "arena"] {
            let h = scheme_history(scheme, &cfg)?;
            let e_dev = h.total_energy() / cfg.topology.devices as f64;
            println!(
                "  {pname:<13} {scheme:<12} acc {:.3}  energy/dev {e_dev:.1} mAh",
                h.final_accuracy()
            );
            w.row(&[
                pname.to_string(),
                scheme.to_string(),
                format!("{:.4}", h.final_accuracy()),
                format!("{e_dev:.2}"),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// Fig. 12 — impact of the PCA state dimension
// ---------------------------------------------------------------------

fn fig12(cfg: &ExperimentConfig) -> Result<()> {
    let base = scaled(cfg);
    let dir = out_dir("fig12");
    let mut w = CsvWriter::create(
        format!("{dir}/{}.csv", base.hfl.dataset.name()),
        &["npca", "accuracy", "energy_per_device_mah"],
    )?;
    println!(
        "Fig.12 ({}): Arena accuracy vs n_PCA",
        base.hfl.dataset.name()
    );
    for npca in [2usize, 6, 10] {
        let mut cfg = base.clone();
        cfg.agent.npca = npca;
        let mut e = HflEngine::new(cfg.clone(), true)?;
        let t = trained_on(
            &mut e,
            &ArenaOptions::arena(cfg.agent.episodes),
            "shared",
        )?;
        let h = run_arena_policy(&mut e, &t.agent, &t.sb, true)?;
        let e_dev = h.total_energy() / cfg.topology.devices as f64;
        println!(
            "  n_PCA={npca:<3} acc {:.3}  energy/dev {e_dev:.1} mAh",
            h.final_accuracy()
        );
        w.row_mixed(&npca.to_string(), &[h.final_accuracy(), e_dev])?;
    }
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// Table 1 — profiling module (cluster vs non-cluster)
// ---------------------------------------------------------------------

fn table1(cfg: &ExperimentConfig) -> Result<()> {
    let cfg = scaled(cfg);
    let dir = out_dir("table1");
    let fracs = [0.7, 0.8, 0.9, 1.0];
    let mut w = CsvWriter::create(
        format!("{dir}/{}.csv", cfg.hfl.dataset.name()),
        &["variant", "threshold_s", "accuracy", "energy_per_device_mah"],
    )?;
    println!(
        "Table 1 ({}): Arena with vs without the profiling module",
        cfg.hfl.dataset.name()
    );
    for (variant, profiled) in [("cluster", true), ("non-cluster", false)] {
        let mut e = HflEngine::new(cfg.clone(), profiled)?;
        let t = trained_on(
            &mut e,
            &ArenaOptions::arena(cfg.agent.episodes),
            "shared", // profiling flag is part of the cache key
        )?;
        let h = run_arena_policy(&mut e, &t.agent, &t.sb, true)?;
        for &f in &fracs {
            let tt = f * cfg.hfl.threshold_time;
            let (acc, energy) = h.at_time(tt);
            let e_dev = energy / cfg.topology.devices as f64;
            println!(
                "  {variant:<12} T={tt:>6.0}s  acc {acc:.3}  energy/dev {e_dev:.1} mAh"
            );
            w.row(&[
                variant.to_string(),
                format!("{tt:.0}"),
                format!("{acc:.4}"),
                format!("{e_dev:.2}"),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// fig_async_headtohead — ROADMAP "async baselines head-to-head": the
// learned per-edge (γ1_j, α_j) controller vs fixed semi-sync quorum K vs
// fixed-α async-greedy, on the same event engine and profiled topology,
// compared at matched energy budgets.
// ---------------------------------------------------------------------

fn fig_async_headtohead(cfg: &ExperimentConfig) -> Result<()> {
    let cfg = scaled(cfg);
    let dir = out_dir("fig_async_headtohead");
    let mut histories: Vec<(&str, RunHistory)> = Vec::new();

    // Fixed semi-sync: quorum K edges, fixed default γ1 everywhere.
    let mut semi = cfg.clone();
    semi.sync.mode = SyncModeCfg::SemiSync;
    semi.sync.learned = false;
    let mut e = AsyncHflEngine::new(semi, true)?;
    histories.push(("semi-sync-k", e.run_to_threshold()?));

    // Fixed-α async at the greedy per-edge local-epoch counts.
    let mut fixed = cfg.clone();
    fixed.sync.mode = SyncModeCfg::Async;
    fixed.sync.learned = false;
    let mut e = AsyncHflEngine::new(fixed, true)?;
    let h = baselines::async_greedy::async_greedy(&mut e)?;
    histories.push(("async-fixed-alpha", h));

    // Arena-learned per-edge (γ1_j, α_j) on the same async engine. The
    // greedy rollout runs on a FRESH engine: training episodes advance
    // the mobility/churn process on theirs, and the head-to-head must
    // compare all three schemes from the identical seed-fresh
    // environment the fixed baselines start in.
    let mut learned = cfg.clone();
    learned.sync.mode = SyncModeCfg::Async;
    learned.sync.learned = true;
    let mut e = AsyncHflEngine::new(learned.clone(), true)?;
    let opts = ArenaOptions::arena(learned.agent.episodes);
    let t = trained_on(&mut e, &opts, "ctrl")?;
    let mut e = AsyncHflEngine::new(learned.clone(), true)?;
    let h = run_policy_on(&mut e, &t.agent, &t.sb, true)?;
    histories.push(("arena-learned", h));

    // Matched energy budgets: fractions of the *lowest* total spend, so
    // every scheme has actually reached each budget level.
    let e_min = histories
        .iter()
        .map(|(_, h)| h.total_energy())
        .fold(f64::INFINITY, f64::min);
    let n_dev = cfg.topology.devices as f64;
    let mut w = CsvWriter::create(
        format!("{dir}/{}.csv", cfg.hfl.dataset.name()),
        &["scheme", "energy_budget_mah", "energy_budget_per_device_mah",
          "accuracy", "sim_time", "comm_overlap_frac", "mean_link_util",
          "mean_staleness"],
    )?;
    println!(
        "fig_async_headtohead ({}): learned (γ1_j, α_j) vs semi-sync K vs \
         fixed-α async at matched energy budgets",
        cfg.hfl.dataset.name()
    );
    for (name, h) in &histories {
        h.write_csv(&format!("{dir}/{name}_history.csv"), name)?;
        for &f in &[0.25, 0.5, 0.75, 1.0] {
            let budget = f * e_min;
            let (acc, t_at) = h.at_energy(budget);
            if t_at <= 0.0 {
                // Even the scheme's first cloud window costs more than
                // this budget: there is no state to compare at it, so
                // flag the row instead of emitting a meaningless 0.
                println!(
                    "  {name:<18} E={budget:>8.1} mAh  (first window \
                     exceeds this budget; row skipped)"
                );
                continue;
            }
            let (overlap, util) = h.comm_stats_at(t_at);
            let stale = h.mean_staleness_at(t_at);
            println!(
                "  {name:<18} E={budget:>8.1} mAh  acc {acc:.3}  t {t_at:>7.0}s  \
                 overlap {overlap:.2}  util {util:.2}  staleness {stale:.2}"
            );
            w.row(&[
                name.to_string(),
                format!("{budget:.2}"),
                format!("{:.3}", budget / n_dev),
                format!("{acc:.4}"),
                format!("{t_at:.1}"),
                format!("{overlap:.4}"),
                format!("{util:.4}"),
                format!("{stale:.4}"),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// fig_lifecycle — production client lifecycle under injected failures:
// the learned per-edge (γ1_j, α_j) controller vs fixed semi-sync quorum
// K vs fixed-α async-greedy, all on the same event engine with the SAME
// seeded fault plan (edge outages + an edge↔cloud partition + a device
// crash storm), over-selection and diurnal pace steering enabled,
// compared at matched energy budgets with abandonment/availability/
// fault columns.
// ---------------------------------------------------------------------

fn fig_lifecycle(cfg: &ExperimentConfig) -> Result<()> {
    let mut cfg = scaled(cfg);
    // Default chaos setting when the user didn't bring their own fault
    // plan via --set: two edge outages, one partition, one crash storm,
    // durations scaled to the simulated budget so every event both
    // lands and recovers inside the run.
    if cfg.fault.outages == 0
        && cfg.fault.partitions == 0
        && cfg.fault.crash_storms == 0
    {
        let t = cfg.hfl.threshold_time;
        cfg.fault.outages = 2;
        cfg.fault.outage_duration = 0.06 * t;
        cfg.fault.partitions = 1;
        cfg.fault.partition_duration = 0.08 * t;
        cfg.fault.crash_storms = 1;
        cfg.fault.crash_frac = 0.3;
        cfg.fault.rejoin_delay = 0.05 * t;
    }
    if cfg.lifecycle.overselect == 0.0 {
        cfg.lifecycle.overselect = 1.3; // the classic 130% over-selection
    }
    if cfg.lifecycle.pace_day == 0.0 {
        // Diurnal period = a quarter of the budget: every device cycles
        // through its availability window a few times per run.
        cfg.lifecycle.pace_day = 0.25 * cfg.hfl.threshold_time;
    }
    let dir = out_dir("fig_lifecycle");
    let mut histories: Vec<(&str, RunHistory)> = Vec::new();

    // Fixed semi-sync: quorum K with first-K-of-N over-selection closes.
    let mut semi = cfg.clone();
    semi.sync.mode = SyncModeCfg::SemiSync;
    semi.sync.learned = false;
    let mut e = AsyncHflEngine::new(semi, true)?;
    histories.push(("semi-sync-k", e.run_to_threshold()?));

    // Fixed-α async at the greedy per-edge local-epoch counts.
    let mut fixed = cfg.clone();
    fixed.sync.mode = SyncModeCfg::Async;
    fixed.sync.learned = false;
    let mut e = AsyncHflEngine::new(fixed, true)?;
    let h = baselines::async_greedy::async_greedy(&mut e)?;
    histories.push(("async-fixed-alpha", h));

    // Arena-learned per-edge (γ1_j, α_j), trained under the same fault
    // plan (the ctrl state carries the abandonment-rate and availability
    // observables). Fresh engine for the rollout, same as the
    // head-to-head: all three schemes start from the identical
    // seed-fresh environment, so the fault plan fires identically.
    let mut learned = cfg.clone();
    learned.sync.mode = SyncModeCfg::Async;
    learned.sync.learned = true;
    let mut e = AsyncHflEngine::new(learned.clone(), true)?;
    let opts = ArenaOptions::arena(learned.agent.episodes);
    let t = trained_on(&mut e, &opts, "ctrl")?;
    let mut e = AsyncHflEngine::new(learned.clone(), true)?;
    let h = run_policy_on(&mut e, &t.agent, &t.sb, true)?;
    histories.push(("arena-learned", h));

    // Matched energy budgets: fractions of the lowest total spend, so
    // every scheme has actually reached each budget level.
    let e_min = histories
        .iter()
        .map(|(_, h)| h.total_energy())
        .fold(f64::INFINITY, f64::min);
    let n_dev = cfg.topology.devices as f64;
    let mut w = CsvWriter::create(
        format!("{dir}/{}.csv", cfg.hfl.dataset.name()),
        &["scheme", "energy_budget_mah", "energy_budget_per_device_mah",
          "accuracy", "sim_time", "mean_staleness", "abandoned",
          "mean_availability", "fault_events"],
    )?;
    println!(
        "fig_lifecycle ({}): learned (γ1_j, α_j) vs semi-sync K vs \
         fixed-α async under {} outage(s) / {} partition(s) / {} crash \
         storm(s), overselect {:.2}, at matched energy budgets",
        cfg.hfl.dataset.name(),
        cfg.fault.outages,
        cfg.fault.partitions,
        cfg.fault.crash_storms,
        cfg.lifecycle.overselect,
    );
    for (name, h) in &histories {
        h.write_csv(&format!("{dir}/{name}_history.csv"), name)?;
        for &f in &[0.25, 0.5, 0.75, 1.0] {
            let budget = f * e_min;
            let (acc, t_at) = h.at_energy(budget);
            if t_at <= 0.0 {
                println!(
                    "  {name:<18} E={budget:>8.1} mAh  (first window \
                     exceeds this budget; row skipped)"
                );
                continue;
            }
            let stale = h.mean_staleness_at(t_at);
            let (abandoned, avail, faults) = h.lifecycle_stats_at(t_at);
            println!(
                "  {name:<18} E={budget:>8.1} mAh  acc {acc:.3}  t \
                 {t_at:>7.0}s  abandoned {abandoned}  avail {avail:.2}  \
                 faults {faults}"
            );
            w.row(&[
                name.to_string(),
                format!("{budget:.2}"),
                format!("{:.3}", budget / n_dev),
                format!("{acc:.4}"),
                format!("{t_at:.1}"),
                format!("{stale:.4}"),
                abandoned.to_string(),
                format!("{avail:.4}"),
                faults.to_string(),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// Table 2 — impact of the §3.6 enhancements (Arena vs Hwamei)
// ---------------------------------------------------------------------

fn table2(cfg: &ExperimentConfig) -> Result<()> {
    let cfg = scaled(cfg);
    let dir = out_dir("table2");
    let mut w = CsvWriter::create(
        format!("{dir}/{}.csv", cfg.hfl.dataset.name()),
        &["variant", "accuracy", "energy_per_device_mah",
          "episodes_to_converge"],
    )?;
    println!(
        "Table 2 ({}): Arena vs Hwamei (enhancement ablation)",
        cfg.hfl.dataset.name()
    );
    for (variant, opts) in [
        ("arena", ArenaOptions::arena(cfg.agent.episodes)),
        ("hwamei", ArenaOptions::hwamei(cfg.agent.episodes)),
    ] {
        let mut e = HflEngine::new(cfg.clone(), true)?;
        let t = trained_on(&mut e, &opts, "shared")?;
        let h =
            run_arena_policy(&mut e, &t.agent, &t.sb, opts.nearest_solution)?;
        let e_dev = h.total_energy() / cfg.topology.devices as f64;
        // Convergence episode: first episode whose reward EMA reaches 90%
        // of the final EMA (n/a when the policy came from cache).
        let conv = if t.logs.is_empty() {
            "cached".to_string()
        } else {
            let rewards: Vec<f64> = t.logs.iter().map(|l| l.reward).collect();
            let ema = stats::ema(&rewards, 0.3);
            let last = ema.last().copied().unwrap_or(0.0);
            ema.iter()
                .position(|&r| (r - last).abs() <= 0.1 * last.abs().max(1e-9))
                .unwrap_or(ema.len().saturating_sub(1))
                .to_string()
        };
        println!(
            "  {variant:<8} acc {:.3}  energy/dev {e_dev:.1} mAh  converged by episode {conv}",
            h.final_accuracy()
        );
        w.row(&[
            variant.to_string(),
            format!("{:.4}", h.final_accuracy()),
            format!("{e_dev:.2}"),
            conv,
        ])?;
    }
    w.flush()?;
    Ok(())
}
