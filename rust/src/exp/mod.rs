//! Experiment harnesses: one per paper table/figure (see DESIGN.md §5).
pub mod harness;

pub use harness::{run_experiment, EXPERIMENTS};
